"""Dynamic-budget receding-horizon benchmark (DESIGN.md §15).

Day-scale scenarios riding the shipped grid-signal fixtures (96 points =
15-minute resolution): a CO2-intensity day on a flat cluster and a
solar-following budget on a racked cluster.  Three policies run through
identical sims per tier:

 * **myopic** — the default controller riding the instantaneous cap
   (H=1, today's behaviour, the baseline);
 * **reactive** — the signal-blind eco mode: the same controller under a
   uniformly derated budget (``ScaledProvider(base, ECO)``), i.e. the
   same average power reduction with no knowledge of *when* power is
   dirty;
 * **mpc** — the receding-horizon planner (``horizon=H``,
   ``eco_factor=ECO``) planning over the budget forecast weighted by the
   CO2 (or price) signal: it banks spend away from dirty rounds and
   toward clean ones.

Per tier the bench records total measured improvement (value), grams CO2
(sum of intensity x spent watts per round), dollars (price x spent), and
the derived perf-per-CO2 / perf-per-dollar.  **Compliance is validated
per round**: every policy's spent watts must stay under that round's
instantaneous budget (the planner only ever *shrinks* a round's budget).
The acceptance bar: MPC strictly beats myopic on perf-per-CO2 on the
CO2-day scenario.

Run as a module to emit ``BENCH_budget_horizon.json``:

    PYTHONPATH=src python -m benchmarks.budget_horizon [--fast]

``--check BENCH_budget_horizon.json`` guards fresh per-round times
against the committed reference (generous factor, shared-runner noise).
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, PowerTopology, scenario as sc
from repro.cluster import budget as bm
from repro.cluster.controller import make_controller

#: planner knobs (full tiers); ``--fast`` shortens the horizon with the day
HORIZON = 12
ECO = 0.7


def _sim(system, apps, surfs, n, topology=None) -> ClusterSim:
    return ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0,
        initial_caps=(150.0, 150.0), topology=topology,
    )


def _play(system, apps, surfs, n, scen, policy, topology=None, **ctrl_kw):
    """One full scenario replay; returns (result, seconds-per-round)."""
    sim = _sim(system, apps, surfs, n, topology=topology)
    ctrl = make_controller(policy, system, **ctrl_kw)
    t0 = time.perf_counter()
    res = sim.run(scen, ctrl)
    dt = time.perf_counter() - t0
    return res, dt / max(res.n_rounds, 1)


def _scores(res) -> dict:
    """Value / CO2 / dollars totals with per-round compliance validation."""
    value = 0.0
    grams = 0.0
    dollars = 0.0
    for rec in res.records:
        spent = rec.result.allocation.spent
        assert spent <= rec.result.budget + 1e-6, (
            f"round {rec.round}: spent {spent:.1f} W exceeds instantaneous "
            f"budget {rec.result.budget:.1f} W"
        )
        value += rec.avg_improvement
        if rec.carbon_intensity is not None:
            grams += rec.carbon_intensity * spent
        if rec.power_price is not None:
            dollars += rec.power_price * spent
    return {
        "value": value,
        "co2_g": grams,
        "dollars": dollars,
        "perf_per_co2": value / grams if grams > 0 else None,
        "perf_per_dollar": value / dollars if dollars > 0 else None,
        "compliant": True,
    }


def _policy_entry(name, res, per_round_s) -> dict:
    return {"policy": name, "round_s": per_round_s, **_scores(res)}


def _co2_day_tier(system, apps, surfs, *, fast: bool) -> dict:
    """Flat cluster through a grid-CO2 day under a constant site budget."""
    n = 64 if fast else 256
    n_rounds = 32 if fast else 96
    horizon = 8 if fast else HORIZON
    budget = 2.0 * n
    scen = sc.Scenario.carbon_aware(
        n_rounds, bm.ConstantProvider(budget)
    )
    cases = [
        ("myopic", scen, {}),
        (
            "reactive",
            scen.with_budget_provider(
                bm.ScaledProvider(bm.ConstantProvider(budget), ECO)
            ),
            {},
        ),
        ("mpc", scen, {"horizon": horizon, "eco_factor": ECO}),
    ]
    entry = {
        "tier": "co2_day_flat",
        "n_nodes": n,
        "n_rounds": n_rounds,
        "budget_w": budget,
        "horizon": horizon,
        "eco_factor": ECO,
        "policies": [],
    }
    for name, s, kw in cases:
        res, per_round = _play(system, apps, surfs, n, s, "ecoshift", **kw)
        entry["policies"].append(_policy_entry(name, res, per_round))
    by = {p["policy"]: p for p in entry["policies"]}
    assert by["mpc"]["perf_per_co2"] > by["myopic"]["perf_per_co2"], (
        f"MPC perf-per-CO2 {by['mpc']['perf_per_co2']:.4g} does not beat "
        f"myopic {by['myopic']['perf_per_co2']:.4g}"
    )
    entry["ppc_gain_vs_myopic"] = (
        by["mpc"]["perf_per_co2"] / by["myopic"]["perf_per_co2"]
    )
    entry["ppc_gain_vs_reactive"] = (
        by["mpc"]["perf_per_co2"] / by["reactive"]["perf_per_co2"]
    )
    return entry


def _solar_hier_tier(system, apps, surfs, *, fast: bool) -> dict:
    """Racked cluster on a solar-following budget (grid-backstop floor),
    CO2-weighted MPC vs myopic — the composed-provider scenario."""
    n = 48 if fast else 128
    n_racks = 4 if fast else 8
    n_rounds = 32 if fast else 96
    horizon = 8 if fast else HORIZON
    peak = 2.5 * n
    floor = 0.5 * n
    # racks comfortably above committed draw (~300 W/node at the initial
    # caps): the *solar budget* is the binding constraint in this tier
    topo = PowerTopology.uniform_racks(
        n, n_racks, rack_cap=320.0 * (n // n_racks) + peak / n_racks
    )
    provider = bm.solar_budget(peak, floor_watts=floor, n_rounds=n_rounds)
    scen = (
        sc.Scenario(
            n_rounds=n_rounds,
            budget=provider,
            carbon=bm.fixture_trace("co2_day", n_rounds),
            power_price=bm.fixture_trace("price_day", n_rounds),
        )
        .with_topology(topo)
    )
    entry = {
        "tier": "solar_hier",
        "n_nodes": n,
        "n_racks": n_racks,
        "n_rounds": n_rounds,
        "peak_w": peak,
        "floor_w": floor,
        "horizon": horizon,
        "eco_factor": ECO,
        "policies": [],
    }
    for name, kw in (
        ("myopic", {}),
        ("mpc", {"horizon": horizon, "eco_factor": ECO}),
    ):
        res, per_round = _play(
            system, apps, surfs, n, scen, "ecoshift_hier", topology=topo, **kw
        )
        entry["policies"].append(_policy_entry(name, res, per_round))
    by = {p["policy"]: p for p in entry["policies"]}
    entry["ppc_gain_vs_myopic"] = (
        by["mpc"]["perf_per_co2"] / by["myopic"]["perf_per_co2"]
    )
    return entry


def run(lines: list[str], *, fast: bool = False, results: list | None = None):
    system, apps, surfs = get_suite("system1-a100")
    for tier_fn in (_co2_day_tier, _solar_hier_tier):
        entry = tier_fn(system, apps, surfs, fast=fast)
        if results is not None:
            results.append(entry)
        for p in entry["policies"]:
            ppc = p["perf_per_co2"]
            lines.append(csv_line(
                f"budget_horizon.{entry['tier']}.{p['policy']}",
                p["round_s"] * 1e6,
                f"value={p['value']:.3f};co2_g={p['co2_g']:.0f};"
                f"ppc={ppc * 1e6 if ppc else 0.0:.3f}",
            ))


#: regression-guard tolerance vs a committed reference (benchmarks.*
#: convention: generous for shared-runner noise)
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Fresh per-round times and the MPC quality bar vs the committed run."""
    ref_by_key = {
        (t["tier"], p["policy"]): p
        for t in reference.get("tiers", [])
        for p in t["policies"]
    }
    problems = []
    for tier in results:
        for p in tier["policies"]:
            ref = ref_by_key.get((tier["tier"], p["policy"]))
            if ref is None:
                continue
            allowed = CHECK_FACTOR * ref["round_s"] + CHECK_SLACK_S
            if p["round_s"] > allowed:
                problems.append(
                    f"{tier['tier']}.{p['policy']}: round "
                    f"{p['round_s']:.3f}s exceeds {allowed:.3f}s "
                    f"({CHECK_FACTOR}x ref {ref['round_s']:.3f}s "
                    f"+ {CHECK_SLACK_S}s)"
                )
        if tier["tier"] == "co2_day_flat" and tier["ppc_gain_vs_myopic"] <= 1.0:
            problems.append(
                f"{tier['tier']}: MPC perf-per-CO2 gain "
                f"{tier['ppc_gain_vs_myopic']:.3f}x fell to/under 1.0"
            )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="trimmed day")
    ap.add_argument(
        "--out", default="BENCH_budget_horizon.json", help="JSON output"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh per-round times + the MPC quality bar against "
        "a committed reference (loaded before --out overwrites it); "
        "exit 1 on regression",
    )
    args = ap.parse_args()

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results)
    payload = {
        "benchmark": "budget_horizon",
        "fast": args.fast,
        "elapsed_s": time.time() - t0,
        "horizon": HORIZON,
        "eco_factor": ECO,
        "tiers": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
