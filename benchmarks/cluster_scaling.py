"""Cluster-scaling benchmark: grouped columnar engine vs legacy path.

Times one redistribution round at n ∈ {100, 1k, 10k} nodes for

 * **grouped**: the columnar engine — array partition, batched events,
   group-collapsed sparse DP (one super-stage per behaviour class),
   vectorized measurement;
 * **legacy**:  the per-node path — NodeState view materialization,
   per-instance option tables, one DP stage per receiver, per-node loop
   measurement —

plus allocator-only wall-clock (cold and warm caches) and a 20-round
grouped scenario at the top tier with failures/stragglers/arrivals.
Grouped-vs-legacy cap parity is asserted at every tier before timing.

Run as a module to emit ``BENCH_cluster_scaling.json``:

    PYTHONPATH=src python -m benchmarks.cluster_scaling [--fast]

``--check BENCH_cluster_scaling.json`` additionally guards against
regressions: fresh warm-round times must stay within a generous factor of
the committed reference (the reference is loaded before ``--out``
overwrites it, so both flags may point at the same file — CI does).
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, Scenario
from repro.cluster.controller import make_controller

#: wall-clock guard for the top-tier 20-round grouped scenario (matches the
#: CI smoke budget; the acceptance bar for DESIGN.md §11)
SCENARIO_BUDGET_S = 60.0


def _sim(system, apps, surfs, n: int) -> ClusterSim:
    # grid-aligned uniform initial caps: the realistic fleet-provisioning
    # case, and it keeps the sparse DP state lattice at watt-step pitch
    return ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0, initial_caps=(150.0, 150.0)
    )


def _budget(n: int) -> float:
    return float(min(2.0 * n, 8000.0))


def _legacy_round(sim: ClusterSim, ctrl, budget: float) -> float:
    """One legacy round: view materialization + per-instance DP + loop
    measurement (the pre-columnar engine's shape)."""
    t0 = time.perf_counter()
    _, recv, _ = sim.partition()
    sim.run_round(
        ctrl, budget=budget, receivers=recv, use_loop_measurement=True
    )
    return time.perf_counter() - t0


def _grouped_round(sim: ClusterSim, ctrl, budget: float) -> float:
    t0 = time.perf_counter()
    sim.run_round(ctrl, budget=budget)
    return time.perf_counter() - t0


def _alloc_times(sim: ClusterSim, budget: float) -> dict:
    """Allocator-only wall-clock: grouped vs legacy, cold and warm."""
    _, rows, _ = sim.partition_rows()
    batch = sim._receiver_batch(rows, None, False)
    out = {}
    ctrl = make_controller("ecoshift", sim.system)
    t0 = time.perf_counter()
    alloc_g = ctrl.allocate_grouped(batch, budget)
    out["grouped_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    ctrl.allocate_grouped(batch, budget)
    out["grouped_warm_s"] = time.perf_counter() - t0

    recv = sim.table.views(rows)
    apps = [n.app for n in recv]
    baselines = {n.app.name: n.caps for n in recv}
    seen = {n.app.name: sim._surface(n) for n in recv}
    ctrl_u = make_controller("ecoshift", sim.system, grouped=False)
    t0 = time.perf_counter()
    alloc_u = ctrl_u.allocate(apps, baselines, budget, seen)
    out["legacy_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    ctrl_u.allocate(apps, baselines, budget, seen)
    out["legacy_warm_s"] = time.perf_counter() - t0
    assert dict(alloc_g.caps) == dict(alloc_u.caps), "grouped/legacy divergence"
    return out


def _scenario(n_rounds: int, n: int, budget: float) -> Scenario:
    scen = Scenario.constant(n_rounds, budget=budget)
    scen = scen.with_failure(1, *range(0, max(1, n // 100)))
    scen = scen.with_straggler(min(2, n_rounds - 1), n // 2, 1.7)
    return scen


def run(lines: list[str], *, fast: bool = False, results: list | None = None):
    system, apps, surfs = get_suite("system1-a100")
    tiers = [100, 1000] if fast else [100, 1000, 10000]
    for n in tiers:
        budget = _budget(n)
        alloc = _alloc_times(_sim(system, apps, surfs, n), budget)

        sim_g = _sim(system, apps, surfs, n)
        ctrl_g = make_controller("ecoshift", system)
        t_round_cold = _grouped_round(sim_g, ctrl_g, budget)
        t_round_warm = _grouped_round(sim_g, ctrl_g, budget)

        sim_l = _sim(system, apps, surfs, n)
        ctrl_l = make_controller("ecoshift", system, grouped=False)
        t_legacy_cold = _legacy_round(sim_l, ctrl_l, budget)
        t_legacy_warm = _legacy_round(sim_l, ctrl_l, budget)

        speedup = t_legacy_warm / t_round_warm
        if n >= 10000:
            # acceptance bar (DESIGN.md §11.4); measured ~370x, so a 10x
            # floor is robust to shared-runner noise
            assert speedup >= 10.0, (
                f"grouped speedup at n={n} regressed to {speedup:.1f}x"
            )
        tier = {
            "n_nodes": n,
            "budget_w": budget,
            "alloc": alloc,
            "grouped_round_s": {"cold": t_round_cold, "warm": t_round_warm},
            "legacy_round_s": {"cold": t_legacy_cold, "warm": t_legacy_warm},
            "round_speedup_warm": speedup,
        }

        # top tier: a 20-round scenario with events, inside the CI guard
        if n == tiers[-1]:
            n_rounds = 20
            sim_s = _sim(system, apps, surfs, n)
            scen = _scenario(n_rounds, n, budget)
            t0 = time.perf_counter()
            trace = sim_s.run(scen, make_controller("ecoshift", system))
            elapsed = time.perf_counter() - t0
            assert trace.n_rounds == n_rounds
            assert np.isfinite(trace.improvement_trace).all()
            assert elapsed < SCENARIO_BUDGET_S, (
                f"{n}-node {n_rounds}-round scenario took {elapsed:.1f}s "
                f"(guard {SCENARIO_BUDGET_S}s)"
            )
            tier["scenario"] = {
                "n_rounds": n_rounds,
                "total_s": elapsed,
                "rounds_per_s": n_rounds / elapsed,
            }

        if results is not None:
            results.append(tier)
        lines.append(
            csv_line(
                f"cluster_scaling.n{n}",
                t_round_warm * 1e6,
                f"grouped_round_s={t_round_warm:.4f};"
                f"legacy_round_s={t_legacy_warm:.4f};"
                f"speedup={speedup:.1f}x;"
                f"alloc_grouped_warm_s={alloc['grouped_warm_s']:.4f};"
                f"alloc_legacy_warm_s={alloc['legacy_warm_s']:.4f}",
            )
        )


#: regression-guard tolerance vs a committed reference: generous, because
#: the reference was measured on a different (possibly idle) machine
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Compare fresh warm-round times against a committed reference run.

    A tier regresses when its fresh grouped warm round exceeds
    ``CHECK_FACTOR x ref + CHECK_SLACK_S`` — loose enough for shared-runner
    noise, tight enough to catch an accidental return to per-node scaling
    (the legacy path is 60-370x slower at the upper tiers).  Only tiers
    present in both runs are compared.  Returns regression messages.
    """
    ref_by_n = {t["n_nodes"]: t for t in reference.get("tiers", [])}
    problems = []
    for tier in results:
        ref = ref_by_n.get(tier["n_nodes"])
        if ref is None:
            continue
        fresh = tier["grouped_round_s"]["warm"]
        budget = CHECK_FACTOR * ref["grouped_round_s"]["warm"] + CHECK_SLACK_S
        if fresh > budget:
            problems.append(
                f"n={tier['n_nodes']}: warm grouped round {fresh:.3f}s "
                f"exceeds {budget:.3f}s "
                f"({CHECK_FACTOR}x ref {ref['grouped_round_s']['warm']:.3f}s "
                f"+ {CHECK_SLACK_S}s)"
            )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the 10k tier")
    ap.add_argument(
        "--out", default="BENCH_cluster_scaling.json", help="JSON output path"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh warm-round times against a committed reference "
        "(loaded before --out overwrites it); exit 1 on regression",
    )
    args = ap.parse_args()

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results)
    payload = {
        "benchmark": "cluster_scaling",
        "fast": args.fast,
        "elapsed_s": time.time() - t0,
        "tiers": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
