"""Shared benchmark context: systems, suites, trained predictors, timing.

Building the NCF predictor is the expensive part, so one ``Context`` per
system is built lazily and cached for the whole ``benchmarks.run`` session.
"""

from __future__ import annotations

import dataclasses
import functools
import time

from repro.cluster import ClusterSim
from repro.core import ncf, surfaces, types
from repro.core.allocator import EcoShiftAllocator
from repro.core.emulator import ClusterEmulator

#: benchmark-grade NCF config (full runs use the default 3000 steps)
NCF_CFG = ncf.NCFConfig(train_steps=2000, online_steps=400)

#: apps held out from offline training (onboarded online, like production)
N_HELDOUT = 12


@dataclasses.dataclass
class Context:
    system: types.SystemSpec
    apps: list[types.AppSpec]
    true_surfaces: dict
    allocator: EcoShiftAllocator
    #: instance-independent predicted surfaces keyed by app name
    predicted: dict
    unseen: list[str]

    def predicted_for(self, emu: ClusterEmulator) -> dict:
        """Instance-name -> predicted surface mapping for a cluster."""
        return {
            n.app.name: self.predicted[n.base_app]
            for n in emu.alive_nodes()
        }


@functools.lru_cache(maxsize=4)
def get_suite(system_name: str):
    """(system, apps, true_surfaces) without training the predictor."""
    system = types.SYSTEMS[system_name]
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


@functools.lru_cache(maxsize=4)
def get_context(system_name: str) -> Context:
    system = types.SYSTEMS[system_name]
    apps, surfs = surfaces.build_paper_suite(system)
    train_apps = apps[: len(apps) - N_HELDOUT]
    heldout = apps[len(apps) - N_HELDOUT :]
    hist = {a.name: surfs[a.name] for a in train_apps}
    alloc = EcoShiftAllocator.train_offline(system, hist, NCF_CFG)
    for a in train_apps:
        alloc.onboard_known(a.name)
    for i, a in enumerate(heldout):
        alloc.onboard(a.name, surfs[a.name], seed=i)
    return Context(
        system=system,
        apps=apps,
        true_surfaces=surfs,
        allocator=alloc,
        predicted=dict(alloc.predicted),
        unseen=[a.name for a in heldout],
    )


def build_cluster(
    ctx: Context, group: str, *, n_nodes: int = 100, seed: int = 0,
    initial_caps=None,
) -> ClusterEmulator:
    apps = surfaces.workload_group(ctx.apps, group)
    return ClusterEmulator.build(
        ctx.system, apps, ctx.true_surfaces, n_nodes=n_nodes, seed=seed,
        initial_caps=initial_caps,
    )


def build_cluster_sim(
    ctx: Context, group: str, *, n_nodes: int = 100, seed: int = 0,
    initial_caps=None,
) -> ClusterSim:
    """Multi-round engine view of the same cluster (repro.cluster.sim)."""
    apps = surfaces.workload_group(ctx.apps, group)
    return ClusterSim.build(
        ctx.system, apps, ctx.true_surfaces, n_nodes=n_nodes, seed=seed,
        initial_caps=initial_caps,
    )


def timed(fn, *args, repeats: int = 3, **kw):
    """(result, microseconds-per-call)."""
    out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def csv_line(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
