"""§3.2.3 complexity: DP-search scaling in receivers and budget + kernels.

Reports wall time of the faithful sparse Algorithm-1 solver, the vectorized
dense DP and the jit'd JAX scan (with the Pallas (max,+) kernel path) as
N_receivers and the budget grow, plus per-call timings of the Pallas
kernels in interpret mode.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_context, timed
from repro.core import curves, mckp


def _options(ctx, n_apps: int, budget: float):
    base = (ctx.system.init_cpu, ctx.system.init_gpu)
    out = []
    for i in range(n_apps):
        app = ctx.apps[i % len(ctx.apps)]
        out.append(
            curves.build_options(
                f"{app.name}#{i}",
                ctx.true_surfaces[app.name],
                base,
                ctx.system.grid,
                budget,
            )
        )
    return out


def run(lines: list[str], *, fast: bool = False) -> None:
    ctx = get_context("system1-a100")
    cases = [(10, 1000.0), (50, 3500.0), (100, 7000.0)]
    if not fast:
        cases.append((200, 14000.0))
    for n_apps, budget in cases:
        opts = _options(ctx, n_apps, budget)
        sol_sparse, us_sparse = timed(mckp.solve_sparse, opts, budget, repeats=1)
        sol_dense, us_dense = timed(mckp.solve_dense, opts, budget, repeats=1)
        sol_jax, us_jax = timed(
            mckp.solve_dense_jax, opts, budget, repeats=1
        )
        assert abs(sol_sparse.total_value - sol_dense.total_value) < 1e-6
        lines.append(
            csv_line(
                f"dp_scaling.N{n_apps}.B{int(budget)}",
                us_sparse,
                f"sparse_us={us_sparse:.0f};dense_us={us_dense:.0f};"
                f"jax_us={us_jax:.0f};value={sol_sparse.total_value:.3f}",
            )
        )

    # Pallas kernel micro-benchmarks (interpret mode on CPU)
    import jax.numpy as jnp

    from repro.kernels import mckp_dp, ref

    rng = np.random.default_rng(0)
    nb = 512
    dp = jnp.asarray(np.maximum.accumulate(rng.uniform(0, 1, nb)), jnp.float32)
    f = jnp.asarray(np.maximum.accumulate(rng.uniform(0, 1, nb)), jnp.float32)
    _, us_pallas = timed(
        lambda: mckp_dp.maxplus_conv_pallas(dp, f)[0].block_until_ready(),
        repeats=2,
    )
    _, us_ref = timed(
        lambda: ref.maxplus_conv(dp, f)[0].block_until_ready(), repeats=2
    )
    lines.append(
        csv_line(
            "kernel.maxplus_conv.nb512",
            us_pallas,
            f"interpret_us={us_pallas:.0f};ref_us={us_ref:.0f};"
            f"work={nb*nb} cell-ops",
        )
    )
