"""Chaos benchmark: value retention + cap safety under fault storms
(DESIGN.md §18).

Three tiers over identical sims:

 * **storm_sweep** — a flat cluster under seeded fault storms of rising
   intensity (per-channel per-round probability 0 -> 0.30: telemetry
   drops/corruption + actuation NACK/partial/delay).  Per rate the bench
   records delivered value, value retention vs the clean run, the worst
   pre-derate PowerGuard excursion, and the number of rounds whose
   *settled* draw exceeded the budget — the chaos invariant is that the
   last number is **zero at every rate** (a stuck actuator causes at most
   a sub-round excursion, clawed back by the same round's derate).
 * **storm_hier** — a racked cluster under the heaviest storm plus
   controller crashes; the invariant extends to every power-domain cap
   (settled per-domain draw <= cap, every round, no consecutive-round
   excursions).
 * **crash_restore** — controller crash mid-run with snapshot restore:
   ``recovery_rounds`` counts post-crash rounds whose allocation differs
   from the uninterrupted reference (bit-for-bit restore => 0).

Run as a module to emit ``BENCH_fault_storm.json``:

    PYTHONPATH=src python -m benchmarks.fault_storm [--fast]

``--check BENCH_fault_storm.json`` guards fresh per-round times and the
chaos invariants against the committed reference.
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, PowerTopology, Scenario
from repro.cluster.controller import make_controller
from repro.cluster.faults import ControllerCrash

#: per-channel per-round fault probabilities swept by the flat tier
RATES = (0.0, 0.05, 0.15, 0.30)


def _budget_trace(n_rounds: int, nominal: float) -> list[float]:
    """Deterministic varying budget (NACKs are invisible on a constant
    trace: keeping yesterday's caps *is* the command)."""
    t = np.arange(n_rounds)
    return (nominal * (1.0 + 0.5 * np.sin(2.0 * np.pi * t / 7.0))).tolist()


def _storm(scen: Scenario, rate: float, *, seed: int, crash_rounds=()):
    if rate <= 0.0 and not crash_rounds:
        return scen
    return scen.with_fault_storm(
        seed=seed,
        telemetry_drop=rate / 2,
        telemetry_delay=rate / 2,
        telemetry_corrupt=rate,
        telemetry_stale=rate / 2,
        actuation_nack=rate,
        actuation_partial=rate,
        actuation_delay=rate / 2,
        node_fraction=0.3,
        crash_rounds=crash_rounds,
    )


def _play(system, apps, surfs, n, scen, policy, topology=None):
    sim = ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0, topology=topology
    )
    ctrl = make_controller(policy, system)
    t0 = time.perf_counter()
    res = sim.run(scen, ctrl)
    dt = time.perf_counter() - t0
    return res, dt / max(res.n_rounds, 1)


def _safety(res) -> dict:
    """Settled-draw safety counters over a trace (chaos invariants)."""
    overdraw_rounds = 0
    consecutive = 0
    max_consecutive = 0
    max_excursion = 0.0
    derate_total = 0.0
    nack_rounds = 0
    for rec in res.records:
        extra = sum(
            float(np.sum(t.allocated_caps) - np.sum(t.baseline_caps))
            for t in rec.telemetry
        )
        violated = extra > rec.result.budget + 1e-6
        if rec.domain_draw:
            violated = violated or any(
                w > rec.domain_caps[d] + 1e-6
                for d, w in rec.domain_draw.items()
            )
        if violated:
            overdraw_rounds += 1
            consecutive += 1
            max_consecutive = max(max_consecutive, consecutive)
        else:
            consecutive = 0
        max_excursion = max(max_excursion, rec.overdraw_w)
        derate_total += rec.derate_w
        nack_rounds += bool(rec.nacked)
    return {
        "overdraw_rounds": overdraw_rounds,
        "max_consecutive_overdraw": max_consecutive,
        "max_excursion_w": max_excursion,
        "derate_total_w": derate_total,
        "nack_rounds": nack_rounds,
    }


def _storm_sweep_tier(system, apps, surfs, *, fast: bool) -> dict:
    n = 32 if fast else 64
    n_rounds = 12 if fast else 24
    budgets = _budget_trace(n_rounds, 40.0 * n)
    entry = {
        "tier": "storm_sweep_flat",
        "n_nodes": n,
        "n_rounds": n_rounds,
        "rates": [],
    }
    clean_value = None
    for rate in RATES:
        scen = _storm(Scenario(n_rounds, budget=budgets), rate, seed=17)
        res, per_round = _play(system, apps, surfs, n, scen, "ecoshift")
        value = float(sum(r.avg_improvement for r in res.records))
        if rate == 0.0:
            clean_value = value
        safety = _safety(res)
        assert safety["overdraw_rounds"] == 0, (
            f"rate {rate}: settled draw exceeded the budget in "
            f"{safety['overdraw_rounds']} round(s)"
        )
        entry["rates"].append({
            "rate": rate,
            "round_s": per_round,
            "value": value,
            "value_retention": value / clean_value if clean_value else None,
            **safety,
        })
    return entry


def _storm_hier_tier(system, apps, surfs, *, fast: bool) -> dict:
    n = 30 if fast else 60
    n_racks = 3 if fast else 6
    n_rounds = 12 if fast else 24
    budgets = _budget_trace(n_rounds, 35.0 * n)
    # racks sized so both the budget and the rack caps bind under NACKs
    topo = PowerTopology.uniform_racks(
        n, n_racks, rack_cap=300.0 * (n // n_racks) + 18.0 * n
    )
    scen = _storm(
        Scenario(n_rounds, budget=budgets).with_topology(topo),
        0.30,
        seed=23,
        crash_rounds=(n_rounds // 2,),
    )
    res, per_round = _play(
        system, apps, surfs, n, scen, "ecoshift_hier", topology=topo
    )
    safety = _safety(res)
    assert safety["overdraw_rounds"] == 0, (
        f"settled domain draw exceeded a cap in "
        f"{safety['overdraw_rounds']} round(s)"
    )
    assert safety["max_consecutive_overdraw"] == 0
    return {
        "tier": "storm_hier",
        "n_nodes": n,
        "n_racks": n_racks,
        "n_rounds": n_rounds,
        "rate": 0.30,
        "round_s": per_round,
        "value": float(sum(r.avg_improvement for r in res.records)),
        **safety,
    }


def _crash_restore_tier(system, apps, surfs, *, fast: bool) -> dict:
    n = 32 if fast else 64
    n_rounds = 12 if fast else 24
    crash_at = n_rounds // 2
    budgets = _budget_trace(n_rounds, 40.0 * n)
    clean = Scenario(n_rounds, budget=budgets)
    ref, _ = _play(system, apps, surfs, n, clean, "ecoshift")
    entry = {
        "tier": "crash_restore",
        "n_nodes": n,
        "n_rounds": n_rounds,
        "crash_round": crash_at,
        "cases": [],
    }
    for name, restore in (("restore", True), ("cold", False)):
        scen = clean.with_faults(
            [ControllerCrash(round=crash_at, restore=restore)]
        )
        res, per_round = _play(system, apps, surfs, n, scen, "ecoshift")
        recovery = sum(
            dict(a.result.allocation.caps) != dict(b.result.allocation.caps)
            for a, b in zip(
                ref.records[crash_at:], res.records[crash_at:]
            )
        )
        if restore:
            assert recovery == 0, (
                f"snapshot-restored run diverged for {recovery} round(s)"
            )
        entry["cases"].append({
            "case": name,
            "round_s": per_round,
            "recovery_rounds": int(recovery),
        })
    return entry


def run(lines: list[str], *, fast: bool = False, results: list | None = None):
    system, apps, surfs = get_suite("system1-a100")
    for tier_fn in (_storm_sweep_tier, _storm_hier_tier, _crash_restore_tier):
        entry = tier_fn(system, apps, surfs, fast=fast)
        if results is not None:
            results.append(entry)
        if entry["tier"] == "storm_sweep_flat":
            for r in entry["rates"]:
                ret = r["value_retention"]
                lines.append(csv_line(
                    f"fault_storm.sweep.rate{r['rate']:.2f}",
                    r["round_s"] * 1e6,
                    f"value={r['value']:.3f};"
                    f"retention={ret if ret is not None else 1.0:.3f};"
                    f"max_excursion_w={r['max_excursion_w']:.1f};"
                    f"overdraw_rounds={r['overdraw_rounds']}",
                ))
        elif entry["tier"] == "storm_hier":
            lines.append(csv_line(
                "fault_storm.hier.rate0.30",
                entry["round_s"] * 1e6,
                f"value={entry['value']:.3f};"
                f"max_excursion_w={entry['max_excursion_w']:.1f};"
                f"overdraw_rounds={entry['overdraw_rounds']}",
            ))
        else:
            for c in entry["cases"]:
                lines.append(csv_line(
                    f"fault_storm.crash.{c['case']}",
                    c["round_s"] * 1e6,
                    f"recovery_rounds={c['recovery_rounds']}",
                ))


#: regression-guard tolerance vs a committed reference (benchmarks.*
#: convention: generous for shared-runner noise)
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Fresh per-round times + chaos invariants vs the committed run."""
    ref_times = {}
    for t in reference.get("tiers", []):
        if t["tier"] == "storm_sweep_flat":
            for r in t["rates"]:
                ref_times[("sweep", r["rate"])] = r["round_s"]
        elif t["tier"] == "storm_hier":
            ref_times[("hier", t["rate"])] = t["round_s"]
        else:
            for c in t["cases"]:
                ref_times[("crash", c["case"])] = c["round_s"]

    problems = []

    def _time_check(key, round_s):
        ref = ref_times.get(key)
        if ref is None:
            return
        allowed = CHECK_FACTOR * ref + CHECK_SLACK_S
        if round_s > allowed:
            problems.append(
                f"{key}: round {round_s:.3f}s exceeds {allowed:.3f}s "
                f"({CHECK_FACTOR}x ref {ref:.3f}s + {CHECK_SLACK_S}s)"
            )

    for t in results:
        if t["tier"] == "storm_sweep_flat":
            for r in t["rates"]:
                _time_check(("sweep", r["rate"]), r["round_s"])
                if r["overdraw_rounds"] != 0:
                    problems.append(
                        f"sweep rate {r['rate']}: "
                        f"{r['overdraw_rounds']} settled overdraw round(s)"
                    )
        elif t["tier"] == "storm_hier":
            _time_check(("hier", t["rate"]), t["round_s"])
            if t["overdraw_rounds"] != 0:
                problems.append(
                    f"hier: {t['overdraw_rounds']} settled overdraw round(s)"
                )
        else:
            for c in t["cases"]:
                _time_check(("crash", c["case"]), c["round_s"])
                if c["case"] == "restore" and c["recovery_rounds"] != 0:
                    problems.append(
                        f"crash_restore: restored run diverged for "
                        f"{c['recovery_rounds']} round(s)"
                    )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="trimmed storm")
    ap.add_argument(
        "--out", default="BENCH_fault_storm.json", help="JSON output"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh per-round times + chaos invariants against a "
        "committed reference (loaded before --out overwrites it); "
        "exit 1 on regression",
    )
    args = ap.parse_args()

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results)
    payload = {
        "benchmark": "fault_storm",
        "fast": args.fast,
        "elapsed_s": time.time() - t0,
        "rates": list(RATES),
        "tiers": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
