"""Fig. 10: CDF of the gap between EcoShift and the exhaustive Oracle.

100 test configurations per system: 5 random 10-app selections x 5 initial
cap pairs x 4 budgets.  EcoShift runs the full pipeline (NCF-predicted
surfaces + DP); the Oracle solves on true surfaces.  Paper: 90% of cases
within 3 pp, median ~1.2-1.5 pp.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_context
from repro.core import metrics, policies


def _configs(ctx, n_sel: int, rng):
    grid = ctx.system.grid
    lo_c, hi_c = grid.cpu_min, grid.cpu_max
    lo_g, hi_g = grid.gpu_min, grid.gpu_max
    caps = [
        (lo_c + f * (hi_c - lo_c) / 2, lo_g + f * (hi_g - lo_g) / 2)
        for f in (0.0, 0.25, 0.5, 0.75, 1.0)
    ]
    caps = [ctx.system.grid.snap(c, g) for c, g in caps]
    budgets = (500.0, 1000.0, 2000.0, 4000.0)
    for _ in range(n_sel):
        sel = rng.choice(len(ctx.apps), size=10, replace=False)
        apps = [ctx.apps[i] for i in sel]
        for cap in caps:
            for b in budgets:
                yield apps, cap, b


def run(lines: list[str], *, fast: bool = False) -> None:
    for system_name, tag in (("system2-h100", "h100"), ("system1-a100", "a100")):
        ctx = get_context(system_name)
        rng = np.random.default_rng(0)
        gaps = []
        n_sel = 2 if fast else 5
        for apps, caps, budget in _configs(ctx, n_sel, rng):
            baselines = {a.name: caps for a in apps}
            pred = {a.name: ctx.predicted[a.name] for a in apps}
            true = {a.name: ctx.true_surfaces[a.name] for a in apps}
            eco = policies.ecoshift(apps, baselines, budget, ctx.system, pred)
            orc = policies.oracle(
                apps, baselines, budget, ctx.system, true, exhaustive=False
            )

            def realized(alloc):
                gains = [
                    float(
                        true[a.name].improvement(baselines[a.name], *alloc.caps[a.name])
                    )
                    for a in apps
                ]
                return float(np.mean(gains))

            gaps.append((realized(orc) - realized(eco)) * 100)
        g, cdf, s = metrics.gap_cdf(np.array(gaps))
        lines.append(
            csv_line(
                f"fig10.oracle_gap.{tag}",
                0.0,
                f"median={s['median']:.2f}pp;mean={s['mean']:.2f}pp;"
                f"p90={s['p90']:.2f}pp;within1={s['frac_within_1pp']*100:.0f}%;"
                f"within2={s['frac_within_2pp']*100:.0f}%;"
                f"within3={s['frac_within_3pp']*100:.0f}%",
            )
        )
