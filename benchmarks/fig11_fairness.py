"""Fig. 11: Jain's fairness index on the mixed workloads, both systems."""

from __future__ import annotations

from benchmarks.common import csv_line, get_context
from benchmarks.policy_eval import POLICIES, evaluate

BUDGET = {"system1-a100": 3500.0, "system2-h100": 7000.0}


def run(lines: list[str], *, fast: bool = False) -> None:
    systems = ("system1-a100",) if fast else ("system1-a100", "system2-h100")
    for system_name in systems:
        ctx = get_context(system_name)
        jains = {}
        for policy in POLICIES:
            res = evaluate(ctx, "mixed", policy, BUDGET[system_name], seeds=(0, 1, 2))
            jains[policy] = res.jain
            lines.append(
                csv_line(
                    f"fig11.{ctx.system.name}.{policy}",
                    0.0,
                    f"jain={res.jain:.3f};mean_impr={res.mean*100:.2f}%",
                )
            )
        gap = jains["ecoshift"] - min(jains["dps"], jains["mixed_adaptive"])
        lines.append(
            csv_line(
                f"fig11.{ctx.system.name}.summary",
                0.0,
                f"ecoshift_jain_vs_worst_baseline={gap:+.3f}",
            )
        )
