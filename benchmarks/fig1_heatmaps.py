"""Fig. 1: performance heatmaps across (cpu, gpu) cap pairs, 4 classes."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_suite

#: one representative app per sensitivity class (paper uses these four)
REPRESENTATIVES = {
    "C": "hecbench.softmax",
    "G": "spec.tealeaf",
    "B": "mlperf.ResNet50",
    "N": "spec.minisweep",
}


def run(lines: list[str]) -> None:
    system, apps, surfs = get_suite("system2-h100")
    grid = system.grid
    cc, gg = np.meshgrid(grid.cpu_levels, grid.gpu_levels, indexing="ij")
    for sclass, name in REPRESENTATIVES.items():
        surf = surfs[name]
        t = np.asarray(surf.runtime(cc, gg))
        norm = t / t.min()  # normalized runtime (1.0 = fastest corner)
        # sensitivity along each axis: relative runtime range
        cpu_sens = float((norm.max(axis=0) / norm.min(axis=0)).max() - 1)
        gpu_sens = float((norm.max(axis=1) / norm.min(axis=1)).max() - 1)
        lines.append(
            csv_line(
                f"fig1.heatmap.{sclass}.{name}",
                0.0,
                f"cpu_sens={cpu_sens:.3f};gpu_sens={gpu_sens:.3f};"
                f"worst_over_best={norm.max():.3f}",
            )
        )
