"""Fig. 2: diminishing marginal gains from extra CPU/GPU budget.

Validates the published anchors: cfd +17%/+7.6% per 100 W CPU step,
raytracing +15.5%/+2.1% per 100 W GPU step, plus cross-component
insensitivity.
"""

from __future__ import annotations

from benchmarks.common import csv_line
from repro.core import surfaces


def run(lines: list[str]) -> None:
    cfd = surfaces.cfd_surface()
    rt = surfaces.raytracing_surface()

    def gain(surf, a, b):
        ta, tb = float(surf.runtime(*a)), float(surf.runtime(*b))
        return (ta - tb) / ta * 100

    rows = [
        ("cfd.cpu_300_400", gain(cfd, (300, 200), (400, 200)), 17.0),
        ("cfd.cpu_400_500", gain(cfd, (400, 200), (500, 200)), 7.6),
        ("raytracing.gpu_200_300", gain(rt, (300, 200), (300, 300)), 15.5),
        ("raytracing.gpu_300_400", gain(rt, (300, 300), (300, 400)), 2.1),
        ("cfd.gpu_200_400_cross", gain(cfd, (300, 200), (300, 400)), None),
        ("raytracing.cpu_300_500_cross", gain(rt, (300, 200), (500, 200)), None),
    ]
    for name, got, want in rows:
        tag = f"got={got:.2f}%"
        if want is not None:
            tag += f";paper={want}%;abs_err={abs(got - want):.3f}pp"
        lines.append(csv_line(f"fig2.{name}", 0.0, tag))
