"""Figs. 5 & 7: avg improvement vs reclaimed budget, per workload group.

System 1 (Fig. 5, initial caps 140/150 W) and System 2 (Fig. 7, 300/300 W),
100-node clusters, EcoShift (NCF-predicted surfaces) vs DPS vs
MixedAdaptive, 98% CIs over 5 seeds.

Runs on the scenario API: each (group, policy, seed) steps ONE budget-trace
scenario through ``repro.cluster.sim`` — EcoShift's per-receiver option
tables build on the first budget and re-solve warm on the rest.
"""

from __future__ import annotations

from benchmarks.common import csv_line, get_context, timed
from benchmarks.policy_eval import GROUPS, POLICIES, evaluate_trace

BUDGETS = {
    "system1-a100": (1000.0, 3500.0, 7000.0),
    "system2-h100": (3500.0, 7000.0, 14000.0),
}
FIG = {"system1-a100": "fig5", "system2-h100": "fig7"}


def run(lines: list[str], *, fast: bool = False) -> None:
    for system_name, budgets in BUDGETS.items():
        ctx = get_context(system_name)
        groups = ("mixed",) if fast else GROUPS
        budgets_use = budgets[1:2] if fast else budgets
        for group in groups:
            results = {}
            for policy in POLICIES:
                by_budget, us = timed(
                    evaluate_trace, ctx, group, policy, budgets_use, repeats=1
                )
                results[policy] = by_budget
                for budget in budgets_use:
                    res = by_budget[budget]
                    lines.append(
                        csv_line(
                            f"{FIG[system_name]}.{group}.B{int(budget)}.{policy}",
                            us / len(budgets_use),
                            f"mean={res.mean*100:.2f}%;"
                            f"ci=[{res.lo*100:.2f},{res.hi*100:.2f}]",
                        )
                    )
            for budget in budgets_use:
                adv = results["ecoshift"][budget].mean - max(
                    results["dps"][budget].mean,
                    results["mixed_adaptive"][budget].mean,
                )
                lines.append(
                    csv_line(
                        f"{FIG[system_name]}.{group}.B{int(budget)}.advantage",
                        0.0,
                        f"ecoshift_vs_best_baseline={adv*100:+.2f}pp",
                    )
                )
