"""Figs. 6 & 8: sweep the initial cap pair at a fixed reclaimed budget.

Tight initial caps leave room for performance-aware reallocation; all
policies converge as the caps approach power-sufficiency (paper §6.1).
"""

from __future__ import annotations

from benchmarks.common import csv_line, get_context
from benchmarks.policy_eval import POLICIES, evaluate

SWEEPS = {
    # (fig, budget, [(cpu0, gpu0), ...])
    "system1-a100": ("fig6", 7000.0, [(125.0, 125.0), (200.0, 200.0), (300.0, 300.0)]),
    "system2-h100": ("fig8", 14000.0, [(225.0, 150.0), (300.0, 300.0), (425.0, 425.0)]),
}


def run(lines: list[str], *, fast: bool = False) -> None:
    for system_name, (fig, budget, caps_list) in SWEEPS.items():
        ctx = get_context(system_name)
        caps_use = caps_list[:2] if fast else caps_list
        tight_adv = loose_adv = None
        for caps in caps_use:
            results = {}
            for policy in POLICIES:
                res = evaluate(
                    ctx, "mixed", policy, budget, initial_caps=caps,
                    seeds=(0, 1, 2),
                )
                results[policy] = res
                lines.append(
                    csv_line(
                        f"{fig}.caps{int(caps[0])}_{int(caps[1])}.{policy}",
                        0.0,
                        f"mean={res.mean*100:.2f}%",
                    )
                )
            adv = results["ecoshift"].mean - max(
                results["dps"].mean, results["mixed_adaptive"].mean
            )
            if tight_adv is None:
                tight_adv = adv
            loose_adv = adv
        lines.append(
            csv_line(
                f"{fig}.convergence",
                0.0,
                f"advantage_tight={tight_adv*100:+.2f}pp;"
                f"advantage_loose={loose_adv*100:+.2f}pp",
            )
        )
