"""Fig. 9: distribution of per-application improvements (violin stand-in)."""

from __future__ import annotations

from benchmarks.common import csv_line, get_context
from benchmarks.policy_eval import POLICIES, evaluate
from repro.core import metrics


def run(lines: list[str], *, fast: bool = False) -> None:
    ctx = get_context("system1-a100")
    groups = ("mixed",) if fast else ("cpu", "gpu", "both", "mixed")
    for group in groups:
        for policy in POLICIES:
            res = evaluate(ctx, group, policy, 3500.0, seeds=(0, 1, 2))
            q = metrics.violin_quantiles(res.improvements)
            lines.append(
                csv_line(
                    f"fig9.{group}.{policy}",
                    0.0,
                    f"median={q['median']*100:.2f}%;p25={q['p25']*100:.2f}%;"
                    f"p75={q['p75']*100:.2f}%;p95={q['p95']*100:.2f}%",
                )
            )
