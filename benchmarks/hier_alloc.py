"""Hierarchical vs flat allocation benchmark (DESIGN.md §12).

For n ∈ {1k, 10k} nodes and rack fan-outs {1, 4, 16}, times one
redistribution round through

 * **flat**: the group-collapsed columnar engine (no topology) — the PR 3
   reference path;
 * **hier**: the same engine with a site → rack PowerTopology attached and
   the two-level capped-frontier solver (``ecoshift_hier``);

and reports achieved performance (average measured improvement) plus each
path's worst per-domain overdraw — the flat allocator ignores rack caps
and overdraws tight racks, the hierarchical one never does (engine-
asserted).  Rack caps are set to committed draw + 60% of the rack's
budget share, so the caps genuinely bind.

At fan-out 1 the topology degenerates to a single root and the
hierarchical allocation is asserted cap-for-cap equal to the flat one; at
10k nodes the multi-domain warm round must finish within 2x the flat warm
round (the DESIGN.md §12 acceptance bar).

Run as a module to emit ``BENCH_hier_alloc.json``:

    PYTHONPATH=src python -m benchmarks.hier_alloc [--fast]
"""

from __future__ import annotations

import json
import time

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, PowerDomain, PowerTopology
from repro.cluster.controller import make_controller

#: acceptance bar: multi-domain round time vs the flat grouped round
MAX_RATIO_VS_FLAT = 2.0

#: rack headroom as a fraction of the rack's even budget share
RACK_HEADROOM_FRAC = 0.6


def _sim(system, apps, surfs, n: int, topology=None) -> ClusterSim:
    return ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0,
        initial_caps=(150.0, 150.0), topology=topology,
    )


def _budget(n: int) -> float:
    return float(min(2.0 * n, 8000.0))


def _topology(system, apps, surfs, n: int, n_racks: int, budget: float):
    """Site → rack tree with *per-rack* binding caps: each rack gets its
    own committed draw + 60% of its even budget share, so every rack's
    cap genuinely binds (fan-out 1 keeps an unconstrained root — the
    parity anchor)."""
    if n_racks == 1:
        return PowerTopology.single_root(n, cap=1e18)
    probe = _sim(
        system, apps, surfs, n,
        topology=PowerTopology.uniform_racks(n, n_racks, rack_cap=1e15),
    )
    _, committed, _ = probe.domain_headroom(0)
    rack_extra = RACK_HEADROOM_FRAC * budget / n_racks
    racks = tuple(
        PowerDomain(
            name=probe.topology.domains[i].name,
            cap=float(committed[i]) + rack_extra,
            nodes=probe.topology.domains[i].nodes,
        )
        for i in probe.topology.leaf_ids
    )
    return PowerTopology(PowerDomain(name="site", cap=1e18, children=racks))


def _timed_round(sim, ctrl, budget: float) -> tuple[float, object]:
    t0 = time.perf_counter()
    res = sim.run_round(ctrl, budget=budget)
    return time.perf_counter() - t0, res


def _max_overdraw(sim) -> float:
    if not sim.last_domain_draw:
        return 0.0
    return max(
        0.0,
        max(
            sim.last_domain_draw[k] - sim.last_domain_caps[k]
            for k in sim.last_domain_draw
        ),
    )


def run(lines: list[str], *, fast: bool = False, results: list | None = None):
    system, apps, surfs = get_suite("system1-a100")
    tiers = [1000] if fast else [1000, 10000]
    fanouts = [1, 4, 16]
    for n in tiers:
        budget = _budget(n)

        # flat grouped reference (no topology): cold + warm round
        sim_f = _sim(system, apps, surfs, n)
        ctrl_f = make_controller("ecoshift", system)
        t_flat_cold, res_flat = _timed_round(sim_f, ctrl_f, budget)
        t_flat_warm, _ = _timed_round(sim_f, ctrl_f, budget)

        tier = {
            "n_nodes": n,
            "budget_w": budget,
            "flat_round_s": {"cold": t_flat_cold, "warm": t_flat_warm},
            "fanouts": [],
        }
        for n_racks in fanouts:
            topo = _topology(system, apps, surfs, n, n_racks, budget)

            sim_h = _sim(system, apps, surfs, n, topology=topo)
            ctrl_h = make_controller("ecoshift_hier", system)
            t_cold, res_h = _timed_round(sim_h, ctrl_h, budget)
            hier_over = _max_overdraw(sim_h)
            t_warm, _ = _timed_round(sim_h, ctrl_h, budget)

            if n_racks == 1:
                # single-root degenerate topology == flat, cap for cap
                assert dict(res_h.allocation.caps) == dict(
                    res_flat.allocation.caps
                ), "single-root hierarchical diverged from flat grouped"

            # what a flat allocator does to the same rack caps
            sim_v = _sim(system, apps, surfs, n, topology=topo)
            sim_v.run_round(make_controller("ecoshift", system), budget=budget)
            flat_over = _max_overdraw(sim_v)

            ratio = t_warm / t_flat_warm
            if n >= 10000 and n_racks > 1:
                assert ratio <= MAX_RATIO_VS_FLAT, (
                    f"hier round at n={n}, {n_racks} racks took "
                    f"{ratio:.2f}x the flat round (bar {MAX_RATIO_VS_FLAT}x)"
                )
            entry = {
                "n_racks": n_racks,
                "hier_round_s": {"cold": t_cold, "warm": t_warm},
                "ratio_warm_vs_flat": ratio,
                "hier_avg_improvement": res_h.avg_improvement,
                "flat_avg_improvement": res_flat.avg_improvement,
                "hier_max_overdraw_w": hier_over,
                "flat_max_overdraw_w": flat_over,
            }
            assert hier_over <= 1e-6, "hierarchical path overdrew a domain"
            tier["fanouts"].append(entry)
            lines.append(
                csv_line(
                    f"hier_alloc.n{n}.racks{n_racks}",
                    t_warm * 1e6,
                    f"hier_warm_s={t_warm:.4f};flat_warm_s={t_flat_warm:.4f};"
                    f"ratio={ratio:.2f}x;"
                    f"hier_imp={res_h.avg_improvement * 100:.2f}%;"
                    f"flat_imp={res_flat.avg_improvement * 100:.2f}%;"
                    f"flat_overdraw_w={flat_over:.0f};"
                    f"hier_overdraw_w={hier_over:.0f}",
                )
            )
        if results is not None:
            results.append(tier)


#: regression-guard tolerance vs a committed reference (mirrors
#: benchmarks.cluster_scaling; generous for shared-runner noise)
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Warm hierarchical-round regressions vs a committed reference run.

    Compares (n_nodes, n_racks) pairs present in both runs; a fresh warm
    round above ``CHECK_FACTOR x ref + CHECK_SLACK_S`` regresses.
    """
    ref_by_key = {
        (t["n_nodes"], f["n_racks"]): f
        for t in reference.get("tiers", [])
        for f in t["fanouts"]
    }
    problems = []
    for tier in results:
        for f in tier["fanouts"]:
            ref = ref_by_key.get((tier["n_nodes"], f["n_racks"]))
            if ref is None:
                continue
            fresh = f["hier_round_s"]["warm"]
            budget = CHECK_FACTOR * ref["hier_round_s"]["warm"] + CHECK_SLACK_S
            if fresh > budget:
                problems.append(
                    f"n={tier['n_nodes']}, racks={f['n_racks']}: warm hier "
                    f"round {fresh:.3f}s exceeds {budget:.3f}s "
                    f"({CHECK_FACTOR}x ref {ref['hier_round_s']['warm']:.3f}s "
                    f"+ {CHECK_SLACK_S}s)"
                )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the 10k tier")
    ap.add_argument(
        "--out", default="BENCH_hier_alloc.json", help="JSON output path"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh warm hier-round times against a committed "
        "reference (loaded before --out overwrites it); exit 1 on regression",
    )
    args = ap.parse_args()

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results)
    payload = {
        "benchmark": "hier_alloc",
        "fast": args.fast,
        "elapsed_s": time.time() - t0,
        "tiers": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
