"""Hierarchical vs flat allocation benchmark (DESIGN.md §12).

For n ∈ {1k, 10k} nodes and rack fan-outs {1, 4, 16}, times one
redistribution round through

 * **flat**: the group-collapsed columnar engine (no topology) — the PR 3
   reference path;
 * **hier**: the same engine with a site → rack PowerTopology attached and
   the two-level capped-frontier solver (``ecoshift_hier``);

and reports achieved performance (average measured improvement) plus each
path's worst per-domain overdraw — the flat allocator ignores rack caps
and overdraws tight racks, the hierarchical one never does (engine-
asserted).  Rack caps are set to committed draw + 60% of the rack's
budget share, so the caps genuinely bind.

At fan-out 1 the topology degenerates to a single root and the
hierarchical allocation is asserted cap-for-cap equal to the flat one; at
10k nodes the multi-domain warm round must finish within 2x the flat warm
round (the DESIGN.md §12 acceptance bar).

Deep tiers (ISSUE 8) then time 4-level site → row → PDU → chassis trees
with binding caps at every level — up to 100k nodes, whose warm round
must land within 3x the same run's 10k hier-16 warm round — through both
the host incremental controller and the fused device-resident one.
``--smoke-1m`` builds (and coverage-validates) a million-node 4-level
tree and solves one sampled-PDU sub-tree round.

Run as a module to emit ``BENCH_hier_alloc.json``:

    PYTHONPATH=src python -m benchmarks.hier_alloc [--fast]
"""

from __future__ import annotations

import json
import time

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, PowerDomain, PowerTopology
from repro.cluster.controller import make_controller

#: acceptance bar: multi-domain round time vs the flat grouped round
MAX_RATIO_VS_FLAT = 2.0

#: rack headroom as a fraction of the rack's even budget share
RACK_HEADROOM_FRAC = 0.6

#: acceptance bar (ISSUE 8): the 100k-node 4-level warm round must land
#: within this factor of the 10k hier-16 warm round — the larger of the
#: same run's measurement and the committed anchor below, so an
#: unusually quick 10k round on a fast machine doesn't turn a 10x node
#: scale-up into a flaky failure
DEEP_MAX_RATIO_VS_10K = 3.0

#: committed BENCH_hier_alloc.json 10k hier-16 warm round (seconds) at
#: the time the deep tiers landed; floors the ratio bar's denominator
DEEP_ANCHOR_10K_WARM_S = 0.1543

#: deep-tree per-level headroom fractions (level 1 = rows, then PDUs,
#: then leaf chassis) of each domain's node-proportional budget share —
#: strictly tightening down the tree, so every level genuinely binds
DEEP_LEVEL_FRACS = (0.9, 0.75, 0.6)

#: deep bench tiers: (n_nodes, fanouts) — 4-level site → row → PDU →
#: chassis trees; the 100k tier is the ISSUE 8 scale target
DEEP_TIERS = [(1000, (2, 2, 2)), (100_000, (4, 5, 5))]


def _sim(system, apps, surfs, n: int, topology=None) -> ClusterSim:
    return ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0,
        initial_caps=(150.0, 150.0), topology=topology,
    )


def _budget(n: int) -> float:
    return float(min(2.0 * n, 8000.0))


def _topology(system, apps, surfs, n: int, n_racks: int, budget: float):
    """Site → rack tree with *per-rack* binding caps: each rack gets its
    own committed draw + 60% of its even budget share, so every rack's
    cap genuinely binds (fan-out 1 keeps an unconstrained root — the
    parity anchor)."""
    if n_racks == 1:
        return PowerTopology.single_root(n, cap=1e18)
    probe = _sim(
        system, apps, surfs, n,
        topology=PowerTopology.uniform_racks(n, n_racks, rack_cap=1e15),
    )
    _, committed, _ = probe.domain_headroom(0)
    rack_extra = RACK_HEADROOM_FRAC * budget / n_racks
    racks = tuple(
        PowerDomain(
            name=probe.topology.domains[i].name,
            cap=float(committed[i]) + rack_extra,
            nodes=probe.topology.domains[i].nodes,
        )
        for i in probe.topology.leaf_ids
    )
    return PowerTopology(PowerDomain(name="site", cap=1e18, children=racks))


def _node_counts(dom, index, out) -> int:
    i = index[dom.name]
    if dom.children:
        out[i] = sum(_node_counts(c, index, out) for c in dom.children)
    else:
        out[i] = sum(hi - lo for lo, hi in dom.nodes)
    return out[i]


def _deep_topology(system, apps, surfs, n: int, fanouts, budget: float):
    """Arbitrary-depth site → row → PDU → chassis tree with binding caps
    at *every* level: each domain gets its committed draw plus a
    per-level fraction of its node-proportional budget share, the
    fractions tightening toward the leaves (root stays unconstrained —
    the cluster budget is the binding root signal)."""
    probe = _sim(
        system, apps, surfs, n,
        topology=PowerTopology.uniform_tree(
            n, fanouts, [1e15] * (len(fanouts) + 1)
        ),
    )
    _, committed, _ = probe.domain_headroom(0)
    index = probe.topology.index
    counts: dict[int, int] = {}
    _node_counts(probe.topology.domains[0], index, counts)

    def recap(dom, depth):
        i = index[dom.name]
        if depth == 0:
            cap = 1e18
        else:
            frac = DEEP_LEVEL_FRACS[min(depth - 1, len(DEEP_LEVEL_FRACS) - 1)]
            cap = float(committed[i]) + frac * budget * counts[i] / n
        return PowerDomain(
            name=dom.name,
            cap=cap,
            nodes=dom.nodes,
            children=tuple(recap(c, depth + 1) for c in dom.children),
        )

    return PowerTopology(recap(probe.topology.domains[0], 0), n_nodes=n)


def _timed_round(sim, ctrl, budget: float) -> tuple[float, object]:
    t0 = time.perf_counter()
    res = sim.run_round(ctrl, budget=budget)
    return time.perf_counter() - t0, res


def _max_overdraw(sim) -> float:
    if not sim.last_domain_draw:
        return 0.0
    return max(
        0.0,
        max(
            sim.last_domain_draw[k] - sim.last_domain_caps[k]
            for k in sim.last_domain_draw
        ),
    )


def run(lines: list[str], *, fast: bool = False, results: list | None = None):
    system, apps, surfs = get_suite("system1-a100")
    tiers = [1000] if fast else [1000, 10000]
    fanouts = [1, 4, 16]
    warm_10k_hier16 = None
    for n in tiers:
        budget = _budget(n)

        # flat grouped reference (no topology): cold + warm round
        sim_f = _sim(system, apps, surfs, n)
        ctrl_f = make_controller("ecoshift", system)
        t_flat_cold, res_flat = _timed_round(sim_f, ctrl_f, budget)
        t_flat_warm, _ = _timed_round(sim_f, ctrl_f, budget)

        tier = {
            "n_nodes": n,
            "budget_w": budget,
            "flat_round_s": {"cold": t_flat_cold, "warm": t_flat_warm},
            "fanouts": [],
        }
        for n_racks in fanouts:
            topo = _topology(system, apps, surfs, n, n_racks, budget)

            sim_h = _sim(system, apps, surfs, n, topology=topo)
            ctrl_h = make_controller("ecoshift_hier", system)
            t_cold, res_h = _timed_round(sim_h, ctrl_h, budget)
            hier_over = _max_overdraw(sim_h)
            t_warm, _ = _timed_round(sim_h, ctrl_h, budget)

            if n_racks == 1:
                # single-root degenerate topology == flat, cap for cap
                assert dict(res_h.allocation.caps) == dict(
                    res_flat.allocation.caps
                ), "single-root hierarchical diverged from flat grouped"

            # what a flat allocator does to the same rack caps
            sim_v = _sim(system, apps, surfs, n, topology=topo)
            sim_v.run_round(make_controller("ecoshift", system), budget=budget)
            flat_over = _max_overdraw(sim_v)

            ratio = t_warm / t_flat_warm
            if n == 10000 and n_racks == 16:
                warm_10k_hier16 = t_warm
            if n >= 10000 and n_racks > 1:
                assert ratio <= MAX_RATIO_VS_FLAT, (
                    f"hier round at n={n}, {n_racks} racks took "
                    f"{ratio:.2f}x the flat round (bar {MAX_RATIO_VS_FLAT}x)"
                )
            entry = {
                "n_racks": n_racks,
                "hier_round_s": {"cold": t_cold, "warm": t_warm},
                "ratio_warm_vs_flat": ratio,
                "hier_avg_improvement": res_h.avg_improvement,
                "flat_avg_improvement": res_flat.avg_improvement,
                "hier_max_overdraw_w": hier_over,
                "flat_max_overdraw_w": flat_over,
            }
            assert hier_over <= 1e-6, "hierarchical path overdrew a domain"
            tier["fanouts"].append(entry)
            lines.append(
                csv_line(
                    f"hier_alloc.n{n}.racks{n_racks}",
                    t_warm * 1e6,
                    f"hier_warm_s={t_warm:.4f};flat_warm_s={t_flat_warm:.4f};"
                    f"ratio={ratio:.2f}x;"
                    f"hier_imp={res_h.avg_improvement * 100:.2f}%;"
                    f"flat_imp={res_flat.avg_improvement * 100:.2f}%;"
                    f"flat_overdraw_w={flat_over:.0f};"
                    f"hier_overdraw_w={hier_over:.0f}",
                )
            )
        if results is not None:
            results.append(tier)

    # deep (>= 4-level) tiers: site -> row -> PDU -> chassis trees with
    # binding caps at every level (ISSUE 8).  The 100k tier is the scale
    # target: its warm round must land within DEEP_MAX_RATIO_VS_10K x the
    # same run's 10k hier-16 warm round.
    deep_tiers = DEEP_TIERS[:1] if fast else DEEP_TIERS
    for n, fanouts_t in deep_tiers:
        budget = _budget(n)
        topo = _deep_topology(system, apps, surfs, n, fanouts_t, budget)

        sim_d = _sim(system, apps, surfs, n, topology=topo)
        ctrl_d = make_controller("ecoshift_hier", system)
        t_cold, res_d = _timed_round(sim_d, ctrl_d, budget)
        over = _max_overdraw(sim_d)
        assert over <= 1e-6, "deep hierarchical path overdrew a domain"
        t_warm, _ = _timed_round(sim_d, ctrl_d, budget)
        assert _max_overdraw(sim_d) <= 1e-6, (
            "deep hierarchical warm round overdrew a domain"
        )

        # fused (device-resident) controller on a fresh identical sim:
        # round 1 falls back (structure build), round 2 compiles, round 3
        # is the steady-state warm round the envelope bar measures.
        sim_u = _sim(system, apps, surfs, n, topology=topo)
        ctrl_u = make_controller("ecoshift_hier", system, fused=True)
        _, res_u = _timed_round(sim_u, ctrl_u, budget)
        assert dict(res_u.allocation.caps) == dict(res_d.allocation.caps), (
            "fused deep cold round diverged from the host controller"
        )
        sim_u.run_round(ctrl_u, budget=budget)
        t_fused_warm, _ = _timed_round(sim_u, ctrl_u, budget)
        assert _max_overdraw(sim_u) <= 1e-6, (
            "fused deep warm round overdrew a domain"
        )

        if n >= 100_000:
            anchor = max(warm_10k_hier16 or 0.0, DEEP_ANCHOR_10K_WARM_S)
            bar = DEEP_MAX_RATIO_VS_10K * anchor
            best = min(t_warm, t_fused_warm)
            assert best <= bar, (
                f"deep {n}-node warm round took {best:.3f}s, above "
                f"{DEEP_MAX_RATIO_VS_10K}x the 10k hier-16 warm anchor "
                f"({anchor:.3f}s -> bar {bar:.3f}s)"
            )

        depth = len(fanouts_t) + 1
        entry = {
            "n_nodes": n,
            "budget_w": budget,
            "fanouts_tree": list(fanouts_t),
            "depth": depth,
            "n_domains": len(topo.domains),
            "hier_round_s": {"cold": t_cold, "warm": t_warm},
            "fused_round_s": {"warm": t_fused_warm},
            "max_overdraw_w": over,
            "avg_improvement": res_d.avg_improvement,
        }
        if results is not None:
            results.append(entry)
        lines.append(
            csv_line(
                f"hier_alloc.deep.n{n}.d{depth}",
                t_warm * 1e6,
                f"warm_s={t_warm:.4f};fused_warm_s={t_fused_warm:.4f};"
                f"cold_s={t_cold:.4f};domains={len(topo.domains)};"
                f"imp={res_d.avg_improvement * 100:.2f}%;"
                f"overdraw_w={over:.0f}",
            )
        )


def smoke_1m(lines: list[str]) -> None:
    """1M-node smoke: build (and coverage-validate) a 4-level million-node
    tree, then run one allocation round on a sampled PDU sub-tree (~10k
    nodes) shifted to the origin — proof the builder and the deep solver
    hold up at the million-node topology scale without paying a full
    million-node simulation."""
    system, apps, surfs = get_suite("system1-a100")
    n = 1_000_000
    t0 = time.perf_counter()
    topo = PowerTopology.uniform_tree(
        n, (10, 10, 10), [1e18, 1e15, 1e15, 1e15]
    )
    t_build = time.perf_counter() - t0
    assert len(topo.domains) == 1 + 10 + 100 + 1000

    # sample one PDU (10 chassis, n/100 nodes); shift node ids to 0
    pdu = topo.domains[0].children[0].children[0]
    off = min(lo for leaf in pdu.children for lo, _hi in leaf.nodes)
    n_sub = sum(hi - lo for leaf in pdu.children for lo, hi in leaf.nodes)

    def shift(dom):
        return PowerDomain(
            name=dom.name,
            cap=dom.cap,
            nodes=tuple((lo - off, hi - off) for lo, hi in dom.nodes),
            children=tuple(shift(c) for c in dom.children),
        )

    sub = PowerTopology(
        PowerDomain(name="site", cap=1e18, children=(shift(pdu),)),
        n_nodes=n_sub,
    )
    budget = _budget(n_sub)
    sim = _sim(system, apps, surfs, n_sub, topology=sub)
    ctrl = make_controller("ecoshift_hier", system)
    t_round, res = _timed_round(sim, ctrl, budget)
    assert _max_overdraw(sim) <= 1e-6, "1M-smoke sub-tree overdrew a domain"
    lines.append(
        csv_line(
            "hier_alloc.smoke1m",
            t_round * 1e6,
            f"build_s={t_build:.4f};round_s={t_round:.4f};"
            f"sampled_nodes={n_sub};"
            f"imp={res.avg_improvement * 100:.2f}%",
        )
    )


#: regression-guard tolerance vs a committed reference (mirrors
#: benchmarks.cluster_scaling; generous for shared-runner noise)
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Warm hierarchical-round regressions vs a committed reference run.

    Compares (n_nodes, n_racks) pairs present in both runs; a fresh warm
    round above ``CHECK_FACTOR x ref + CHECK_SLACK_S`` regresses.
    """
    ref_by_key = {
        (t["n_nodes"], f["n_racks"]): f
        for t in reference.get("tiers", [])
        for f in t.get("fanouts", [])
    }
    ref_deep = {
        (t["n_nodes"], tuple(t["fanouts_tree"])): t
        for t in reference.get("tiers", [])
        if "fanouts_tree" in t
    }
    problems = []
    for tier in results:
        if "fanouts_tree" in tier:
            ref = ref_deep.get((tier["n_nodes"], tuple(tier["fanouts_tree"])))
            if ref is None:
                continue
            for key, fresh, base in (
                ("hier", tier["hier_round_s"]["warm"],
                 ref["hier_round_s"]["warm"]),
                ("fused", tier["fused_round_s"]["warm"],
                 ref["fused_round_s"]["warm"]),
            ):
                budget = CHECK_FACTOR * base + CHECK_SLACK_S
                if fresh > budget:
                    problems.append(
                        f"deep n={tier['n_nodes']}, "
                        f"fanouts={tier['fanouts_tree']}: warm {key} round "
                        f"{fresh:.3f}s exceeds {budget:.3f}s "
                        f"({CHECK_FACTOR}x ref {base:.3f}s + {CHECK_SLACK_S}s)"
                    )
            continue
        for f in tier["fanouts"]:
            ref = ref_by_key.get((tier["n_nodes"], f["n_racks"]))
            if ref is None:
                continue
            fresh = f["hier_round_s"]["warm"]
            budget = CHECK_FACTOR * ref["hier_round_s"]["warm"] + CHECK_SLACK_S
            if fresh > budget:
                problems.append(
                    f"n={tier['n_nodes']}, racks={f['n_racks']}: warm hier "
                    f"round {fresh:.3f}s exceeds {budget:.3f}s "
                    f"({CHECK_FACTOR}x ref {ref['hier_round_s']['warm']:.3f}s "
                    f"+ {CHECK_SLACK_S}s)"
                )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the 10k tier")
    ap.add_argument(
        "--out", default="BENCH_hier_alloc.json", help="JSON output path"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh warm hier-round times against a committed "
        "reference (loaded before --out overwrites it); exit 1 on regression",
    )
    ap.add_argument(
        "--smoke-1m",
        action="store_true",
        help="run only the 1M-node topology smoke (build + sampled "
        "sub-tree round); no JSON is written",
    )
    args = ap.parse_args()

    if args.smoke_1m:
        smoke_lines = ["name,us_per_call,derived"]
        smoke_1m(smoke_lines)
        print("\n".join(smoke_lines))
        return

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results)
    payload = {
        "benchmark": "hier_alloc",
        "fast": args.fast,
        "elapsed_s": time.time() - t0,
        "tiers": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
