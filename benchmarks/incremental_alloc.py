"""Delta-driven incremental allocation benchmark (DESIGN.md §13).

Measures the *steady-state* cost of a redistribution round — the case the
production control loop lives in: the cluster barely changed since the
last round, so the round should cost O(churn), not O(cluster).

For n ∈ {1k, 10k} nodes, flat and 16-rack hierarchical, and per-round
churn ∈ {0%, 1%, 10%}, a scenario of warm rounds runs twice through
identical sims:

 * **incremental** — the default controller: batch-delta grouping, warm
   content-keyed curve/pick/plan/frontier caches, the frontier
   aggregation tree, batched dirty-leaf DPs and whole-solution reuse;
 * **from_scratch** — ``incremental=False``: the PR-4-shaped control flow
   that re-collapses and re-solves every round (it still shares this PR's
   faster (max,+) primitives and engine-side delta caches, so it is a
   *conservative* baseline — the true PR-4 code is slower; see
   ``pr4_reference`` in the committed JSON, measured from a PR-4 git
   worktree on the same machine with ``--pr4-ref``).

Per-round **allocations are asserted bit-for-bit equal** between the two
controllers before any timing is trusted.

Churn is a representative event mix per round (on ``churn * n`` nodes):
60% straggler slowdown toggles, 25% phase changes, 10% failures, 5%
arrivals (arrivals replace failed capacity so the cluster stays in steady
state).  Stragglers are digest-invariant (free for the warm caches),
phase changes move nodes between behaviour classes, failures/arrivals
shift class multiplicities and membership.

With ``--fused`` the bench adds a **warm re-solve** case per tier: event-
free rounds under monotone budget drift (the production steady state —
the reclaimed pool moves with measured draws, so the whole-solution
allocation cache misses every round while every content-keyed structure
stays warm).  Three controllers run through identical sims — the
device-resident fused round (DESIGN.md §14), the PR-5 host incremental
path, and the from-scratch baseline — with per-round bit-for-bit
allocation parity asserted across all three, and the allocate-phase
medians plus the fused device/host split recorded.  Timed fused rounds
are bracketed by explicit ``jax.block_until_ready`` syncs on the resident
banks so no async device work leaks across round boundaries.

``--fused`` also measures **fused-under-churn** cases at churn {1%, 10%}
(DESIGN.md §17): the same MIX event storm as the host churn cases, with
structure-changing rounds served on device by capacity-slack row patches
and device-side compaction.  **Zero post-warmup host fallbacks** is
asserted at every tier; churn warmup is longer (CHURN_WARMUP_ROUNDS)
because the first storm rounds pay the *bounded* one-time costs of the
slack scheme — capacity-tier growth recompiles and new scatter-batch
shape tiers — after which the sticky pow2 pads absorb further churn.
At the 10k hier-16 acceptance tier the 10%-churn fused round must beat
the from-scratch baseline and stay within the same ~0.8x-of-host ratio
it holds event-free.  (That ratio *holding* is the honest headline:
pre-PR-9 any structure change forced a whole host-fallback round, so
churn rounds were strictly host-speed; now the idle-machine medians are
~52 ms fused vs ~43 ms host incremental vs ~77 ms from-scratch — 1.4x
from-scratch, ~0.8x host, matching the event-free ratio.  There is no 3x of
from-scratch headroom in the problem off-accelerator, since ~80% of a
churn round is grouping/curve/assembly host work shared by every
solver, and on CPU *interpret* the device segment is itself emulated —
the fused round's relative position is expected to flip on a real
accelerator, which is exactly what the zero-fallback property makes
possible to measure.)

Run as a module to emit ``BENCH_incremental_alloc.json``:

    PYTHONPATH=src python -m benchmarks.incremental_alloc [--fast] [--fused]

``--check BENCH_incremental_alloc.json`` guards against regressions like
the other cluster benches (fresh medians must stay within a generous
factor of the committed reference).  ``--pr4-ref SECONDS`` records an
externally measured PR-4 warm-round time (git worktree at the PR-4
commit, same machine/scenario) into the JSON for the vs-PR-4 speedups.
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, scenario as sc
from repro.cluster.controller import make_controller

#: acceptance bar (ISSUE 5): the steady-state (no-event) warm round at the
#: top tier must be >= this factor faster than the from-scratch round
MIN_STEADY_SPEEDUP = 5.0

#: churn event mix: fractions of the per-round churn budget
MIX = (("straggler", 0.60), ("phase", 0.25), ("failure", 0.10), ("arrival", 0.05))

N_ROUNDS = 10
WARMUP_ROUNDS = 2

#: fused-under-churn cases run longer and discard more warmup: the first
#: storm rounds pay the bounded one-time compiles of the slack scheme
#: (capacity-tier growth re-jits, new pow2 scatter-batch shapes); sticky
#: pads make these converge, after which churn rounds are steady
CHURN_N_ROUNDS = 12
CHURN_WARMUP_ROUNDS = 4


def _budget(n: int) -> float:
    return float(min(2.0 * n, 8000.0))


def _sim(system, apps, surfs, n: int, topology=None) -> ClusterSim:
    return ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=0,
        initial_caps=(150.0, 150.0), topology=topology,
    )


def _topology(system, apps, surfs, n: int, n_racks: int, budget: float):
    """Binding site -> rack tree (committed draw + 60% of the even budget
    share per rack), mirroring benchmarks.hier_alloc."""
    from benchmarks.hier_alloc import _topology as hier_topology

    return hier_topology(system, apps, surfs, n, n_racks, budget)


def _churn_events(sim, rng, r: int, k: int, recv_apps, app_by_name, racks):
    """One round's churn: k nodes hit by the MIX of event types."""
    alive = sim.table.node_ids[sim.table.alive]
    victims = rng.choice(alive, size=min(k, len(alive)), replace=False)
    counts = [max(0, int(round(k * frac))) for _, frac in MIX]
    ev: list = []
    i = 0
    for (kind, _), cnt in zip(MIX, counts):
        for _ in range(cnt):
            if i >= len(victims):
                break
            v = int(victims[i])
            i += 1
            if kind == "straggler":
                ev.append(sc.StragglerOnset(
                    round=r, node_id=v,
                    slowdown=float(rng.choice([1.0, 1.3, 1.7])),
                ))
            elif kind == "phase":
                ev.append(sc.PhaseChange(
                    round=r, node_id=v,
                    surface_id=recv_apps[int(rng.integers(len(recv_apps)))],
                ))
            elif kind == "failure":
                ev.append(sc.NodeFailure(round=r, node_ids=(v,)))
                if racks is not None:
                    # steady state: an arrival replaces the failed node
                    app = app_by_name[
                        recv_apps[int(rng.integers(len(recv_apps)))]
                    ]
                    ev.append(sc.NodeArrival(
                        round=r, app=app,
                        domain=racks[v % len(racks)], caps=(150.0, 150.0),
                    ))
            else:  # arrival
                app = app_by_name[recv_apps[int(rng.integers(len(recv_apps)))]]
                ev.append(sc.NodeArrival(
                    round=r, app=app,
                    domain=racks[v % len(racks)] if racks is not None else None,
                    caps=(150.0, 150.0),
                ))
    return ev


def _measure_case(
    system, apps, surfs, n: int, churn: float, *, topology, policy: str,
) -> dict:
    """Run the incremental and from-scratch controllers through identical
    churn scenarios; assert bit-for-bit allocation parity every round."""
    budget = _budget(n)
    rng = np.random.default_rng(11)
    pair = []
    for inc in (True, False):
        sim = _sim(system, apps, surfs, n, topology=topology)
        ctrl = make_controller(policy, system, incremental=inc)
        pair.append((sim, ctrl))
    sim0 = pair[0][0]
    _, recv, _ = sim0.partition_rows()
    recv_apps = sorted(
        {sim0.table.strings[g] for g in sim0.table.base_gid[recv]}
    )
    app_by_name = {a.name: a for a in apps}
    racks = (
        [d.name for d in topology.domains if d.is_leaf]
        if topology is not None
        else None
    )
    times: dict[bool, list[float]] = {True: [], False: []}
    for r in range(N_ROUNDS):
        events = []
        if churn > 0 and r >= 1:
            events = _churn_events(
                sim0, rng, r, int(n * churn), recv_apps, app_by_name, racks
            )
        results = []
        for sim, ctrl in pair:
            if events:
                touched = sim.apply_events(events)
                ctrl.invalidate(touched)
            t0 = time.perf_counter()
            res = sim.run_round(ctrl, budget=budget, round_index=r)
            times[ctrl.incremental].append(time.perf_counter() - t0)
            results.append(res)
        a, b = results
        assert dict(a.allocation.caps) == dict(b.allocation.caps), (
            f"{policy} n={n} churn={churn}: incremental diverged from "
            f"from-scratch at round {r}"
        )
        assert a.allocation.spent == b.allocation.spent
    inc_med = float(np.median(times[True][WARMUP_ROUNDS:]))
    base_med = float(np.median(times[False][WARMUP_ROUNDS:]))
    return {
        "churn": churn,
        "incremental_round_s": inc_med,
        "from_scratch_round_s": base_med,
        "speedup_vs_from_scratch": base_med / inc_med,
        "incremental_rounds_s": [round(t, 5) for t in times[True]],
    }


def _fused_sync(ctrl) -> None:
    """Explicit device sync point: drain any asynchronously dispatched
    device work (donated delta patches, pipeline readback) so a timed
    round can never leak work into its neighbour's measurement."""
    fstate = getattr(ctrl, "_fused_state", None)
    if fstate is None:
        return
    import jax

    for buf in (fstate.kb_dev, fstate.vb_dev):
        if buf is not None:
            jax.block_until_ready(buf)


def _measure_fused_case(
    system, apps, surfs, n: int, *, topology, policy: str,
) -> dict:
    """Warm re-solve under monotone budget drift: fused vs host
    incremental vs from-scratch, parity-certified every round.

    Event-free rounds, but the budget moves -25 W/round so the
    whole-solution allocation cache misses and every round pays a real
    solve — the cost this PR moved on-device.  The allocate-phase median
    isolates the control-loop solve from the (shared, unchanged)
    measurement pipeline.
    """
    budget = _budget(n)
    variants = (
        ("fused", dict(fused=True)),
        ("host", {}),
        ("from_scratch", dict(incremental=False)),
    )
    alloc_ts: dict[str, list[float]] = {k: [] for k, _ in variants}
    round_ts: dict[str, list[float]] = {k: [] for k, _ in variants}
    device_ts: list[float] = []
    allocs: dict[str, list] = {k: [] for k, _ in variants}
    fused_ctrl = None
    for label, kw in variants:
        sim = _sim(system, apps, surfs, n, topology=topology)
        ctrl = make_controller(policy, system, **kw)
        if label == "fused":
            fused_ctrl = ctrl
        for r in range(N_ROUNDS):
            b = budget - 25.0 * r
            if label == "fused":
                _fused_sync(ctrl)
            t0 = time.perf_counter()
            res = sim.run_round(ctrl, budget=b, round_index=r)
            if label == "fused":
                _fused_sync(ctrl)
            round_ts[label].append(time.perf_counter() - t0)
            alloc_ts[label].append(float(sim.last_round_profile["allocate_s"]))
            if label == "fused":
                device_ts.append(
                    float(sim.last_round_profile["alloc_device_s"])
                )
            allocs[label].append(
                (dict(res.allocation.caps), res.allocation.spent)
            )
    for other in ("host", "from_scratch"):
        assert allocs["fused"] == allocs[other], (
            f"{policy} n={n} warm re-solve: fused diverged from {other}"
        )
    med = lambda ts: float(np.median(ts[WARMUP_ROUNDS:]))  # noqa: E731
    stats = fused_ctrl.fused_stats()
    case = {
        "scenario": "event_free_budget_drift",
        "fused_alloc_s": med(alloc_ts["fused"]),
        "host_alloc_s": med(alloc_ts["host"]),
        "from_scratch_alloc_s": med(alloc_ts["from_scratch"]),
        "fused_device_s": med(device_ts),
        "fused_round_s": med(round_ts["fused"]),
        "host_round_s": med(round_ts["host"]),
        "fused_stats": {
            "rounds": stats.rounds,
            "fallbacks": stats.fallbacks,
            "rebuilds": stats.rebuilds,
            "compactions": stats.compactions,
            "row_uploads": stats.row_uploads,
            "short_circuits": stats.short_circuits,
        },
    }
    case["speedup_fused_vs_from_scratch"] = (
        case["from_scratch_alloc_s"] / case["fused_alloc_s"]
    )
    case["speedup_fused_vs_host"] = (
        case["host_alloc_s"] / case["fused_alloc_s"]
    )
    return case


def _measure_fused_churn_case(
    system, apps, surfs, n: int, churn: float, *, topology, policy: str,
) -> dict:
    """Fused round under *structure churn* (DESIGN.md §17): the same MIX
    event storm as the host churn cases, three controllers (fused / host
    incremental / from-scratch) through identical sims, per-round
    bit-for-bit parity.  The fused path must serve every structure-
    changing round on device — ``post_warmup_fallbacks`` proves it."""
    budget = _budget(n)
    rng = np.random.default_rng(23)
    variants = (
        ("fused", dict(fused=True)),
        ("host", {}),
        ("from_scratch", dict(incremental=False)),
    )
    trips = []
    for label, kw in variants:
        sim = _sim(system, apps, surfs, n, topology=topology)
        ctrl = make_controller(policy, system, **kw)
        trips.append((label, sim, ctrl))
    sim0, fused_ctrl = trips[0][1], trips[0][2]
    _, recv, _ = sim0.partition_rows()
    recv_apps = sorted(
        {sim0.table.strings[g] for g in sim0.table.base_gid[recv]}
    )
    app_by_name = {a.name: a for a in apps}
    racks = (
        [d.name for d in topology.domains if d.is_leaf]
        if topology is not None
        else None
    )
    alloc_ts: dict[str, list[float]] = {label: [] for label, _, _ in trips}
    device_ts: list[float] = []
    k = int(n * churn)
    warmup_fallbacks = 0
    for r in range(CHURN_N_ROUNDS):
        b = budget - 25.0 * r  # drift: no whole-solution cache hits
        events = (
            _churn_events(sim0, rng, r, k, recv_apps, app_by_name, racks)
            if churn > 0 and r >= 1 else []
        )
        results = []
        for label, sim, ctrl in trips:
            if events:
                touched = sim.apply_events(events)
                ctrl.invalidate(touched)
            if label == "fused":
                _fused_sync(ctrl)
            res = sim.run_round(ctrl, budget=b, round_index=r)
            if label == "fused":
                _fused_sync(ctrl)
            alloc_ts[label].append(float(sim.last_round_profile["allocate_s"]))
            if label == "fused":
                device_ts.append(
                    float(sim.last_round_profile["alloc_device_s"])
                )
            results.append((dict(res.allocation.caps), res.allocation.spent))
        for (label, _, _), got in zip(trips[1:], results[1:]):
            assert results[0] == got, (
                f"{policy} n={n} fused churn={churn}: fused diverged from "
                f"{label} at round {r}"
            )
        if r == CHURN_WARMUP_ROUNDS - 1:
            warmup_fallbacks = fused_ctrl.fused_stats().fallbacks
    med = lambda ts: float(np.median(ts[CHURN_WARMUP_ROUNDS:]))  # noqa: E731
    stats = fused_ctrl.fused_stats()
    case = {
        "scenario": "mixed_churn_budget_drift",
        "churn": churn,
        "fused_alloc_s": med(alloc_ts["fused"]),
        "host_alloc_s": med(alloc_ts["host"]),
        "from_scratch_alloc_s": med(alloc_ts["from_scratch"]),
        "fused_device_s": med(device_ts),
        "fused_stats": {
            "rounds": stats.rounds,
            "fallbacks": stats.fallbacks,
            "post_warmup_fallbacks": stats.fallbacks - warmup_fallbacks,
            "rebuilds": stats.rebuilds,
            "compactions": stats.compactions,
            "row_uploads": stats.row_uploads,
            "short_circuits": stats.short_circuits,
            "slack_utilization": round(stats.slack_utilization, 4),
        },
    }
    case["speedup_fused_vs_from_scratch"] = (
        case["from_scratch_alloc_s"] / case["fused_alloc_s"]
    )
    case["speedup_fused_vs_host"] = (
        case["host_alloc_s"] / case["fused_alloc_s"]
    )
    return case


def run(
    lines: list[str],
    *,
    fast: bool = False,
    results: list | None = None,
    fused: bool = False,
):
    system, apps, surfs = get_suite("system1-a100")
    tiers = [1000] if fast else [1000, 10000]
    churns = [0.0, 0.01, 0.10]
    for n in tiers:
        budget = _budget(n)
        for mode in ("flat", "hier16"):
            if mode == "flat":
                topo, policy = None, "ecoshift"
            else:
                topo = _topology(system, apps, surfs, n, 16, budget)
                policy = "ecoshift_hier"
            entry = {"n_nodes": n, "mode": mode, "budget_w": budget,
                     "churn_levels": []}
            for churn in churns:
                case = _measure_case(
                    system, apps, surfs, n, churn,
                    topology=topo, policy=policy,
                )
                entry["churn_levels"].append(case)
                lines.append(csv_line(
                    f"incremental_alloc.n{n}.{mode}.churn{int(churn * 100)}",
                    case["incremental_round_s"] * 1e6,
                    f"incr_s={case['incremental_round_s']:.4f};"
                    f"scratch_s={case['from_scratch_round_s']:.4f};"
                    f"speedup={case['speedup_vs_from_scratch']:.1f}x",
                ))
            steady = entry["churn_levels"][0]
            if n >= (1000 if fast else 10000):
                assert steady["speedup_vs_from_scratch"] >= (
                    2.0 if fast else MIN_STEADY_SPEEDUP
                ), (
                    f"{mode} n={n}: steady-state incremental round only "
                    f"{steady['speedup_vs_from_scratch']:.1f}x faster than "
                    f"from-scratch"
                )
            if fused:
                case = _measure_fused_case(
                    system, apps, surfs, n, topology=topo, policy=policy,
                )
                entry["warm_resolve"] = case
                lines.append(csv_line(
                    f"incremental_alloc.n{n}.{mode}.warm_resolve",
                    case["fused_alloc_s"] * 1e6,
                    f"fused_s={case['fused_alloc_s']:.4f};"
                    f"device_s={case['fused_device_s']:.4f};"
                    f"host_s={case['host_alloc_s']:.4f};"
                    f"scratch_s={case['from_scratch_alloc_s']:.4f};"
                    f"vs_scratch="
                    f"{case['speedup_fused_vs_from_scratch']:.1f}x",
                ))
                if n >= 10000 and mode == "hier16" and not fast:
                    # hard floor only (shared-runner noise: the committed
                    # JSON factor guard is the real regression fence)
                    assert case["speedup_fused_vs_from_scratch"] >= 2.0, (
                        f"{mode} n={n}: fused warm re-solve only "
                        f"{case['speedup_fused_vs_from_scratch']:.1f}x "
                        f"faster than the re-solving from-scratch path"
                    )
                    assert case["fused_stats"]["fallbacks"] == 0, (
                        f"{mode} n={n}: event-free warm re-solve fell "
                        f"back to host "
                        f"{case['fused_stats']['fallbacks']} times"
                    )
                entry["fused_churn"] = []
                for churn in (0.01, 0.10):
                    ccase = _measure_fused_churn_case(
                        system, apps, surfs, n, churn,
                        topology=topo, policy=policy,
                    )
                    ccase["vs_event_free_fused"] = (
                        ccase["fused_alloc_s"] / case["fused_alloc_s"]
                    )
                    entry["fused_churn"].append(ccase)
                    lines.append(csv_line(
                        f"incremental_alloc.n{n}.{mode}."
                        f"fused_churn{int(churn * 100)}",
                        ccase["fused_alloc_s"] * 1e6,
                        f"fused_s={ccase['fused_alloc_s']:.4f};"
                        f"device_s={ccase['fused_device_s']:.4f};"
                        f"scratch_s={ccase['from_scratch_alloc_s']:.4f};"
                        f"vs_scratch="
                        f"{ccase['speedup_fused_vs_from_scratch']:.1f}x;"
                        f"fallbacks={ccase['fused_stats']['fallbacks']}",
                    ))
                    # the tentpole bar (ISSUE 9): structure churn is a
                    # fused fast path — zero post-warmup host fallbacks
                    # at every tier, and at the acceptance tier (10k
                    # hier-16, 10% churn) the fused round must beat both
                    # host solvers.  Hard floors only: shared-runner
                    # noise and seed-dependent capacity-tier sizes move
                    # the ratios; the committed-JSON factor guard is the
                    # real regression fence.
                    assert (
                        ccase["fused_stats"]["post_warmup_fallbacks"] == 0
                    ), (
                        f"{mode} n={n} churn={churn}: structure-changing "
                        f"rounds fell back to host"
                    )
                    if (
                        n >= 10000 and mode == "hier16" and not fast
                        and churn >= 0.10
                    ):
                        # idle-machine medians: ~52 ms fused vs ~43 ms
                        # host incremental vs ~77 ms from-scratch, i.e.
                        # 1.4x from-scratch and 0.80x host — the same
                        # ~0.8x ratio fused holds event-free, so churn
                        # costs the fused path no relative ground (the
                        # point of this PR: pre-9 a structure change
                        # forced a whole host-fallback round).  Floors
                        # sit below the idle ratios because full-run
                        # medians swing with where the bounded jit
                        # compiles (new scatter-batch tiers) land in
                        # the window.
                        assert (
                            ccase["speedup_fused_vs_from_scratch"] >= 1.0
                        ), (
                            f"{mode} n={n} churn={churn}: fused churn "
                            f"round "
                            f"{ccase['speedup_fused_vs_from_scratch']:.2f}x"
                            f" from-scratch (floor 1.0x)"
                        )
                        assert ccase["speedup_fused_vs_host"] >= 0.6, (
                            f"{mode} n={n} churn={churn}: fused churn "
                            f"round "
                            f"{ccase['speedup_fused_vs_host']:.2f}x the "
                            f"host incremental path (floor 0.6x — "
                            f"event-free fused already sits at ~0.8x "
                            f"host on CPU interpret)"
                        )
            if results is not None:
                results.append(entry)


#: regression-guard tolerance vs a committed reference (benchmarks.*
#: convention: generous for shared-runner noise)
CHECK_FACTOR = 5.0
CHECK_SLACK_S = 0.25


def check_against(reference: dict, results: list) -> list[str]:
    """Fresh incremental medians vs the committed reference run."""
    ref_by_key = {
        (t["n_nodes"], t["mode"], c["churn"]): c
        for t in reference.get("tiers", [])
        for c in t["churn_levels"]
    }
    problems = []
    for tier in results:
        for c in tier["churn_levels"]:
            ref = ref_by_key.get((tier["n_nodes"], tier["mode"], c["churn"]))
            if ref is None:
                continue
            fresh = c["incremental_round_s"]
            allowed = CHECK_FACTOR * ref["incremental_round_s"] + CHECK_SLACK_S
            if fresh > allowed:
                problems.append(
                    f"n={tier['n_nodes']} {tier['mode']} churn={c['churn']}: "
                    f"incremental round {fresh:.3f}s exceeds {allowed:.3f}s "
                    f"({CHECK_FACTOR}x ref {ref['incremental_round_s']:.3f}s "
                    f"+ {CHECK_SLACK_S}s)"
                )
    fused_ref = {
        (t["n_nodes"], t["mode"]): t["warm_resolve"]
        for t in reference.get("tiers", [])
        if "warm_resolve" in t
    }
    for tier in results:
        case = tier.get("warm_resolve")
        ref = fused_ref.get((tier["n_nodes"], tier["mode"]))
        if case is None or ref is None:
            continue
        for key in ("fused_alloc_s", "fused_device_s"):
            fresh = case[key]
            allowed = CHECK_FACTOR * ref[key] + CHECK_SLACK_S
            if fresh > allowed:
                problems.append(
                    f"n={tier['n_nodes']} {tier['mode']} warm_resolve: "
                    f"{key} {fresh:.3f}s exceeds {allowed:.3f}s "
                    f"({CHECK_FACTOR}x ref {ref[key]:.3f}s "
                    f"+ {CHECK_SLACK_S}s)"
                )
    churn_ref = {
        (t["n_nodes"], t["mode"], c["churn"]): c
        for t in reference.get("tiers", [])
        for c in t.get("fused_churn", [])
    }
    for tier in results:
        for c in tier.get("fused_churn", []):
            ref = churn_ref.get((tier["n_nodes"], tier["mode"], c["churn"]))
            if ref is None:
                continue
            fresh = c["fused_alloc_s"]
            allowed = CHECK_FACTOR * ref["fused_alloc_s"] + CHECK_SLACK_S
            if fresh > allowed:
                problems.append(
                    f"n={tier['n_nodes']} {tier['mode']} fused_churn="
                    f"{c['churn']}: fused_alloc_s {fresh:.3f}s exceeds "
                    f"{allowed:.3f}s ({CHECK_FACTOR}x ref "
                    f"{ref['fused_alloc_s']:.3f}s + {CHECK_SLACK_S}s)"
                )
    return problems


def main() -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the 10k tier")
    ap.add_argument(
        "--fused",
        action="store_true",
        help="also measure the device-resident fused warm re-solve per "
        "tier (fused vs host vs from-scratch, parity-certified)",
    )
    ap.add_argument(
        "--out", default="BENCH_incremental_alloc.json", help="JSON output"
    )
    ap.add_argument(
        "--check",
        default=None,
        metavar="REF_JSON",
        help="compare fresh incremental medians against a committed "
        "reference (loaded before --out overwrites it); exit 1 on regression",
    )
    ap.add_argument(
        "--pr4-ref",
        default=None,
        type=float,
        metavar="SECONDS",
        help="externally measured PR-4 warm-round time at the top hier tier "
        "(git worktree at the PR-4 commit, same machine) — recorded into "
        "the JSON so vs-PR-4 speedups are explicit",
    )
    args = ap.parse_args()

    reference = None
    if args.check:
        with open(args.check) as f:
            reference = json.load(f)

    lines: list[str] = ["name,us_per_call,derived"]
    results: list = []
    t0 = time.time()
    run(lines, fast=args.fast, results=results, fused=args.fused)
    payload = {
        "benchmark": "incremental_alloc",
        "fast": args.fast,
        "fused": args.fused,
        "elapsed_s": time.time() - t0,
        "churn_mix": dict(MIX),
        "tiers": results,
    }
    pr4 = args.pr4_ref
    if pr4 is None and reference is not None:
        pr4 = reference.get("pr4_reference", {}).get("warm_round_s")
    if pr4 is not None:
        payload["pr4_reference"] = {
            "warm_round_s": pr4,
            "note": "PR-4 code (git worktree at the PR-4 commit), same "
            "machine, 10k nodes / 16 racks, event-free warm round",
        }
        for t in results:
            if t["n_nodes"] >= 10000 and t["mode"] == "hier16":
                for c in t["churn_levels"]:
                    c["speedup_vs_pr4"] = pr4 / c["incremental_round_s"]
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print("\n".join(lines))
    print(f"# wrote {args.out} in {payload['elapsed_s']:.1f}s")

    if reference is not None:
        problems = check_against(reference, results)
        for p in problems:
            print(f"# REGRESSION: {p}", file=sys.stderr)
        if problems:
            sys.exit(1)
        print(f"# regression guard OK vs {args.check}")


if __name__ == "__main__":
    main()
