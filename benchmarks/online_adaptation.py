"""Online adaptation: cold-start allocation quality vs the oracle.

The closed-loop counterpart of fig10's static oracle gap (DESIGN.md §10):
a cold-start app arrives mid-scenario with *no pretrained surface*; the
``ecoshift_online`` controller serves it from the population prior, then
refreshes its surface from accumulated telemetry.  We replay the same
scenario under the oracle controller and report the arriving instance's
per-round improvement gap — which should shrink toward the static
(fully-profiled) oracle gap as telemetry accumulates — plus the
predictor's own error trace and refit/invalidation counters.

Budget variation across rounds provides natural exploration: different
budgets land the instance on different grid cells, enriching the
observation buffer the online phase fits from.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_context
from repro.cluster import ClusterSim, OnlinePredictor, OnlinePredictorConfig, Scenario
from repro.cluster.controller import make_controller
from repro.core import surfaces


def run(lines: list[str], fast: bool = False) -> None:
    ctx = get_context("system1-a100")
    system = ctx.system
    apps = surfaces.workload_group(ctx.apps, "mixed")
    known = [a for a in apps if a.name not in ctx.unseen]
    cold_apps = [
        a for a in apps if a.name in ctx.unseen and a.sclass in ("C", "G", "B")
    ][: 2 if fast else 4]

    n_nodes = 20 if fast else 30
    n_rounds = 10 if fast else 16
    arrival_round = 2
    budgets = tuple(700.0 + 350.0 * ((3 * r) % 5) for r in range(n_rounds))

    for cold in cold_apps:
        scen = Scenario(n_rounds=n_rounds, budget=budgets).with_arrival(
            arrival_round, cold
        )
        inst = f"{cold.name}#n{n_nodes}"

        pred = OnlinePredictor(ctx.allocator.predictor, OnlinePredictorConfig())
        # offline-known apps start from their offline-predicted surfaces;
        # only the arrival is cold.  Although the shared ctx predictor has
        # an embedding row for the arrival (get_context onboards every
        # held-out app), nothing served leaks it: the population prior
        # averages only *served* surfaces, and the first telemetry refit
        # replaces the row from scratch (seeded init).
        pred.seed_surfaces(
            {n: s for n, s in ctx.predicted.items() if n != cold.name}
        )
        ctrl = make_controller("ecoshift_online", system, predictor=pred)
        sim = ClusterSim.build(
            system, known, ctx.true_surfaces, n_nodes=n_nodes, seed=11
        )
        online = sim.run(scen, ctrl)

        sim_o = ClusterSim.build(
            system, known, ctx.true_surfaces, n_nodes=n_nodes, seed=11
        )
        oracle = sim_o.run(scen, "oracle")

        gap = oracle.improvements_of(inst) - online.improvements_of(inst)
        post = gap[arrival_round:]
        half = len(post) // 2
        early, late = float(np.mean(post[:half])), float(np.mean(post[half:]))
        lines.append(
            csv_line(
                f"online_adaptation.cold_start.{cold.name}",
                0.0,
                f"early_gap_pp={early * 100:.2f};late_gap_pp={late * 100:.2f};"
                f"refits={pred.n_refits};"
                f"pred_err={pred.prediction_error.get(cold.name, np.nan):.4f};"
                f"trace_pp={'|'.join(f'{g * 100:.1f}' for g in post)}",
            )
        )

    # cluster-wide view for the last scenario: online vs oracle average
    cluster_gap = oracle.improvement_trace - online.improvement_trace
    lines.append(
        csv_line(
            "online_adaptation.cluster_gap",
            0.0,
            f"mean_pp={float(np.mean(cluster_gap)) * 100:.2f};"
            f"max_pp={float(np.max(cluster_gap)) * 100:.2f};"
            f"rounds={n_rounds}",
        )
    )
