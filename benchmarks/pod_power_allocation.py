"""BEYOND-PAPER experiment: EcoShift managing a power-capped TPU pod fleet.

The cluster runs the 10 assigned architectures' training/serving jobs
(surfaces derived from the compiled dry-run rooflines — core/arch_surfaces)
under a fleet-wide power budget.  EcoShift's DP allocates reclaimed watts
across jobs; baselines are fair-share (DPS) and demand-proportional
(MixedAdaptive).  This closes the loop: the paper's control plane operating
on the framework's own workloads.
"""

from __future__ import annotations

from benchmarks.common import csv_line
from repro.core import arch_surfaces
from repro.core.emulator import ClusterEmulator
from repro.core.types import SYSTEM_TPU_V5E


def run(lines: list[str], *, fast: bool = False) -> None:
    apps, surfs = arch_surfaces.build_arch_suite()
    if not apps:
        lines.append(
            csv_line("pod_power.missing", 0.0, "run repro.launch.dryrun first")
        )
        return
    classes = {c: sum(1 for a in apps if a.sclass == c) for c in "CGBN"}
    lines.append(
        csv_line(
            "pod_power.suite", 0.0,
            f"jobs={len(apps)};classes=C:{classes['C']},G:{classes['G']},"
            f"B:{classes['B']},N:{classes['N']}",
        )
    )
    emu = ClusterEmulator.build(
        SYSTEM_TPU_V5E, apps, surfs, n_nodes=64 if fast else 100, seed=0
    )
    donors, receivers, pool = emu.partition()
    lines.append(
        csv_line(
            "pod_power.partition", 0.0,
            f"donors={len(donors)};receivers={len(receivers)};"
            f"reclaimed={pool:.0f}W",
        )
    )
    budgets = (2000.0,) if fast else (1000.0, 3000.0, 6000.0)
    for budget in budgets:
        res = {}
        for policy in ("ecoshift", "dps", "mixed_adaptive"):
            r = emu.run_round(policy, budget=budget)
            res[policy] = r.avg_improvement
            lines.append(
                csv_line(
                    f"pod_power.B{int(budget)}.{policy}", 0.0,
                    f"avg_impr={r.avg_improvement*100:.2f}%;jain={r.jain_index:.3f}",
                )
            )
        adv = res["ecoshift"] - max(res["dps"], res["mixed_adaptive"])
        lines.append(
            csv_line(
                f"pod_power.B{int(budget)}.advantage", 0.0,
                f"ecoshift_vs_best_baseline={adv*100:+.2f}pp",
            )
        )

    # fault-tolerance probe: kill 5 nodes, re-optimize
    emu.fail_nodes([n.node_id for n in emu.alive_nodes()[:5]])
    r = emu.run_round("ecoshift", budget=3000.0)
    lines.append(
        csv_line(
            "pod_power.after_5_failures", 0.0,
            f"avg_impr={r.avg_improvement*100:.2f}%;"
            f"budget_includes_reclaimed_from_dead_nodes={r.budget:.0f}W",
        )
    )
