"""Emulation-based policy evaluation engine (paper §5.4, Figs. 5-9, 11).

One ``evaluate`` call runs a policy on an emulated cluster for several
seeds and returns the mean/CI of the average improvement plus per-app
distributions — the quantity every results figure is built from.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Context, build_cluster, build_cluster_sim
from repro.cluster import Scenario
from repro.core import metrics, policies


@dataclasses.dataclass
class PolicyResult:
    policy: str
    mean: float
    lo: float
    hi: float
    jain: float
    improvements: np.ndarray  # pooled per-app improvements


def evaluate(
    ctx: Context,
    group: str,
    policy: str,
    budget: float,
    *,
    initial_caps: tuple[float, float] | None = None,
    n_nodes: int = 100,
    seeds: tuple[int, ...] = (0, 1, 2, 3, 4),
) -> PolicyResult:
    means, jains, pooled = [], [], []
    for seed in seeds:
        emu = build_cluster(
            ctx, group, n_nodes=n_nodes, seed=seed, initial_caps=initial_caps
        )
        kw = {}
        if policy == "ecoshift":
            kw["policy_surfaces"] = ctx.predicted_for(emu)
        res = emu.run_round(policy, budget=budget, **kw)
        means.append(res.avg_improvement)
        jains.append(res.jain_index)
        pooled.extend(res.improvements.values())
    mean, lo, hi = metrics.mean_ci98(np.array(means))
    return PolicyResult(
        policy=policy,
        mean=mean,
        lo=lo,
        hi=hi,
        jain=float(np.mean(jains)),
        improvements=np.array(pooled),
    )


def evaluate_trace(
    ctx: Context,
    group: str,
    policy: str,
    budgets: tuple[float, ...],
    *,
    initial_caps: tuple[float, float] | None = None,
    n_nodes: int = 100,
    seeds: tuple[int, ...] = (0, 1, 2, 3, 4),
) -> dict[float, PolicyResult]:
    """Scenario-based sweep: all budgets run as one multi-round timeline.

    One stateful controller per seed steps a budget-trace scenario, so
    EcoShift's option tables build once and every later budget re-solves
    warm — versus ``evaluate``'s cold single round per budget.
    """
    acc: dict[float, tuple[list, list, list]] = {b: ([], [], []) for b in budgets}
    for seed in seeds:
        sim = build_cluster_sim(
            ctx, group, n_nodes=n_nodes, seed=seed, initial_caps=initial_caps
        )
        controller = policies.get_controller(policy, ctx.system)
        surfaces = ctx.predicted_for if policy == "ecoshift" else None
        scen = Scenario(n_rounds=len(budgets), budget=budgets)
        trace = sim.run(scen, controller, policy_surfaces=surfaces)
        for budget, rec in zip(budgets, trace.records):
            means, jains, pooled = acc[budget]
            means.append(rec.result.avg_improvement)
            jains.append(rec.result.jain_index)
            pooled.extend(rec.result.improvements.values())
    out = {}
    for budget, (means, jains, pooled) in acc.items():
        mean, lo, hi = metrics.mean_ci98(np.array(means))
        out[budget] = PolicyResult(
            policy=policy,
            mean=mean,
            lo=lo,
            hi=hi,
            jain=float(np.mean(jains)),
            improvements=np.array(pooled),
        )
    return out


POLICIES = ("ecoshift", "dps", "mixed_adaptive")
GROUPS = ("cpu", "gpu", "both", "insensitive", "mixed")
