"""§6.1 predictor accuracy: NCF mean accuracy per system (paper: 93-95%).

Accuracy = 1 - |p_hat - p| / p over normalized performance relative to the
initial-cap baseline, averaged over all grid cells of the held-out
(online-onboarded) applications.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_context
from repro.core import metrics


def run(lines: list[str]) -> None:
    for system_name in ("system1-a100", "system2-h100"):
        ctx = get_context(system_name)
        system = ctx.system
        base = (system.init_cpu, system.init_gpu)
        grid = system.grid
        cc, gg = np.meshgrid(grid.cpu_levels, grid.gpu_levels, indexing="ij")
        accs = []
        for name in ctx.unseen:
            true, pred = ctx.true_surfaces[name], ctx.predicted[name]
            p_true = true.runtime(*base) / true.runtime(cc, gg)
            p_pred = pred.runtime(*base) / pred.runtime(cc, gg)
            accs.append(
                np.mean(metrics.prediction_accuracy(p_true.ravel(), p_pred.ravel()))
            )
        mean, lo, hi = metrics.mean_ci98(np.array(accs))
        lines.append(
            csv_line(
                f"predictor.accuracy.{system.name}",
                0.0,
                f"mean={mean*100:.2f}%;ci=[{lo*100:.2f},{hi*100:.2f}];"
                f"n_unseen={len(accs)};paper_band=93-95%",
            )
        )
