"""§6.1 predictor accuracy: NCF mean accuracy per system (paper: 93-95%).

Accuracy = 1 - |p_hat - p| / p over normalized performance relative to the
initial-cap baseline, averaged over all grid cells.

Both predictor phases are evaluated against the *same* full-grid cells so
they are directly comparable:

 * ``offline``  — apps inside the offline training matrix (their
                  embeddings were learned from dense noisy sweeps);
 * ``online``   — held-out apps onboarded through the online phase
                  (embeddings fit from K profiled samples, the converged
                  state of the telemetry loop benchmarked end-to-end in
                  benchmarks/online_adaptation.py).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import N_HELDOUT, csv_line, get_context
from repro.core import metrics


def _grid_accuracy(system, true, pred) -> float:
    base = (system.init_cpu, system.init_gpu)
    grid = system.grid
    cc, gg = np.meshgrid(grid.cpu_levels, grid.gpu_levels, indexing="ij")
    p_true = true.runtime(*base) / true.runtime(cc, gg)
    p_pred = pred.runtime(*base) / pred.runtime(cc, gg)
    return float(
        np.mean(metrics.prediction_accuracy(p_true.ravel(), p_pred.ravel()))
    )


def run(lines: list[str]) -> None:
    for system_name in ("system1-a100", "system2-h100"):
        ctx = get_context(system_name)
        system = ctx.system
        seen = [a.name for a in ctx.apps if a.name not in ctx.unseen]
        phases = {
            # same number of apps per phase keeps the CIs comparable
            "offline": seen[:N_HELDOUT],
            "online": ctx.unseen,
        }
        for phase, names in phases.items():
            accs = np.array(
                [
                    _grid_accuracy(
                        system, ctx.true_surfaces[n], ctx.predicted[n]
                    )
                    for n in names
                ]
            )
            mean, lo, hi = metrics.mean_ci98(accs)
            lines.append(
                csv_line(
                    f"predictor.accuracy.{phase}.{system.name}",
                    0.0,
                    f"mean={mean * 100:.2f}%;ci=[{lo * 100:.2f},{hi * 100:.2f}];"
                    f"n_apps={len(accs)};paper_band=93-95%",
                )
            )
