"""Roofline table from the multi-pod dry-run artifacts (§Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
reports, per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, peak memory, and MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import csv_line

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(lines: list[str]) -> None:
    if not DRYRUN_DIR.exists():
        lines.append(csv_line("roofline.missing", 0.0, "run repro.launch.dryrun first"))
        return
    files = sorted(DRYRUN_DIR.glob("*.json"))
    n_ok = n_skip = n_fail = 0
    for path in files:
        rec = json.loads(path.read_text())
        tag = f"roofline.{rec['arch']}.{rec['shape']}.{rec.get('mesh','?')}"
        if "skipped" in rec:
            n_skip += 1
            continue
        if "error" in rec:
            n_fail += 1
            lines.append(csv_line(tag, 0.0, f"ERROR={rec['error'][:80]}"))
            continue
        n_ok += 1
        r = rec["roofline"]
        lines.append(
            csv_line(
                tag,
                r["step_s"] * 1e6,
                f"compute={r['compute_s']*1e3:.2f}ms;mem={r['memory_s']*1e3:.2f}ms;"
                f"coll={r['collective_s']*1e3:.2f}ms;bneck={r['bottleneck']};"
                f"peak={rec['peak_bytes_per_device']/1e9:.2f}GB;"
                f"fits={rec['fits_16gb']};useful={rec['useful_flops_ratio']:.3f}",
            )
        )
    lines.append(
        csv_line("roofline.summary", 0.0, f"ok={n_ok};skipped={n_skip};failed={n_fail}")
    )
