"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--fast`` trims sweeps for CI;
``--only fig10`` runs a single module.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="trimmed sweeps")
    ap.add_argument("--only", default=None, help="substring filter on modules")
    args = ap.parse_args()

    from benchmarks import (
        budget_horizon,
        cluster_scaling,
        dp_scaling,
        fault_storm,
        hier_alloc,
        incremental_alloc,
        fig1_heatmaps,
        fig2_marginal_gain,
        fig5_budget_sweep,
        fig6_cap_sweep,
        fig9_distribution,
        fig10_oracle_gap,
        fig11_fairness,
        online_adaptation,
        pod_power_allocation,
        predictor_accuracy,
        roofline_report,
        straggler_response,
        table2_case_study,
    )

    modules = [
        ("fig1", fig1_heatmaps.run, False),
        ("fig2", fig2_marginal_gain.run, False),
        ("table2", table2_case_study.run, False),
        ("predictor", predictor_accuracy.run, False),
        ("fig5_7", fig5_budget_sweep.run, True),
        ("fig6_8", fig6_cap_sweep.run, True),
        ("fig9", fig9_distribution.run, True),
        ("fig10", fig10_oracle_gap.run, True),
        ("fig11", fig11_fairness.run, True),
        ("dp_scaling", dp_scaling.run, True),
        ("cluster_scaling", cluster_scaling.run, True),
        ("hier_alloc", hier_alloc.run, True),
        ("incremental_alloc", incremental_alloc.run, True),
        ("budget_horizon", budget_horizon.run, True),
        ("fault_storm", fault_storm.run, True),
        ("roofline", roofline_report.run, False),
        ("pod_power", pod_power_allocation.run, True),
        ("straggler", straggler_response.run, True),
        ("online_adaptation", online_adaptation.run, True),
    ]

    lines: list[str] = ["name,us_per_call,derived"]
    for name, fn, takes_fast in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            if takes_fast:
                fn(lines, fast=args.fast)
            else:
                fn(lines)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # noqa: BLE001 - report, keep the harness alive
            lines.append(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            print(f"# {name} FAILED: {e}", file=sys.stderr)
    print("\n".join(lines))


if __name__ == "__main__":
    main()
