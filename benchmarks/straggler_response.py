"""BEYOND-PAPER: straggler mitigation through performance-aware power.

A straggling node (thermal throttle, failing HBM) slows its job by 1.5-3x.
Because EcoShift allocates watts by *marginal gain*, a straggler whose
surface still responds to power automatically attracts reclaimed watts
(its relative runtime reduction per watt is unchanged while its absolute
pain is larger); a straggler that no longer responds (hardware-bound) is
correctly ignored.  DPS gives both the same fair share regardless.

Runs as a declarative multi-round scenario on the cluster engine: the
straggler strikes at round 1 of a 3-round timeline, so the trace shows the
victim's gain before onset, at onset, and after the controller's warm
re-optimization.
"""

from __future__ import annotations

from benchmarks.common import csv_line, get_suite
from repro.cluster import ClusterSim, Scenario
from repro.core import policies

N_NODES = 30
BUDGET = 1500.0
SLOWDOWN = 2.0
ONSET_ROUND = 1


def run(lines: list[str], *, fast: bool = False) -> None:
    system, apps, surfs = get_suite("system1-a100")
    probe = ClusterSim.build(system, apps, surfs, n_nodes=N_NODES, seed=0)
    victim = [n for n in probe.alive_nodes() if n.app.sclass in "CG"][0]
    v_name = victim.app.name

    scen = Scenario.constant(3, budget=BUDGET).with_straggler(
        ONSET_ROUND, victim.node_id, SLOWDOWN
    )
    lines.append(
        csv_line(
            "straggler.victim", 0.0,
            f"node={victim.node_id};app={v_name};slowdown={SLOWDOWN}x;"
            f"onset_round={ONSET_ROUND}",
        )
    )
    for policy in ("ecoshift", "dps"):
        sim = ClusterSim.build(system, apps, surfs, n_nodes=N_NODES, seed=0)
        controller = policies.get_controller(policy, system)
        trace = sim.run(scen, controller)
        onset = trace.records[ONSET_ROUND].result
        victim_trace = trace.improvements_of(v_name)
        lines.append(
            csv_line(
                f"straggler.{policy}", 0.0,
                f"victim_gain={onset.improvements[v_name]*100:.2f}%;"
                f"cluster_avg={onset.avg_improvement*100:.2f}%;"
                f"victim_pre_onset={victim_trace[0]*100:.2f}%",
            )
        )
