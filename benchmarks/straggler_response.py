"""BEYOND-PAPER: straggler mitigation through performance-aware power.

A straggling node (thermal throttle, failing HBM) slows its job by 1.5-3x.
Because EcoShift allocates watts by *marginal gain*, a straggler whose
surface still responds to power automatically attracts reclaimed watts
(its relative runtime reduction per watt is unchanged while its absolute
pain is larger); a straggler that no longer responds (hardware-bound) is
correctly ignored.  DPS gives both the same fair share regardless.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line, get_suite
from repro.core.emulator import ClusterEmulator


def run(lines: list[str], *, fast: bool = False) -> None:
    system, apps, surfs = get_suite("system1-a100")
    emu = ClusterEmulator.build(system, apps, surfs, n_nodes=30, seed=0)
    victim = [n for n in emu.alive_nodes() if n.app.sclass in "CG"][0]
    emu.add_straggler(victim.node_id, slowdown=2.0)

    base = emu.run_round("ecoshift", budget=1500.0)
    dps = emu.run_round("dps", budget=1500.0)
    v_name = victim.app.name
    lines.append(
        csv_line(
            "straggler.victim", 0.0,
            f"node={victim.node_id};app={v_name};slowdown=2.0x",
        )
    )
    lines.append(
        csv_line(
            "straggler.ecoshift", 0.0,
            f"victim_gain={base.improvements[v_name]*100:.2f}%;"
            f"cluster_avg={base.avg_improvement*100:.2f}%",
        )
    )
    lines.append(
        csv_line(
            "straggler.dps", 0.0,
            f"victim_gain={dps.improvements[v_name]*100:.2f}%;"
            f"cluster_avg={dps.avg_improvement*100:.2f}%",
        )
    )
