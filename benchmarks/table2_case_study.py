"""Table 2: two-app case study — cfd + raytracing, 200 W reclaimed.

Paper numbers (H100): EcoShift 16.96% avg (cfd->(400,200) 18.35, rt->(300,300)
15.57), DPS 9.21% (both (350,250)), MixedAdaptive 13.16%.  We reproduce the
ordering and the all-CPU-to-cfd / all-GPU-to-raytracing allocation shape.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line
from repro.core import policies, surfaces, types


def run(lines: list[str]) -> None:
    grid = types.CapGrid(cpu_min=200, cpu_max=500, gpu_min=100, gpu_max=500, step=50)
    system = types.SystemSpec(
        name="system2-h100", grid=grid, init_cpu=300, init_gpu=200
    )
    apps = [
        types.AppSpec("cfd", "C", "cfd"),
        types.AppSpec("raytracing", "G", "raytracing"),
    ]
    surfs = {"cfd": surfaces.cfd_surface(), "raytracing": surfaces.raytracing_surface()}
    baselines = {a.name: (300.0, 200.0) for a in apps}

    for pname in ("ecoshift", "dps", "mixed_adaptive", "oracle"):
        alloc = policies.POLICIES[pname](apps, baselines, 200.0, system, surfs)
        gains = {
            a.name: float(surfs[a.name].improvement(baselines[a.name], *alloc.caps[a.name]))
            for a in apps
        }
        avg = float(np.mean(list(gains.values())))
        caps_txt = ";".join(
            f"{n}=({alloc.caps[n][0]:.0f}W,{alloc.caps[n][1]:.0f}W,{gains[n]*100:.2f}%)"
            for n in sorted(alloc.caps)
        )
        lines.append(csv_line(f"table2.{pname}", 0.0, f"avg={avg*100:.2f}%;{caps_txt}"))
