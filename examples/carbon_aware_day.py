"""A carbon-aware day: ride a grid CO2 trace with the MPC allocator.

Replays the shipped 96-point (15-minute) CO2-intensity and spot-price
fixtures through a 100-node cluster three ways — the myopic cap-riding
controller, a signal-blind uniform derating, and the receding-horizon
planner (DESIGN.md §15) — and prints the value / CO2 / dollars
scoreboard.  The MPC controller plans over the budget forecast weighted
by the CO2 signal, shedding spend on dirty-grid rounds and banking it
into the midday solar trough, and never exceeds any round's
instantaneous budget.

    PYTHONPATH=src python examples/carbon_aware_day.py
"""

from repro.cluster import ClusterSim, ConstantProvider, Scenario
from repro.cluster.controller import make_controller
from repro.core import surfaces, types

SYSTEM = types.SYSTEM_1
N_NODES = 100
N_ROUNDS = 96  # one day at 15-minute resolution
BUDGET_W = 2.0 * N_NODES
HORIZON = 12  # plan 3 hours ahead
ECO = 0.7  # spend at most 70% of the myopic controller's weighted draw


def score(res):
    value = grams = dollars = 0.0
    for rec in res.records:
        spent = rec.result.allocation.spent
        assert spent <= rec.result.budget + 1e-6  # compliance, every round
        value += rec.avg_improvement
        grams += rec.carbon_intensity * spent
        dollars += rec.power_price * spent
    return value, grams, dollars


def main() -> None:
    apps, surfs = surfaces.build_paper_suite(SYSTEM)
    scen = Scenario.carbon_aware(N_ROUNDS, ConstantProvider(BUDGET_W))

    cases = (
        ("myopic (H=1)", Scenario.carbon_aware(N_ROUNDS, BUDGET_W), {}),
        (
            "blind 70% derate",
            Scenario.carbon_aware(N_ROUNDS, ConstantProvider(BUDGET_W * ECO)),
            {},
        ),
        ("mpc (H=12, eco 0.7)", scen, {"horizon": HORIZON, "eco_factor": ECO}),
    )
    print(f"== carbon-aware day: {N_NODES} nodes x {N_ROUNDS} rounds ==")
    print(f"{'policy':22s} {'value':>8s} {'co2':>12s} {'dollars':>10s} "
          f"{'perf/co2':>9s}")
    for name, s, kw in cases:
        sim = ClusterSim.build(
            SYSTEM, apps, surfs, n_nodes=N_NODES, seed=0,
            initial_caps=(150.0, 150.0),
        )
        ctrl = make_controller("ecoshift", SYSTEM, **kw)
        value, grams, dollars = score(sim.run(s, ctrl))
        print(
            f"{name:22s} {value:8.3f} {grams:12.0f} {dollars:10.0f} "
            f"{value / grams * 1e6:9.3f}"
        )
    print("\nMPC sheds spend on dirty-grid rounds: better perf-per-CO2 than "
          "riding the cap, and better than derating blindly.")


if __name__ == "__main__":
    main()
