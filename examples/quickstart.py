"""EcoShift in 60 seconds: predict -> DP-allocate -> beat the baselines.

Runs the full pipeline on the paper's Table-2 scenario plus a small
emulated cluster: train the NCF predictor on historical apps, onboard two
unseen apps with a brief online profile, and distribute 200 W of reclaimed
power with the DP allocator.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ncf, policies, surfaces, types
from repro.core.allocator import EcoShiftAllocator
from repro.core.emulator import ClusterEmulator

SYSTEM = types.SYSTEM_2


def main() -> None:
    print("== EcoShift quickstart ==")
    apps, surfs = surfaces.build_paper_suite(SYSTEM)

    # 1. offline: train the NCF predictor on 30 historical applications
    hist = {a.name: surfs[a.name] for a in apps[:30]}
    print(f"training NCF predictor on {len(hist)} historical apps ...")
    allocator = EcoShiftAllocator.train_offline(
        SYSTEM, hist, ncf.NCFConfig(train_steps=1200)
    )

    # 2. online: two unseen apps arrive; profile 8 cap pairs each
    cfd, rt = surfaces.cfd_surface(), surfaces.raytracing_surface()
    allocator.onboard("cfd", cfd)
    allocator.onboard("raytracing", rt)

    # 3. distribute 200 W of reclaimed power (the paper's Table-2 case)
    recv = [types.AppSpec("cfd", "C", "cfd"), types.AppSpec("raytracing", "G", "raytracing")]
    baselines = {"cfd": (300.0, 200.0), "raytracing": (300.0, 200.0)}
    alloc = allocator.allocate(recv, baselines, budget=200.0)
    true = {"cfd": cfd, "raytracing": rt}
    print("\nEcoShift allocation (200 W reclaimed):")
    for name, (c, g) in sorted(alloc.caps.items()):
        gain = float(true[name].improvement(baselines[name], c, g))
        print(f"  {name:12s} -> ({c:.0f} W CPU, {g:.0f} W GPU)  measured gain {gain*100:.2f}%")

    for pname in ("dps", "mixed_adaptive"):
        a = policies.POLICIES[pname](recv, baselines, 200.0, SYSTEM, true)
        gains = [
            float(true[n].improvement(baselines[n], *a.caps[n])) for n in a.caps
        ]
        print(f"  baseline {pname:15s} avg gain {np.mean(gains)*100:.2f}%")

    # 4. a 40-node emulated cluster round
    emu = ClusterEmulator.build(SYSTEM, apps, surfs, n_nodes=40, seed=0)
    donors, receivers, pool = emu.partition()
    print(f"\ncluster: {len(donors)} donors reclaim {pool:.0f} W for {len(receivers)} receivers")
    for pname in ("ecoshift", "dps", "mixed_adaptive"):
        res = emu.run_round(pname)
        print(f"  {pname:15s} avg improvement {res.avg_improvement*100:.2f}%  jain {res.jain_index:.3f}")


if __name__ == "__main__":
    main()
