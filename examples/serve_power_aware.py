"""Power-aware serving: batched prefill+decode with cap-dependent latency.

Serves a reduced gemma3-family model (5:1 local:global attention with ring
KV caches) through the batched engine, then reports the roofline power
model's token latency across chip caps — the surface EcoShift uses to
decide whether this service deserves reclaimed watts.

    PYTHONPATH=src python examples/serve_power_aware.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.core.arch_surfaces import RooflineSurface
from repro.models.model import Model
from repro.roofline import model as roof
from repro.serving.engine import ServeEngine


def main() -> None:
    cfg = dataclasses.replace(configs.smoke_config("gemma3-27b"), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, s_max=96)

    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 48), 0, cfg.vocab)
    }
    t0 = time.time()
    out = engine.generate(batch, n_steps=8)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s on CPU smoke model)")
    print("sample:", np.asarray(out[0]))

    # the production-cell picture: gemma3-27b decode_32k on a v5e pod
    surf = RooflineSurface(
        flops_pd=2e10, bytes_pd=1.2e11, coll_pd=5e9, host_bytes_pd=1e5,
        host_base_s=0.020,
    )
    print("\nroofline token latency vs chip cap (host cap 300 W):")
    for cap in (100, 140, 180, 220, 250):
        t = float(surf.runtime(300.0, cap))
        print(f"  chip cap {cap:3d} W -> {t*1e3:7.2f} ms/token "
              f"(freq x{roof.freq_fraction(cap):.2f})")
    print("\nhost-cap sensitivity at chip 180 W:")
    for cap in (150, 250, 350, 450):
        t = float(surf.runtime(cap, 180.0))
        print(f"  host cap {cap:3d} W -> {t*1e3:7.2f} ms/token")


if __name__ == "__main__":
    main()
