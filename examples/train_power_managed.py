"""End-to-end driver: train an LM with checkpointing under EcoShift rounds.

Trains a reduced granite-family model (use --d-model/--layers/--steps to
scale up to ~100M params on real hardware) with the full substrate:
packed-Zipf data pipeline, AdamW + cosine schedule, atomic checkpoints,
crash-resume, and a periodic EcoShift power round that treats this job and
its emulated co-tenants as receivers of reclaimed pod power (surfaces from
the roofline power model).

    PYTHONPATH=src python examples/train_power_managed.py --steps 120
"""

import argparse
import dataclasses
import pathlib
import tempfile

from repro import configs
from repro.cluster import ClusterSim, Scenario
from repro.cluster.sim import NodeState
from repro.core import policies
from repro.core.arch_surfaces import RooflineSurface
from repro.core.types import SYSTEM_TPU_V5E, AppSpec
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import make_batch_fn
from repro.train.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--power-round-every", type=int, default=40)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    model = Model(cfg)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="ecoshift_train_")
    trainer = Trainer(
        model=model,
        batch_fn=make_batch_fn(cfg, batch=args.batch, seq=args.seq),
        ckpt=CheckpointManager(pathlib.Path(ckpt_dir)),
        ckpt_every=20,
        peak_lr=3e-3,
        total_steps=args.steps,
    )
    if trainer.resume():
        print(f"resumed from checkpoint at step {trainer.step}")
    else:
        trainer.init()
        print(f"fresh run; checkpoints -> {ckpt_dir}")

    # this job + emulated co-tenants as a 3-node EcoShift pod: a declarative
    # scenario drives the budget trace and ONE stateful controller carries
    # its cached option tables across every power round
    me = AppSpec("this-train-job", "G", "this-train-job")
    peers = [
        AppSpec("decode-service", "C", "decode-service"),
        AppSpec("prefill-burst", "B", "prefill-burst"),
    ]
    surfs = {
        "this-train-job": RooflineSurface(5e13, 1e11, 5e9, 1e6, 0.010),
        "decode-service": RooflineSurface(5e9, 5e9, 1e8, 1e5, 0.020),
        "prefill-burst": RooflineSurface(2e13, 8e10, 3e9, 5e5, 0.012),
    }
    nodes = [
        NodeState(node_id=i, app=a, base_app=a.name, caps=(250.0, 150.0))
        for i, a in enumerate((me, *peers))
    ]
    sim = ClusterSim(
        system=SYSTEM_TPU_V5E, nodes=nodes, surfaces=surfs, n_repeats=1
    )
    n_rounds = -(-args.steps // args.power_round_every)
    scen = Scenario.constant(n_rounds, budget=120.0)
    controller = policies.get_controller("ecoshift", SYSTEM_TPU_V5E)

    round_idx = 0
    while trainer.step < args.steps:
        n = min(args.power_round_every, args.steps - trainer.step)
        hist = trainer.run(n)
        loss = hist[-1]["loss"]
        res = sim.run_round(
            controller,
            budget=scen.budget_at(round_idx),
            receivers=sim.nodes,
            round_index=round_idx,
        )
        round_idx += 1
        c, g = res.allocation.caps["this-train-job"]
        gain = float(
            surfs["this-train-job"].improvement((250.0, 150.0), c, g)
        )
        print(
            f"step {trainer.step:4d}  loss {loss:.4f}  "
            f"power round: this job -> ({c:.0f} W host, {g:.0f} W chip), "
            f"predicted speedup {gain*100:.1f}%"
        )
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"done: loss {first:.3f} -> {last:.3f} over {trainer.step} steps")


if __name__ == "__main__":
    main()
