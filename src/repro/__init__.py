"""EcoShift-on-TPU reproduction framework."""
