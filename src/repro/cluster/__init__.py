"""Stateful, vectorized cluster control loop (EcoShift §5.4, multi-round).

Six layers:

 * ``budget``     — composable :class:`BudgetProvider` sources (constant,
                    trace replay, scaled/min composition, step overrides)
                    plus the shipped day-scale CO2/price/solar fixtures;
 * ``scenario``   — declarative event timelines (budget/price traces, node
                    arrivals/failures, straggler onsets, phase changes);
 * ``faults``     — declarative seeded fault injection (telemetry drops /
                    corruption, actuation NACK/partial/delay, controller
                    crash+restore) resolved by the engine's PowerGuard
                    watchdog and the controllers' self-healing hooks
                    (DESIGN.md §18);
 * ``predictor``  — the telemetry-driven online prediction subsystem
                    (observation buffers, batched NCF online fits,
                    tolerance-gated surface refresh);
 * ``controller`` — stateful per-policy controllers carrying warm state
                    (cached option tables, predictor handles) across rounds;
 * ``sim``        — the time-stepped multi-round engine with vectorized
                    measurement, telemetry emission and batched DP solves.

``repro.core.emulator.ClusterEmulator`` is a thin single-round wrapper over
this package, kept for the paper-figure benchmarks and tests.
"""

from repro.core.topology import PowerDomain, PowerTopology  # noqa: F401
from repro.cluster.budget import (  # noqa: F401
    BudgetProvider,
    ConstantProvider,
    MinProvider,
    OverrideBook,
    ScaledProvider,
    StepOverrideProvider,
    TraceReplayProvider,
    as_provider,
    fixture_provider,
    fixture_trace,
    load_fixture,
    solar_budget,
)
from repro.cluster.scenario import (  # noqa: F401
    DomainCapChange,
    NodeArrival,
    NodeFailure,
    PhaseChange,
    Scenario,
    StragglerOnset,
)
from repro.cluster.predictor import (  # noqa: F401
    OnlinePredictor,
    OnlinePredictorConfig,
    TelemetryBatch,
    TelemetryRecord,
)
from repro.cluster.faults import (  # noqa: F401
    ActuationDelay,
    ActuationNack,
    ActuationPartial,
    ActuationReport,
    ControllerCrash,
    FaultInjector,
    TelemetryCorrupt,
    TelemetryDelay,
    TelemetryDrop,
    TelemetryStale,
    fault_storm,
    validate_faults,
)
from repro.cluster.sim import (  # noqa: F401
    ClusterSim,
    NodeState,
    NodeTable,
    RoundRecord,
    SimResult,
)
from repro.cluster.controller import (  # noqa: F401
    Controller,
    ControllerConfig,
    load_snapshot,
    make_controller,
    save_snapshot,
)
