"""Budget providers: one source of truth for every dynamic power budget.

Real power-constrained sites ride grid signals — CO2 intensity, spot
price, solar output — rather than static caps.  This module turns "what
is the budget at round r?" into a first-class, composable interface:

 * :class:`BudgetProvider` — the protocol every budget source satisfies:
   ``budget_at(round)`` for the instantaneous value and
   ``forecast(round, horizon)`` for the H-round outlook the receding-
   horizon allocator plans over (``repro.core.mckp.plan_horizon``);
 * :class:`ConstantProvider` / :class:`TraceReplayProvider` — static and
   trace-replay sources (scalar / per-round sequence / callable, the
   three legacy ``Scenario`` trace forms, with identical hold-last
   semantics);
 * :class:`ScaledProvider` / :class:`MinProvider` — composition: derate
   a feed by a factor, or cap one feed by another (e.g. "solar output,
   but never above the PDU rating");
 * :class:`StepOverrideProvider` / :class:`OverrideBook` — piecewise
   step overrides active *from their round on*.  ``OverrideBook`` is the
   engine's routing target for ``DomainCapChange`` events, replacing the
   ad-hoc ``dict`` the sim used to mutate — domain caps, cluster
   budgets, and cap-change events now all resolve through the same float
   coercion (:func:`as_watts`) and the same from-round-inclusive step
   semantics (the rounding/precedence bugfix this module centralizes,
   see DESIGN.md §15).

All three historical budget pathways (``Scenario.budget`` traces,
``DomainCapChange`` events, ``Scenario.with_domain_cap``) resolve through
this module; ``Scenario`` auto-wraps raw traces via :func:`as_provider`
so existing scenarios run unchanged.

Day-scale signal fixtures (CO2 intensity, spot price, solar output)
ship with the package under ``fixtures/`` and load via
:func:`load_fixture` / :func:`fixture_trace`.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Protocol, Sequence, Union, runtime_checkable

#: legacy trace union: scalar (constant), per-round sequence (holds its
#: last value), or callable ``round -> value``; None = "no signal"
Trace = Union[None, float, Sequence, Callable[[int], object]]


def as_watts(value) -> float | None:
    """The one scalar coercion every budget/cap pathway shares.

    ``Scenario.budget_at`` and the per-domain cap resolution historically
    coerced independently (plain ``float()`` in two places), which let a
    ``DomainCapChange`` carrying a numpy scalar and a budget-trace step
    landing on the same round disagree at the last bit.  Centralizing the
    coercion (and accepting numpy floats explicitly) makes both sides
    resolve identically by construction.
    """
    if value is None:
        return None
    return float(value)


def trace_at(trace: Trace, r: int):
    """Resolve a legacy trace at round ``r`` (scenario semantics: scalars
    are constant, sequences hold their last value, empty sequences and
    None yield None, callables are invoked)."""
    if trace is None or isinstance(trace, (int, float)):
        return trace
    if callable(trace):
        return trace(r)
    if len(trace) == 0:
        return None
    return trace[min(r, len(trace) - 1)]


@runtime_checkable
class BudgetProvider(Protocol):
    """What every budget source answers: now, and the next H rounds."""

    def budget_at(self, r: int) -> float | None:
        """Budget (watts / signal units) at round ``r``; None = unset."""
        ...

    def forecast(self, r: int, horizon: int) -> tuple:
        """Values for rounds ``r .. r+horizon-1`` (certainty-equivalent:
        trace replay *is* the forecast; a live feed would plug a
        predictive model in here)."""
        ...


class _ProviderBase:
    """Shared forecast/composition plumbing for concrete providers."""

    def budget_at(self, r: int) -> float | None:  # pragma: no cover
        raise NotImplementedError

    def forecast(self, r: int, horizon: int) -> tuple:
        return tuple(self.budget_at(r + i) for i in range(int(horizon)))

    # -- composition sugar ---------------------------------------------------

    def scaled(self, factor: float) -> "ScaledProvider":
        return ScaledProvider(self, factor)

    def min_with(self, other) -> "MinProvider":
        return MinProvider(self, other)


class ConstantProvider(_ProviderBase):
    """The same value every round (``None`` = every round unset)."""

    def __init__(self, value: float | None):
        self.value = as_watts(value)

    def budget_at(self, r: int) -> float | None:
        return self.value

    def __repr__(self) -> str:
        return f"ConstantProvider({self.value!r})"


class TraceReplayProvider(_ProviderBase):
    """Replay a recorded signal trace (CO2 intensity, spot price, solar
    output, budget watts) with the scenario trace semantics: scalars are
    constant, sequences hold their last value, callables are invoked.

    This is the shim target for legacy ``Scenario.budget`` traces: a raw
    trace handed to ``Scenario``/``with_budget`` auto-wraps into one of
    these (:func:`as_provider`), so ``budget_at`` keeps returning exactly
    ``float(trace value)``.
    """

    def __init__(self, trace: Trace):
        if isinstance(trace, TraceReplayProvider):
            trace = trace.trace
        if not (
            trace is None
            or isinstance(trace, (int, float))
            or callable(trace)
            or hasattr(trace, "__len__")
        ):
            raise TypeError(
                f"trace must be None, scalar, sequence or callable, "
                f"got {type(trace).__name__}"
            )
        self.trace = trace

    def budget_at(self, r: int) -> float | None:
        return as_watts(trace_at(self.trace, r))

    def __repr__(self) -> str:
        return f"TraceReplayProvider({self.trace!r})"


class ScaledProvider(_ProviderBase):
    """``factor * base`` — per-domain derating, unit conversion (e.g.
    normalized solar fraction -> watts), or eco-mode shaving."""

    def __init__(self, base, factor: float):
        self.base = as_provider(base)
        self.factor = float(factor)

    def budget_at(self, r: int) -> float | None:
        b = None if self.base is None else self.base.budget_at(r)
        return None if b is None else b * self.factor

    def __repr__(self) -> str:
        return f"ScaledProvider({self.base!r}, {self.factor!r})"


class MinProvider(_ProviderBase):
    """Pointwise minimum of several providers (unset members ignored;
    all-unset rounds stay None) — "solar-following, but never above the
    breaker rating"."""

    def __init__(self, *providers):
        if not providers:
            raise ValueError("MinProvider needs at least one provider")
        self.providers = tuple(as_provider(p) for p in providers)

    def budget_at(self, r: int) -> float | None:
        vals = [
            v
            for p in self.providers
            if p is not None
            for v in (p.budget_at(r),)
            if v is not None
        ]
        return min(vals) if vals else None

    def __repr__(self) -> str:
        return f"MinProvider{self.providers!r}"


class StepOverrideProvider(_ProviderBase):
    """A base provider with piecewise step overrides: each ``(round, value)``
    step applies *from its round on* (inclusive) until a later step
    supersedes it — exactly the ``DomainCapChange`` contract."""

    def __init__(self, base, steps):
        self.base = as_provider(base)
        items = steps.items() if hasattr(steps, "items") else steps
        self.steps = tuple(
            sorted((int(rr), as_watts(v)) for rr, v in items)
        )

    def budget_at(self, r: int) -> float | None:
        v = None if self.base is None else self.base.budget_at(r)
        for rr, val in self.steps:
            if rr <= r:
                v = val
        return v

    def __repr__(self) -> str:
        return f"StepOverrideProvider({self.base!r}, {self.steps!r})"


def as_provider(trace) -> BudgetProvider | None:
    """Normalize anything budget-like into a provider (the shim).

    ``None`` stays None ("no signal" — e.g. donor-derived pool budgets);
    an object already exposing ``budget_at`` passes through unchanged;
    raw legacy traces wrap into a :class:`TraceReplayProvider`.
    Idempotent, so frozen-dataclass normalization can run on every
    ``dataclasses.replace``.
    """
    if trace is None:
        return None
    if hasattr(trace, "budget_at"):
        return trace
    return TraceReplayProvider(trace)


class OverrideBook:
    """Mutable registry of per-domain cap-change steps (the engine's
    ``DomainCapChange`` routing target).

    Each domain id accumulates ``(round, cap)`` steps; :meth:`active`
    resolves which override (if any) binds each domain *at a given
    round* — a step applies from its round on, the latest applicable
    step wins.  Resolution shares :func:`as_watts` with the budget
    providers, so a cap change and a budget-trace step landing on the
    same round can no longer disagree on float handling; and a headroom
    query for a round *before* a change's round no longer sees the
    future cap (the old ``dict`` override applied unconditionally the
    moment the event was processed).
    """

    def __init__(self):
        self._steps: dict[int, list[tuple[int, float]]] = {}

    def set(self, domain_id: int, round: int, cap) -> None:
        """Record: ``domain_id``'s cap becomes ``cap`` from ``round`` on."""
        steps = self._steps.setdefault(int(domain_id), [])
        steps.append((int(round), as_watts(cap)))
        steps.sort(key=lambda s: s[0])

    def active(self, r: int) -> dict[int, float]:
        """domain id -> overriding cap binding at round ``r``."""
        out: dict[int, float] = {}
        for dom, steps in self._steps.items():
            for rr, cap in steps:
                if rr <= r:
                    out[dom] = cap
        return out

    def provider_for(self, domain_id: int, base=None) -> StepOverrideProvider:
        """This domain's cap timeline as a provider (base = its cap trace)."""
        return StepOverrideProvider(
            base, self._steps.get(int(domain_id), ())
        )

    def clear(self) -> None:
        self._steps.clear()

    def __len__(self) -> int:
        return len(self._steps)

    def __bool__(self) -> bool:
        return bool(self._steps)


# ---------------------------------------------------------------------------
# Day-scale signal fixtures (shipped as scenario inputs)
# ---------------------------------------------------------------------------

_FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")

#: shipped day-scale signal fixtures (96 points = 15-minute resolution)
FIXTURES = ("co2_day", "price_day", "solar_day")


def load_fixture(name: str) -> dict:
    """Load a shipped signal fixture (or a path to one) as its raw dict:
    ``{"name", "units", "resolution_minutes", "values"}``."""
    path = (
        name
        if os.path.sep in name or name.endswith(".json")
        else os.path.join(_FIXTURE_DIR, f"{name}.json")
    )
    with open(path) as f:
        return json.load(f)


def fixture_trace(name: str, n_rounds: int | None = None) -> tuple:
    """A fixture's value sequence, resampled to ``n_rounds`` points by
    nearest-index lookup (None = native resolution)."""
    values = load_fixture(name)["values"]
    if n_rounds is None or n_rounds == len(values):
        return tuple(float(v) for v in values)
    n = len(values)
    return tuple(
        float(values[min(int(i * n / n_rounds), n - 1)])
        for i in range(int(n_rounds))
    )


def fixture_provider(name: str, n_rounds: int | None = None) -> TraceReplayProvider:
    """A shipped fixture as a replayable provider (scenario input)."""
    return TraceReplayProvider(fixture_trace(name, n_rounds))


def solar_budget(
    peak_watts: float,
    floor_watts: float = 0.0,
    n_rounds: int | None = None,
) -> BudgetProvider:
    """Day-scale solar-following budget: the shipped normalized solar
    curve scaled to ``peak_watts``, never below ``floor_watts`` (grid
    backstop) — a ready-made dynamic-budget scenario input."""
    solar = ScaledProvider(fixture_provider("solar_day", n_rounds), peak_watts)

    class _Floor(_ProviderBase):
        def budget_at(self, r: int) -> float | None:
            v = solar.budget_at(r)
            return None if v is None else max(v, float(floor_watts))

    return _Floor()
