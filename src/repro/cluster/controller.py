"""Stateful policy controllers for the multi-round cluster engine.

One controller per entry in ``repro.core.policies.POLICIES``.  Each wraps
the existing pure policy function but *carries warm state across rounds*:

 * ``EcoShiftController`` / ``OracleController`` cache per-receiver
   ``OptionTable``s keyed by (instance, baseline, surface identity).  The
   tables are budget-independent (built to the grid's headroom ceiling; all
   MCKP solvers already skip over-budget options), so after a node failure
   only the *pool* changes and re-optimization reuses every surviving
   table — the incremental re-solve the paper's fault-tolerance study
   needs.  Event hooks (``invalidate``) drop entries whose surface or
   baseline changed (stragglers, phase changes).
 * ``EcoShiftOnlineController`` closes the prediction loop: it sources its
   surfaces from a telemetry-driven ``repro.cluster.predictor
   .OnlinePredictor`` instead of a frozen mapping, ingests each round's
   measurements via ``ingest_telemetry``, and invalidates warm option
   tables only for instances whose served surface actually moved beyond
   the predictor's tolerance.
 * ``EcoShiftHierController`` allocates through the topology-aware
   two-level capped-frontier DP (DESIGN.md §12), collapsing behaviour
   classes within each leaf power domain and splitting the cluster budget
   across domains subject to every local cap — with the same warm
   content-keyed caches, plus per-domain frontier memoization.
 * heuristic controllers (uniform / DPS / MixedAdaptive) are stateless
   wrappers, registered for a uniform interface.

Controllers register themselves into ``policies.CONTROLLERS`` so the
registry lives beside ``POLICIES`` (``policies.get_controller``).
Controller-only policies (``ecoshift_online``) have no pure-function
counterpart in ``POLICIES`` — the online phase is inherently stateful.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import curves, mckp
from repro.core import policies as policies_mod
from repro.core.curves import OptionTable
from repro.core.surfaces import PowerSurface
from repro.core.types import (
    Allocation,
    AppSpec,
    FusedRoundStats,
    ReceiverBatch,
    SystemSpec,
    as_receiver_order,
)


class Controller:
    """Base: a policy with per-round ``allocate`` plus warm-state hooks."""

    #: key into ``POLICIES`` / the legacy ``run_round`` name
    policy: str = ""
    #: True for policies that always see ground-truth surfaces (Oracle)
    sees_truth: bool = False
    #: True when the controller consumes a columnar ``ReceiverBatch`` via
    #: ``allocate_grouped`` (group-collapsed DP controllers)
    supports_grouped: bool = False

    def __init__(self, system: SystemSpec):
        self.system = system

    def allocate(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        budget: float,
        surfaces: Mapping[str, PowerSurface],
    ) -> Allocation:
        raise NotImplementedError

    # -- warm-state hooks ----------------------------------------------------

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        """Drop cached per-receiver state (``None`` = everything)."""

    def ingest_telemetry(self, records: Sequence) -> None:
        """Consume one round's noisy measurements
        (:class:`repro.cluster.predictor.TelemetryRecord`).  The engine
        calls this after every measured round; predictor-backed
        controllers refresh their surfaces here, everyone else ignores
        it."""

    def reset(self) -> None:
        self.invalidate()

    # -- fault-tolerance hooks (DESIGN.md §18) -------------------------------

    def notify_actuation(self, report) -> None:
        """Engine hook after a faulted round's actuation settles
        (:class:`repro.cluster.faults.ActuationReport`).  DP controllers
        pin NACKed receivers at their last-confirmed caps with bounded
        retry backoff; the base class ignores it."""

    def snapshot(self) -> dict:
        """Serializable warm-state checkpoint (plain python/numpy values).

        The contract (certified by tests/test_faults.py): a controller
        that is ``crash_reset()`` then ``restore(snapshot)``-ed produces
        **bit-for-bit** the allocations of the uninterrupted run.  Warm
        caches are *not* serialized — every incremental/fused path is
        already certified bit-for-bit equal to its from-scratch solve, so
        only state that changes *results* (pins, online-learned predictor
        state) needs to survive; caches and resident banks rebuild cold.
        """
        return {"policy": self.policy}

    def restore(self, state: Mapping) -> None:
        """Adopt a :meth:`snapshot` (see there for the bit-for-bit
        contract).  Drops any warm caches accumulated since — restore is
        self-contained and valid on a warm controller."""
        if state.get("policy") != self.policy:
            raise ValueError(
                f"snapshot of policy {state.get('policy')!r} cannot restore "
                f"a {self.policy!r} controller"
            )

    def crash_reset(self) -> None:
        """Simulate a controller process crash: all warm state is gone.
        (Restore from a snapshot afterwards for checkpointed failover.)"""
        self.reset()


class _StatelessController(Controller):
    """Wraps a pure policy function; nothing carries across rounds."""

    def allocate(self, receivers, baselines, budget, surfaces):
        fn = policies_mod.POLICIES[self.policy]
        return fn(receivers, baselines, budget, self.system, surfaces)


@policies_mod.register_controller("uniform")
class UniformController(_StatelessController):
    policy = "uniform"


@policies_mod.register_controller("dps")
class DPSController(_StatelessController):
    policy = "dps"


@policies_mod.register_controller("mixed_adaptive")
class MixedAdaptiveController(_StatelessController):
    policy = "mixed_adaptive"


@dataclasses.dataclass
class ControllerConfig:
    """One construction config for every EcoShift-family controller.

    The solver/grouping/fusion/predictor knobs grew organically across
    ``EcoShiftController`` / ``EcoShiftHierController`` /
    ``EcoShiftOnlineController`` / ``OracleController``; this dataclass
    folds them into a single object so callers (and
    ``policies.get_controller``) construct any controller as
    ``Ctrl(system, config=ControllerConfig(...))``.  Every historical
    keyword form keeps working as an alias: an explicit keyword passed to
    a controller's ``__init__`` overrides the corresponding config field
    (``merged``), and the defaults here are exactly the historical
    per-controller defaults.

    The receding-horizon fields (DESIGN.md §15): ``horizon`` is how many
    rounds of budget forecast the controller plans over (1 = myopic —
    planning entirely disabled, bit-for-bit today's path); ``eco_factor``
    is the fraction of the myopic controller's weighted (CO2/dollar)
    spend the planner may use (>= 1.0 never restricts, also bit-for-bit);
    ``plan_levels`` / ``plan_grid`` bound the horizon DP's per-round
    candidate count and allowance lattice.
    """

    solver: str = "sparse"
    unit: float = 1.0
    grouped: bool = True
    incremental: bool = True
    fused: bool = False
    #: optional repro.core.allocator.EcoShiftAllocator (warm NCF handle)
    allocator: object | None = None
    #: optional repro.cluster.predictor.OnlinePredictor (required by the
    #: online controller; optional surface source for the hier controller)
    predictor: object | None = None
    #: optional repro.core.topology.PowerTopology (hier controller)
    topology: object | None = None
    #: Oracle brute-force toggle (None = auto, <= 10 receivers)
    exhaustive: bool | None = None
    #: receding-horizon plan length in rounds (1 = myopic)
    horizon: int = 1
    #: fraction of the myopic weighted spend the planner may use
    eco_factor: float = 1.0
    #: max frontier candidates per horizon step
    plan_levels: int = 64
    #: allowance-lattice cells of the horizon DP
    plan_grid: int = 2048
    #: LRU bounds of the warm caches (None = the class defaults, e.g.
    #: ``_OptionCachingController.MAX_GROUP_TABLES``).  Long-running
    #: serving deployments tune memory here; any bound >= 1 is
    #: bit-for-bit safe — caches are pure accelerators (evictions
    #: re-compute, never change results; tests/test_faults.py certifies
    #: a bound of 1 end-to-end)
    max_group_tables: int | None = None
    max_agg_curves: int | None = None
    max_picks: int | None = None
    max_plans: int | None = None
    max_allocations: int | None = None
    max_frontiers: int | None = None

    def merged(self, **overrides) -> "ControllerConfig":
        """Copy with every non-None override applied — the legacy-kwarg
        alias path (an explicit keyword beats the config field)."""
        changes = {k: v for k, v in overrides.items() if v is not None}
        return dataclasses.replace(self, **changes) if changes else self


def _served_replace(batch: ReceiverBatch, served) -> ReceiverBatch:
    """Swap in predictor-served surfaces and strip the delta sequence.

    Served surfaces move on telemetry, outside the engine's delta bound,
    so the batch must not claim delta continuity (seq=0 routes grouping
    down the from-scratch path).  The one helper both online paths share.
    """
    return dataclasses.replace(
        batch, surfaces=served, seq=0, prev_seq=None, delta=None, removed=()
    )


class _ClassRec:
    """One live behaviour class inside a :class:`_GroupingState` scope."""

    __slots__ = ("surf", "members", "table", "group")

    def __init__(self, surf, table):
        self.surf = surf
        #: name-sorted member list, maintained incrementally
        self.members: list[str] = []
        self.table = table
        #: lazily rebuilt frozen GroupedOptions (None = members moved)
        self.group = None


class _GroupingState:
    """Persistent behaviour-class grouping, updated by batch deltas.

    Mirrors ``mckp.collapse_receivers`` — receivers sharing (surface
    identity, baseline) form one class — but *across rounds*: the engine's
    :class:`~repro.core.types.ReceiverBatch` delta contract names exactly
    the positions whose surface/baseline moved and the receivers that
    left, so a steady-state round updates O(churn) classes instead of
    re-collapsing the whole cluster.  ``scope`` partitions classes (leaf
    power-domain id on the hierarchical path, 0 on the flat path).
    Unchanged scopes keep their frozen ``GroupedOptions`` tuples — object
    identity downstream caches (plans, leaf solutions) key on.
    """

    __slots__ = ("seq", "scopes", "of_name", "_groups_cache")

    def __init__(self):
        #: batch seq this state mirrors (None = never built)
        self.seq: int | None = None
        self.scopes: dict[int, dict[tuple, _ClassRec]] = {}
        self.of_name: dict[str, tuple[int, tuple]] = {}
        self._groups_cache: dict[int, tuple] = {}

    def reset(self) -> None:
        self.seq = None
        self.scopes.clear()
        self.of_name.clear()
        self._groups_cache.clear()

    def sync(self, batch, leaf_ids, table_for) -> None:
        """Bring the grouping in line with ``batch`` (delta or rebuild)."""
        if batch.seq == self.seq and self.seq is not None:
            return
        if (
            batch.prev_seq is not None
            and batch.prev_seq == self.seq
            and batch.delta is not None
        ):
            for name in batch.removed:
                self._remove(name)
            for pos in batch.delta:
                self._place(batch, pos, leaf_ids, table_for)
            self.seq = batch.seq
            return
        self._rebuild(batch, leaf_ids, table_for)
        self.seq = batch.seq

    def _rebuild(self, batch, leaf_ids, table_for) -> None:
        self.scopes.clear()
        self.of_name.clear()
        self._groups_cache.clear()
        scopes = (
            leaf_ids.tolist() if leaf_ids is not None else [0] * len(batch)
        )
        bl = batch.baselines.tolist()
        for name, surf, base, scope in zip(
            batch.names, batch.surfaces, bl, scopes
        ):
            base = (base[0], base[1])
            ckey = (id(surf), base)
            recs = self.scopes.setdefault(scope, {})
            rec = recs.get(ckey)
            if rec is None or rec.surf is not surf:
                rec = _ClassRec(surf, table_for(surf, base))
                recs[ckey] = rec
            rec.members.append(name)
            self.of_name[name] = (scope, ckey)
        for recs in self.scopes.values():
            for rec in recs.values():
                rec.members.sort()

    def _place(self, batch, pos, leaf_ids, table_for) -> None:
        name = batch.names[pos]
        surf = batch.surfaces[pos]
        b = batch.baselines[pos]
        base = (float(b[0]), float(b[1]))
        scope = int(leaf_ids[pos]) if leaf_ids is not None else 0
        ckey = (id(surf), base)
        old = self.of_name.get(name)
        if old is not None:
            oscope, ockey = old
            if oscope == scope and ockey == ckey:
                rec = self.scopes[scope][ckey]
                if rec.surf is surf:
                    return  # nothing actually moved
            self._remove(name)
        recs = self.scopes.setdefault(scope, {})
        rec = recs.get(ckey)
        if rec is None or rec.surf is not surf:
            rec = _ClassRec(surf, table_for(surf, base))
            recs[ckey] = rec
        bisect.insort(rec.members, name)
        rec.group = None
        self.of_name[name] = (scope, ckey)
        self._groups_cache.pop(scope, None)

    def _remove(self, name: str) -> None:
        loc = self.of_name.pop(name, None)
        if loc is None:
            return
        scope, ckey = loc
        rec = self.scopes[scope][ckey]
        i = bisect.bisect_left(rec.members, name)
        if i < len(rec.members) and rec.members[i] == name:
            del rec.members[i]
        rec.group = None
        if not rec.members:
            del self.scopes[scope][ckey]
        self._groups_cache.pop(scope, None)

    def groups(self, scope: int) -> tuple:
        """Frozen GroupedOptions of one scope (tuple reused while clean)."""
        g = self._groups_cache.get(scope)
        if g is None:
            out = []
            for rec in self.scopes.get(scope, {}).values():
                if rec.group is None:
                    rec.group = mckp.GroupedOptions(
                        table=rec.table, members=tuple(rec.members)
                    )
                out.append(rec.group)
            g = tuple(out)
            self._groups_cache[scope] = g
        return g

    def by_scope(self) -> dict[int, tuple]:
        return {scope: self.groups(scope) for scope in self.scopes}


class _OptionCachingController(Controller):
    """Shared warm ``OptionTable`` caches for the DP-based policies.

    Two cache layers:

     * per-instance tables keyed by name (the legacy ungrouped path);
     * **group tables** keyed by (surface identity, baseline) — one table
       per behaviour class, shared by every member, feeding the
       group-collapsed solvers.  Keys are value+identity based, so event
       invalidation is implicit: a straggler/phase-change swaps the
       surface object and the stale entry simply stops matching (stale
       keys are pruned opportunistically).

    Both layers build budget-independent tables (grid headroom ceiling;
    all MCKP solvers skip over-budget options), so after a node failure
    only the *pool* changes and re-optimization reuses every surviving
    table — the incremental re-solve the paper's fault-tolerance study
    needs.
    """

    #: LRU bounds of the warm caches (DESIGN.md §13: warm state must stay
    #: capped over long scenarios with drifting budgets/digests)
    MAX_GROUP_TABLES = 512
    MAX_AGG_CURVES = 8192
    MAX_PICKS = 16384
    MAX_PLANS = 256
    MAX_ALLOCATIONS = 8

    #: NACK retry policy (DESIGN.md §18): after this many consecutive
    #: NACKs the controller stops re-commanding a receiver (pin holds
    #: until an operator ``invalidate``/event touches it) ...
    NACK_MAX_RETRIES = 4
    #: ... and the exponential retry backoff is capped at this many rounds
    NACK_MAX_BACKOFF = 8

    def __init__(self, system: SystemSpec):
        super().__init__(system)
        #: name -> (baseline, surface, table); surface compared by identity
        self._options: dict[
            str, tuple[tuple[float, float], PowerSurface, OptionTable]
        ] = {}
        #: (id(surface), baseline) -> (surface, table)
        self._group_tables: mckp.LRUCache = mckp.LRUCache(self.MAX_GROUP_TABLES)
        #: (table digest, multiplicity, budget) -> aggregate sparse curve
        self._agg_curves: mckp.LRUCache = mckp.LRUCache(self.MAX_AGG_CURVES)
        #: (digest, budget) -> doubling chain (shielded from (d, m) churn)
        self._chain_cache: mckp.LRUCache = mckp.LRUCache(512)
        #: (curve key, spend) -> unwound pick multiset
        self._pick_cache: mckp.LRUCache = mckp.LRUCache(self.MAX_PICKS)
        #: group-token tuple -> merged-class plan
        self._plan_cache: mckp.LRUCache = mckp.LRUCache(self.MAX_PLANS)
        #: (group tokens, budget[, headroom]) -> warm Allocation
        self._alloc_cache: mckp.LRUCache = mckp.LRUCache(self.MAX_ALLOCATIONS)
        #: delta-maintained behaviour-class grouping (DESIGN.md §13)
        self._grouping = _GroupingState()
        #: NACK pin book: name -> {"caps": (c, g) last-confirmed applied,
        #: "fails": consecutive NACKs, "until": round the backoff expires}
        self._pins: dict[str, dict] = {}
        #: round of the latest actuation report (pins apply to the *next*
        #: round's solve)
        self._pin_round: int = -1

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        if names is None:
            self._options.clear()
            self._group_tables.clear()
            self._agg_curves.clear()
            self._chain_cache.clear()
            self._pick_cache.clear()
            self._plan_cache.clear()
            self._alloc_cache.clear()
            self._grouping.reset()
            self._pins.clear()
            self._pin_round = -1
        else:
            for n in names:
                self._options.pop(n, None)
                # an event touching a pinned node (failure, phase change)
                # supersedes the pin — the next solve re-commands it
                self._pins.pop(n, None)

    def _apply_cache_bounds(self, cfg: ControllerConfig) -> None:
        """Resize the warm caches per the config's LRU-bound overrides.
        In place (``LRUCache.resize``) because downstream state — e.g.
        ``mckp.HierState`` — holds references to the same cache objects."""
        for cache, bound in (
            (self._group_tables, cfg.max_group_tables),
            (self._agg_curves, cfg.max_agg_curves),
            (self._pick_cache, cfg.max_picks),
            (self._plan_cache, cfg.max_plans),
            (self._alloc_cache, cfg.max_allocations),
        ):
            if bound is not None:
                cache.resize(bound)

    # -- NACK pinning (DESIGN.md §18) ----------------------------------------

    def notify_actuation(self, report) -> None:
        """Pin NACKed receivers at their last-confirmed applied caps with
        exponential retry backoff: the first NACK retries next round, the
        k-th after ``min(2^(k-1), NACK_MAX_BACKOFF)`` rounds, and after
        ``NACK_MAX_RETRIES`` consecutive NACKs the controller stops
        re-commanding the receiver entirely (the pin holds until an event
        or ``invalidate`` touches the node).  While pinned, a receiver's
        commanded caps equal its applied caps, so the actuation layer acks
        it trivially — an ack clears the pin only once the backoff window
        has expired (``report.round >= until``), which is exactly the
        retry firing and succeeding."""
        r = int(report.round)
        self._pin_round = r
        for nm in report.nacked:
            p = self._pins.get(nm)
            fails = (p["fails"] if p is not None else 0) + 1
            if fails >= self.NACK_MAX_RETRIES:
                until = r + 10**9  # stop retrying: effectively forever
            else:
                until = r + min(2 ** (fails - 1), self.NACK_MAX_BACKOFF)
            applied = report.applied.get(nm)
            caps = (
                (float(applied[0]), float(applied[1]))
                if applied is not None
                else p["caps"]
            )
            self._pins[nm] = {"caps": caps, "fails": fails, "until": until}
        for nm in report.acked:
            p = self._pins.get(nm)
            if p is not None and r >= p["until"]:
                del self._pins[nm]

    def _active_pins(self) -> dict[str, tuple[float, float]]:
        """Pins that constrain the *next* round's solve."""
        if not self._pins:
            return {}
        nxt = self._pin_round + 1
        return {
            nm: p["caps"]
            for nm, p in self._pins.items()
            if nxt <= p["until"]
        }

    def _solve_pinned(
        self,
        batch: ReceiverBatch,
        budget: float,
        pins: Mapping[str, tuple[float, float]],
        domain_extra=None,
    ) -> Allocation:
        """Pinned-class solve: NACKed receivers hold their last-confirmed
        caps; everyone else solves over the *remaining* budget/headroom.

        The pinned extra is fitted to the current constraints first —
        proportionally derated to each domain's headroom
        (``PowerTopology.derate_factors``) and to the total budget — so
        the merged allocation always validates: a stuck actuator's
        *physical* overdraw is PowerGuard's to claw back, but the
        *commanded* allocation never plans a violation.  The free
        receivers re-solve through the ordinary grouped/hierarchical path
        on a standalone (seq=0) sub-batch, so headroom a pin doesn't use
        is redistributed rather than stranded, and the delta grouping
        state skips these rounds cleanly (it resyncs from the next
        engine-sequenced batch)."""
        names = batch.names
        pinned_idx = [i for i, nm in enumerate(names) if nm in pins]
        free_idx = [i for i, nm in enumerate(names) if nm not in pins]
        base = np.asarray(batch.baselines, dtype=np.float64)
        pbase = base[pinned_idx]
        pcaps = np.array(
            [pins[names[i]] for i in pinned_idx], dtype=np.float64
        ).reshape(len(pinned_idx), 2)
        # a pin never takes a receiver below its baseline allotment
        pcaps = np.maximum(pcaps, pbase)
        pextra = pcaps.sum(axis=1) - pbase.sum(axis=1)
        topo = getattr(self, "topology", None)
        dom = (
            np.asarray(batch.domain_ids)[pinned_idx]
            if batch.domain_ids is not None and len(pinned_idx)
            else None
        )
        scale = np.ones(len(pinned_idx))
        if domain_extra is not None and dom is not None and len(pinned_idx):
            leaf = np.zeros(len(topo), dtype=np.float64)
            leaf += np.bincount(dom, weights=pextra, minlength=len(topo))
            spend = topo.aggregate_leaves(leaf)
            scale = topo.derate_factors(
                spend, np.asarray(domain_extra, dtype=np.float64)
            )[dom]
        tot = float((pextra * scale).sum())
        if tot > budget + 1e-12 and tot > 0:
            scale = scale * (float(budget) / tot)
            tot = float((pextra * scale).sum())
        pcaps = pbase + scale[:, None] * (pcaps - pbase)
        pextra = pextra * scale

        free_budget = max(0.0, float(budget) - tot)
        free_extra = None
        if domain_extra is not None:
            free_extra = np.asarray(domain_extra, dtype=np.float64).copy()
            if dom is not None and len(pinned_idx):
                leaf = np.zeros(len(topo), dtype=np.float64)
                leaf += np.bincount(dom, weights=pextra, minlength=len(topo))
                free_extra = np.clip(
                    free_extra - topo.aggregate_leaves(leaf), 0.0, None
                )
        free = None
        if free_idx:
            sub = ReceiverBatch(
                names=[names[i] for i in free_idx],
                surface_ids=[batch.surface_ids[i] for i in free_idx],
                baselines=base[free_idx],
                surfaces=[batch.surfaces[i] for i in free_idx],
                domain_ids=(
                    np.asarray(batch.domain_ids)[free_idx]
                    if batch.domain_ids is not None
                    else None
                ),
                seq=0,
            )
            if domain_extra is not None:
                free = self.allocate_hierarchical(
                    sub, free_budget, free_extra, _skip_pins=True
                )
            else:
                free = self.allocate_grouped(sub, free_budget, _skip_pins=True)
        caps = dict(free.caps) if free is not None else {}
        for k, i in enumerate(pinned_idx):
            caps[names[i]] = (float(pcaps[k, 0]), float(pcaps[k, 1]))
        pinned_spent = float(pextra.sum())
        if domain_extra is not None:
            ds = dict(getattr(self, "last_domain_spent", None) or {})
            if dom is not None and len(pinned_idx):
                leaf = np.zeros(len(topo), dtype=np.float64)
                leaf += np.bincount(dom, weights=pextra, minlength=len(topo))
                for dn, w in zip(topo.names, topo.aggregate_leaves(leaf)):
                    if w:
                        ds[dn] = ds.get(dn, 0.0) + float(w)
            self.last_domain_spent = ds
        self.last_solver = "pinned"
        return Allocation(
            caps=caps,
            spent=(free.spent if free is not None else 0.0) + pinned_spent,
            predicted_improvement=(
                free.predicted_improvement if free is not None else 0.0
            ),
        )

    # -- snapshot / restore (DESIGN.md §18) ----------------------------------

    def snapshot(self) -> dict:
        snap = super().snapshot()
        snap["pins"] = {
            nm: {
                "caps": [float(p["caps"][0]), float(p["caps"][1])],
                "fails": int(p["fails"]),
                "until": int(p["until"]),
            }
            for nm, p in self._pins.items()
        }
        snap["pin_round"] = int(self._pin_round)
        return snap

    def restore(self, state: Mapping) -> None:
        super().restore(state)
        self.invalidate(None)  # restore is self-contained on a warm ctrl
        self._pins = {
            nm: {
                "caps": (float(p["caps"][0]), float(p["caps"][1])),
                "fails": int(p["fails"]),
                "until": int(p["until"]),
            }
            for nm, p in state.get("pins", {}).items()
        }
        self._pin_round = int(state.get("pin_round", -1))

    @property
    def cached_tables(self) -> int:
        return len(self._options) + len(self._group_tables)

    def _options_for(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        surfaces: Mapping[str, PowerSurface],
    ) -> list[OptionTable]:
        out = []
        for a in as_receiver_order(receivers):
            base = baselines[a.name]
            surf = surfaces[a.name]
            hit = self._options.get(a.name)
            if hit is not None and hit[0] == base and hit[1] is surf:
                out.append(hit[2])
                continue
            # budget-independent: enumerate to the grid headroom ceiling;
            # every solver skips options costing more than the round budget
            table = curves.build_options(
                a.name, surf, base, self.system.grid, np.inf
            )
            self._options[a.name] = (base, surf, table)
            out.append(table)
        return out

    def _group_table(
        self, surf: PowerSurface, base: tuple[float, float]
    ) -> OptionTable:
        key = (id(surf), base)
        hit = self._group_tables.get(key)
        if hit is not None and hit[0] is surf:
            return hit[1]
        table = curves.build_options("class", surf, base, self.system.grid, np.inf)
        self._group_tables[key] = (surf, table)
        return table

    def _grouped_options_for(
        self, batch: ReceiverBatch
    ) -> list[mckp.GroupedOptions]:
        """Collapse a receiver batch into behaviour-class groups.

        Group key is (surface identity, baseline): all members share one
        warm option table, built once per class instead of once per node.
        (Stale identity-keyed table entries age out of the LRU caches.)
        """
        return mckp.collapse_receivers(
            batch.names, batch.surfaces, batch.baselines, self._group_table
        )


@policies_mod.register_controller("ecoshift")
class EcoShiftController(_OptionCachingController):
    """MCKP DP on (predicted) surfaces with warm option tables.

    Optionally holds the NCF predictor handle (``allocator``) so predicted
    surfaces for arriving instances resolve without re-wiring callers.
    """

    policy = "ecoshift"

    def __init__(
        self,
        system: SystemSpec,
        *,
        config: ControllerConfig | None = None,
        solver: str | None = None,
        unit: float | None = None,
        allocator=None,
        grouped: bool | None = None,
        incremental: bool | None = None,
        fused: bool | None = None,
        horizon: int | None = None,
        eco_factor: float | None = None,
        plan_levels: int | None = None,
        plan_grid: int | None = None,
    ):
        super().__init__(system)
        cfg = (config if config is not None else ControllerConfig()).merged(
            solver=solver, unit=unit, allocator=allocator, grouped=grouped,
            incremental=incremental, fused=fused, horizon=horizon,
            eco_factor=eco_factor, plan_levels=plan_levels,
            plan_grid=plan_grid,
        )
        #: the resolved construction config (ControllerConfig)
        self.config = cfg
        self.solver = cfg.solver
        self.unit = cfg.unit
        #: optional repro.core.allocator.EcoShiftAllocator (warm NCF handle)
        self.allocator = cfg.allocator
        #: group-collapsed allocation (one DP super-stage per behaviour
        #: class); False forces the legacy per-instance path
        self.grouped = cfg.grouped
        #: delta-driven steady-state rounds (DESIGN.md §13): consume batch
        #: deltas into persistent grouping state, reuse cached solutions;
        #: False re-collapses and re-solves from scratch every round (the
        #: PR-4-style baseline the incremental_alloc bench compares against)
        self.incremental = cfg.incremental
        #: device-resident fused rounds (DESIGN.md §14/§17): keep option
        #: banks resident on device and run the whole warm-round decision
        #: pipeline as one jitted Pallas program.  Structure churn stays
        #: fused — rows patch or compact in place under the capacity-slack
        #: layout; only off-lattice keys / oversized grids / empty or
        #: infeasible rounds route to the host sparse path.  Requires
        #: ``incremental`` and ``solver='sparse'`` — otherwise silently
        #: ignored.
        self.fused = cfg.fused
        #: resident device banks + capacity-slack layout for fused rounds
        self._fused_state = mckp.FusedState()
        #: 'fused' | 'host' — which path produced the last solution
        self.last_solver: str | None = None
        #: why the last fused attempt routed to host ("" when it stayed
        #: fused, wasn't attempted, or hit the alloc cache) — mirrors
        #: ``FusedRoundStats.fallback_reason``
        self.last_fallback_reason: str = ""
        #: device seconds spent inside the last fused pipeline call (0.0
        #: for host rounds and alloc-cache hits)
        self.last_device_s: float = 0.0
        #: receding-horizon planning (DESIGN.md §15): plan length, weighted
        #: spend fraction, and DP bounds — planning is active only when
        #: horizon > 1 AND eco_factor < 1 AND the engine fed an outlook
        self.horizon = int(cfg.horizon)
        self.eco_factor = float(cfg.eco_factor)
        self.plan_levels = int(cfg.plan_levels)
        self.plan_grid = int(cfg.plan_grid)
        #: (caps, weights) forecast fed by the engine, consumed per round
        self._outlook: tuple | None = None
        #: (group tokens, cutoff) -> planning frontier arrays (flat path)
        self._frontier_lru = mckp.LRUCache(32)
        #: budget the planner committed for the last round (None = the
        #: plan did not restrict the round — myopic path taken verbatim)
        self.last_planned_budget: float | None = None
        #: full per-round spend plan behind last_planned_budget
        self.last_plan: tuple | None = None
        self._apply_cache_bounds(cfg)

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        super().invalidate(names)
        if names is None:
            self._fused_state.clear()
            self._frontier_lru.clear()

    def snapshot(self) -> dict:
        # fused banks / HierState / frontiers are rebuilt cold after a
        # restore (bit-for-bit certified vs warm); only the predictor's
        # online-learned state changes allocations and must serialize
        snap = super().snapshot()
        pred = getattr(self, "predictor", None)
        if pred is not None:
            snap["predictor"] = pred.state_dict()
        return snap

    def restore(self, state: Mapping) -> None:
        super().restore(state)
        pred = getattr(self, "predictor", None)
        if pred is not None and "predictor" in state:
            pred.load_state_dict(state["predictor"])

    def crash_reset(self) -> None:
        super().crash_reset()
        pred = getattr(self, "predictor", None)
        if pred is not None:
            pred.wipe()

    # -- receding-horizon planning (DESIGN.md §15) ---------------------------

    def set_budget_outlook(self, caps, weights=None) -> None:
        """Engine hook: the provider-backed budget forecast for the next
        ``len(caps)`` rounds (``caps[0]`` = this round's budget) plus the
        optional CO2/price weight signal.  Consumed by the next allocate
        call; refreshed by the engine every round."""
        self._outlook = (
            tuple(float(c) for c in caps),
            None if weights is None else tuple(float(w) for w in weights),
        )

    def _plan_pending(self) -> bool:
        return (
            self.horizon > 1
            and self.eco_factor < 1.0
            and self._outlook is not None
            and self.solver == "sparse"
        )

    def _plan_budget(self, budget: float, frontier_fn) -> float:
        """Run the horizon DP over this round's frontier; returns the
        budget to commit for round 0 (== ``budget`` whenever the plan
        would not restrict it — the caller then proceeds on the literally
        unchanged myopic path)."""
        self.last_planned_budget = None
        self.last_plan = None
        outlook, self._outlook = self._outlook, None
        caps, weights = outlook
        caps = caps[: self.horizon]
        if weights is not None:
            weights = weights[: self.horizon]
        # one frontier serves every horizon cap: states <= any cap are
        # identical whether the DP ran under that cap or under the larger
        # quantized cutoff (the _curve_cutoff invariance argument), so the
        # planning frontier is keyed budget-drift-invariantly
        cutoff = mckp._curve_cutoff(max(max(caps), float(budget)))
        keys, vals = frontier_fn(cutoff)
        plan = mckp.plan_horizon(
            keys, vals, caps, weights,
            eco_factor=self.eco_factor,
            levels=self.plan_levels,
            grid=self.plan_grid,
        )
        if plan is None:
            return budget
        b_eff = min(float(budget), float(plan[0]))
        if b_eff >= budget - 1e-9:
            return budget
        self.last_planned_budget = b_eff
        self.last_plan = tuple(plan)
        return b_eff

    def _planning_frontier(self, groups, cutoff: float):
        """Warm flat-path planning frontier (grouped super-stage DP end
        states), LRU-keyed by (group identity tokens, cutoff)."""
        key = (
            tuple(sorted(mckp._group_token(g) for g in groups)),
            mckp._qkey(cutoff),
        )
        hit = self._frontier_lru.get(key)
        if hit is None:
            hit = mckp.grouped_frontier(
                groups,
                cutoff,
                curve_cache=self._agg_curves,
                plan_cache=self._plan_cache,
                chain_cache=self._chain_cache,
            )
            self._frontier_lru[key] = hit
        return hit

    def fused_stats(self) -> FusedRoundStats:
        """Snapshot of the device-resident round counters."""
        return FusedRoundStats(**self._fused_state.stats)

    def fused_segments(self) -> dict:
        """Last fused round's wall-clock split (seconds): prep_s /
        patch_s / compact_s / dispatch_s / backtrack_s / assembly_s —
        the attribution table behind ``tools/profile_round.py --churn``.
        Empty until a fused round has been attempted."""
        return dict(self._fused_state.last_segments)

    def _try_fused_grouped(self, groups, budget) -> mckp.MCKPSolution | None:
        """One fused-round attempt; returns None to use the host path."""
        fstate = self._fused_state
        d0 = fstate.stats["device_s"]
        sol = mckp.solve_grouped_fused(
            groups,
            budget,
            fstate=fstate,
            curve_cache=self._agg_curves,
            pick_cache=self._pick_cache,
            plan_cache=self._plan_cache,
            chain_cache=self._chain_cache,
        )
        self.last_device_s = fstate.stats["device_s"] - d0
        return sol

    @property
    def supports_grouped(self) -> bool:  # type: ignore[override]
        return self.grouped

    def _solve(self, options, budget) -> mckp.MCKPSolution:
        if self.solver == "sparse":
            return mckp.solve_sparse(options, budget)
        if self.solver == "dense":
            return mckp.solve_dense(options, budget, unit=self.unit)
        if self.solver in ("jax", "pallas"):
            return mckp.solve_dense_jax(
                options, budget, unit=self.unit, backend=self.solver
            )
        raise ValueError(f"unknown solver {self.solver!r}")

    def allocate(self, receivers, baselines, budget, surfaces):
        options = self._options_for(receivers, baselines, surfaces)
        sol = self._solve(options, budget)
        return policies_mod.allocation_from_solution(
            sol, baselines, budget, self.system.grid
        )

    def _incremental_groups(self, batch: ReceiverBatch, leaf_ids=None):
        """Sync the persistent grouping with a batch (delta or rebuild)."""
        self._grouping.sync(batch, leaf_ids, self._group_table)

    def allocate_grouped(
        self, batch: ReceiverBatch, budget: float, _skip_pins: bool = False
    ) -> Allocation:
        """Group-collapsed round: receivers sharing (surface identity,
        baseline) solve as one multiplicity-m DP super-stage — parity with
        :meth:`allocate` is certified by tests/test_grouped_alloc.py.

        On the incremental path (default, sparse solver, engine-sequenced
        batches) the behaviour-class grouping is delta-maintained across
        rounds, the solve reuses content-keyed curve/pick/plan caches, and
        a round whose classes and budget are unchanged returns the cached
        Allocation outright — bit-for-bit what a from-scratch solve
        produces (tests/test_incremental_alloc.py)."""
        if not _skip_pins and self._pins:
            pins = self._active_pins()
            present = set(batch.names)
            pins = {nm: c for nm, c in pins.items() if nm in present}
            if pins:
                return self._solve_pinned(batch, budget, pins)
        incremental = (
            self.incremental
            and self.solver == "sparse"
            and getattr(batch, "seq", 0) != 0
        )
        if incremental:
            self._incremental_groups(batch)
            groups = self._grouping.groups(0)
        else:
            groups = self._grouped_options_for(batch)
        if self._plan_pending():
            budget = self._plan_budget(
                budget, lambda cap: self._planning_frontier(groups, cap)
            )
        if incremental:
            key = (
                tuple(sorted(mckp._group_token(g) for g in groups)),
                mckp._qkey(budget),
            )
            hit = self._alloc_cache.get(key)
            if hit is not None:
                self.last_solver = "cache"
                self.last_device_s = 0.0
                self.last_fallback_reason = ""
                return hit
        else:
            key = None
        sol = None
        self.last_device_s = 0.0
        self.last_fallback_reason = ""
        if incremental and self.fused:
            sol = self._try_fused_grouped(groups, budget)
            if sol is None:
                self.last_fallback_reason = self._fused_state.stats.get(
                    "fallback_reason", ""
                )
        self.last_solver = "fused" if sol is not None else "host"
        if sol is None:
            sol = mckp.solve_grouped(
                groups,
                budget,
                solver=self.solver,
                unit=self.unit,
                curve_cache=self._agg_curves,
                pick_cache=self._pick_cache if incremental else None,
                plan_cache=self._plan_cache if incremental else None,
                chain_cache=self._chain_cache if incremental else None,
            )
        alloc = policies_mod.allocation_from_solution(
            sol, batch.baselines_map(), budget, self.system.grid
        )
        if key is not None:
            self._alloc_cache[key] = alloc
        return alloc

    def allocate_batch(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        budgets: Sequence[float],
        surfaces: Mapping[str, PowerSurface],
    ) -> list[Allocation]:
        """Solve one receiver set under many budgets in a single vmapped
        dense DP (option tables cached once, one accelerator dispatch).

        Always solves on the dense ``unit``-watt budget grid regardless of
        ``self.solver`` — with fractional option costs the unit rounding can
        pick slightly different caps than a ``solver='sparse'``
        :meth:`allocate` call at the same budget."""
        options = self._options_for(receivers, baselines, surfaces)
        backend = self.solver if self.solver in ("jax", "pallas") else "jax"
        sols = mckp.solve_dense_jax_batch(
            [options] * len(budgets),
            list(budgets),
            unit=self.unit,
            backend=backend,
        )
        return [
            policies_mod.allocation_from_solution(
                sol, baselines, budget, self.system.grid
            )
            for budget, sol in zip(budgets, sols)
        ]


@policies_mod.register_controller("ecoshift_hier")
class EcoShiftHierController(EcoShiftController):
    """Topology-aware EcoShift: two-level capped-frontier MCKP (DESIGN.md §12).

    The engine hands this controller a columnar receiver batch *with leaf
    domain ids* plus the round's per-domain extra-power headroom; receivers
    collapse into behaviour classes **within each leaf domain** (same warm
    identity-keyed group tables as the flat path), each leaf's class DP
    becomes a capped value-vs-spend frontier, and the upper-level DP splits
    the cluster budget across domains (``mckp.solve_hierarchical``).

    Warm state (``solver='sparse'``, the default): the shared
    aggregate-curve cache plus a **frontier cache** keyed by (per-class
    digest+multiplicity layout, quantized budget) — both content-keyed, so
    telemetry-driven surface swaps invalidate implicitly (a swapped
    surface digests differently and the stale entry stops matching).  The
    dense ``'jax'``/``'pallas'`` path recomputes its layouts per round
    (the warm tables still apply).  Passing ``predictor`` sources every
    receiver surface
    from a telemetry-driven :class:`~repro.cluster.predictor
    .OnlinePredictor` exactly like ``ecoshift_online``.
    """

    policy = "ecoshift_hier"
    supports_hierarchical = True

    #: LRU bound of the leaf-frontier cache (satellite of DESIGN.md §13)
    MAX_FRONTIERS = 512

    def __init__(
        self,
        system: SystemSpec,
        *,
        config: ControllerConfig | None = None,
        topology=None,
        solver: str | None = None,
        unit: float | None = None,
        predictor=None,
        allocator=None,
        incremental: bool | None = None,
        fused: bool | None = None,
        horizon: int | None = None,
        eco_factor: float | None = None,
        plan_levels: int | None = None,
        plan_grid: int | None = None,
    ):
        cfg = (config if config is not None else ControllerConfig()).merged(
            topology=topology, solver=solver, unit=unit, predictor=predictor,
            allocator=allocator, incremental=incremental, fused=fused,
            horizon=horizon, eco_factor=eco_factor, plan_levels=plan_levels,
            plan_grid=plan_grid,
        )
        super().__init__(system, config=cfg)
        #: repro.core.topology.PowerTopology (bound here or by the engine)
        self.topology = cfg.topology
        #: optional OnlinePredictor: serve predicted surfaces + ingest telemetry
        self.predictor = cfg.predictor
        #: (class layout, quantized budget) -> leaf frontier DP arrays
        self._frontiers: mckp.LRUCache = mckp.LRUCache(self.MAX_FRONTIERS)
        #: persistent hierarchical warm state: frontier aggregation tree
        #: combines, pick multisets, leaf solutions, merged-class plans —
        #: all content-keyed and LRU-bounded (mckp.HierState)
        if cfg.max_frontiers is not None:
            self._frontiers.resize(cfg.max_frontiers)
        self._hier_state = mckp.HierState(
            curve_cache=self._agg_curves,
            frontier_cache=self._frontiers,
            chain_cache=self._chain_cache,
            pick_cache=self._pick_cache,
            plan_cache=self._plan_cache,
            max_leaf_solutions=128,
        )
        #: per-domain watts spent by the latest hierarchical solve
        self.last_domain_spent: dict[str, float] | None = None

    @property
    def serves_own_surfaces(self) -> bool:
        return self.predictor is not None

    def bind_topology(self, topology) -> None:
        """Attach (or swap) the domain tree; a swap drops warm state."""
        if self.topology is not None and self.topology is not topology:
            self.invalidate()
        self.topology = topology

    def _served_batch(self, batch: ReceiverBatch) -> ReceiverBatch:
        if self.predictor is None:
            return batch
        served = [
            self.predictor.surface_for(name, sid)
            for name, sid in zip(batch.names, batch.surface_ids)
        ]
        return _served_replace(batch, served)

    _NO_TOPOLOGY = (
        "ecoshift_hier allocates per power domain — attach a PowerTopology "
        "to the sim/scenario, or use 'ecoshift' for flat allocation"
    )

    def allocate(self, receivers, baselines, budget, surfaces):
        # reached only when the engine has no topology attached: a silent
        # flat fallback under the hier name would be a footgun
        raise ValueError(self._NO_TOPOLOGY)

    def allocate_grouped(self, batch: ReceiverBatch, budget: float):
        raise ValueError(self._NO_TOPOLOGY)

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        super().invalidate(names)
        if names is None:
            self._frontiers.clear()
            self._hier_state.clear()

    def _grouped_options_by_leaf(
        self, batch: ReceiverBatch
    ) -> dict[int, list[mckp.GroupedOptions]]:
        """Per-leaf-domain behaviour-class collapse over the warm tables."""
        by_leaf: dict[int, list[mckp.GroupedOptions]] = {}
        leaf_ids = np.asarray(batch.domain_ids)
        for leaf in np.unique(leaf_ids):
            ii = np.flatnonzero(leaf_ids == leaf)
            by_leaf[int(leaf)] = mckp.collapse_receivers(
                [batch.names[i] for i in ii],
                [batch.surfaces[i] for i in ii],
                batch.baselines[ii],
                self._group_table,
            )
        return by_leaf

    def allocate_hierarchical(
        self,
        batch: ReceiverBatch,
        budget: float,
        domain_extra: np.ndarray,
        _skip_pins: bool = False,
    ) -> Allocation:
        """One topology-aware round: per-domain capped frontiers + the
        upper-level budget-split DP through the frontier aggregation tree.
        ``domain_extra`` is the per-domain extra-power headroom (preorder
        ids, caps net of committed draw).

        Incremental path (default, sparse solver): the per-leaf grouping is
        delta-maintained from the batch, unchanged leaves reuse their
        frontier DPs / assembled solutions, dirty leaves re-aggregate
        through O(log n_leaves) tree combines, and a round whose classes,
        budget and headroom are all unchanged returns the cached
        Allocation — always bit-for-bit the from-scratch solve."""
        if self.topology is None:
            raise ValueError("ecoshift_hier needs a bound PowerTopology")
        if batch.domain_ids is None:
            raise ValueError("receiver batch carries no domain ids")
        batch = self._served_batch(batch)
        if not _skip_pins and self._pins:
            pins = self._active_pins()
            present = set(batch.names)
            pins = {nm: c for nm, c in pins.items() if nm in present}
            if pins:
                self.last_domain_spent = {}
                return self._solve_pinned(
                    batch, budget, pins, domain_extra=domain_extra
                )
        incremental = (
            self.incremental
            and self.solver == "sparse"
            and getattr(batch, "seq", 0) != 0
        )
        state = None
        key = None
        if incremental:
            self._incremental_groups(
                batch, leaf_ids=np.asarray(batch.domain_ids)
            )
            by_leaf = self._grouping.by_scope()
            state = self._hier_state
        else:
            by_leaf = self._grouped_options_by_leaf(batch)
        root = None
        if self._plan_pending():
            # the root frontier under the quantized cutoff serves every
            # horizon cap; the primed leaf frontiers and tree combines are
            # the same warm HierState entries the solve below reuses
            root = policies_mod.domain_tree(self.topology, domain_extra, by_leaf)
            budget = self._plan_budget(
                budget,
                lambda cap: mckp.hierarchical_frontier(
                    root, cap, state=self._hier_state
                ),
            )
        if incremental:
            key = (
                tuple(
                    (leaf, tuple(sorted(mckp._group_token(g) for g in groups)))
                    for leaf, groups in sorted(by_leaf.items())
                ),
                mckp._qkey(budget),
                np.asarray(domain_extra).tobytes(),
            )
            hit = self._alloc_cache.get(key)
            if hit is not None:
                self.last_domain_spent = hit[1]
                self.last_solver = "cache"
                self.last_device_s = 0.0
                self.last_fallback_reason = ""
                return hit[0]
        if root is None:
            root = policies_mod.domain_tree(self.topology, domain_extra, by_leaf)
        sol = None
        self.last_device_s = 0.0
        self.last_fallback_reason = ""
        if incremental and self.fused:
            fstate = self._fused_state
            d0 = fstate.stats["device_s"]
            sol = mckp.solve_hierarchical_fused(
                root, budget, state=self._hier_state, fstate=fstate
            )
            self.last_device_s = fstate.stats["device_s"] - d0
            if sol is None:
                self.last_fallback_reason = fstate.stats.get(
                    "fallback_reason", ""
                )
        self.last_solver = "fused" if sol is not None else "host"
        if sol is None:
            sol = mckp.solve_hierarchical(
                root,
                budget,
                solver=self.solver,
                unit=self.unit,
                curve_cache=self._agg_curves,
                frontier_cache=self._frontiers,
                state=state,
            )
        self.last_domain_spent = sol.domain_spent
        alloc = policies_mod.allocation_from_solution(
            sol, batch.baselines_map(), budget, self.system.grid
        )
        if key is not None:
            self._alloc_cache[key] = (alloc, sol.domain_spent)
        return alloc

    def ingest_telemetry(self, records) -> None:
        if self.predictor is not None:
            self.predictor.observe(records)
            self.predictor.refresh()


@policies_mod.register_controller("ecoshift_online", pure=False)
class EcoShiftOnlineController(EcoShiftController):
    """EcoShift with a telemetry-driven online predictor as surface source.

    Ignores the ``surfaces`` mapping the engine passes to ``allocate`` —
    every receiver's surface comes from the attached
    :class:`~repro.cluster.predictor.OnlinePredictor` (population prior
    for cold-start apps).  After each measured round the engine feeds the
    telemetry back via :meth:`ingest_telemetry` and the predictor
    refreshes the apps whose telemetry warrants it.  Cache invalidation
    is implicit: the warm option cache is keyed by surface *identity*
    (``_OptionCachingController._options_for``), and the predictor swaps
    a surface object only on tolerance-exceeding moves — so re-solves
    stay warm exactly while predictions are stable, with no extra
    bookkeeping here.
    """

    policy = "ecoshift_online"
    #: the engine skips filling ReceiverBatch.surfaces: every surface
    #: comes from the predictor, and ground truth must not transit here
    serves_own_surfaces = True

    def __init__(
        self,
        system: SystemSpec,
        *,
        predictor=None,
        config: ControllerConfig | None = None,
        solver: str | None = None,
        unit: float | None = None,
    ):
        cfg = (config if config is not None else ControllerConfig()).merged(
            predictor=predictor, solver=solver, unit=unit
        )
        if cfg.predictor is None:
            raise ValueError("ecoshift_online needs a predictor")
        super().__init__(system, config=cfg)
        #: repro.cluster.predictor.OnlinePredictor (required)
        self.predictor = cfg.predictor

    def allocate(self, receivers, baselines, budget, surfaces=None):
        seen = {
            a.name: self.predictor.surface_for(a.name, a.surface_id)
            for a in receivers
        }
        return super().allocate(receivers, baselines, budget, seen)

    def allocate_grouped(
        self, batch: ReceiverBatch, budget: float, _skip_pins: bool = False
    ):
        served = [
            self.predictor.surface_for(name, sid)
            for name, sid in zip(batch.names, batch.surface_ids)
        ]
        return super().allocate_grouped(
            _served_replace(batch, served), budget, _skip_pins=_skip_pins
        )

    def ingest_telemetry(self, records) -> None:
        self.predictor.observe(records)
        self.predictor.refresh()


@policies_mod.register_controller("oracle")
class OracleController(_OptionCachingController):
    """Exhaustive/DP optimum on true surfaces (``sees_truth``)."""

    policy = "oracle"
    sees_truth = True
    supports_grouped = True

    def __init__(
        self,
        system: SystemSpec,
        *,
        exhaustive: bool | None = None,
        config: ControllerConfig | None = None,
    ):
        super().__init__(system)
        cfg = (config if config is not None else ControllerConfig()).merged(
            exhaustive=exhaustive
        )
        self.config = cfg
        #: None = auto (brute force iff <= 10 receivers, like run_round)
        self.exhaustive = cfg.exhaustive
        self._apply_cache_bounds(cfg)

    def allocate(self, receivers, baselines, budget, surfaces):
        options = self._options_for(receivers, baselines, surfaces)
        exhaustive = (
            len(receivers) <= 10 if self.exhaustive is None else self.exhaustive
        )
        sol = (
            mckp.brute_force(options, budget)
            if exhaustive
            else mckp.solve_sparse(options, budget)
        )
        return policies_mod.allocation_from_solution(
            sol, baselines, budget, self.system.grid
        )

    def allocate_grouped(
        self, batch: ReceiverBatch, budget: float, _skip_pins: bool = False
    ) -> Allocation:
        if not _skip_pins and self._pins:
            pins = self._active_pins()
            present = set(batch.names)
            pins = {nm: c for nm, c in pins.items() if nm in present}
            if pins:
                return self._solve_pinned(batch, budget, pins)
        groups = self._grouped_options_for(batch)
        exhaustive = (
            len(batch) <= 10 if self.exhaustive is None else self.exhaustive
        )
        sol = (
            mckp.brute_force(mckp.expand_groups(groups), budget)
            if exhaustive
            else mckp.solve_sparse_grouped(
                groups, budget, curve_cache=self._agg_curves
            )
        )
        return policies_mod.allocation_from_solution(
            sol, batch.baselines_map(), budget, self.system.grid
        )


def make_controller(policy: str, system: SystemSpec, **kwargs) -> Controller:
    """Instantiate a registered controller by policy name."""
    return policies_mod.get_controller(policy, system, **kwargs)


# ---------------------------------------------------------------------------
# Snapshot persistence (DESIGN.md §18)
# ---------------------------------------------------------------------------


def _pack(obj):
    """Encode a snapshot tree for msgpack: ndarrays as tagged
    dtype/shape/bytes, tuples and non-str-keyed dicts as tagged lists
    (msgpack has neither).  Inverse of :func:`_unpack`; numpy float64 and
    msgpack doubles round-trip exactly, so file round-trips keep the
    bit-for-bit restore contract."""
    if isinstance(obj, np.ndarray):
        return {
            "__nd__": True,
            "dtype": str(obj.dtype),
            "shape": list(obj.shape),
            "data": obj.tobytes(),
        }
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, tuple):
        return {"__tup__": [_pack(v) for v in obj]}
    if isinstance(obj, list):
        return [_pack(v) for v in obj]
    if isinstance(obj, dict):
        if all(isinstance(k, str) for k in obj):
            return {k: _pack(v) for k, v in obj.items()}
        return {"__map__": [[_pack(k), _pack(v)] for k, v in obj.items()]}
    return obj


def _unpack(obj):
    if isinstance(obj, dict):
        if obj.get("__nd__"):
            return (
                np.frombuffer(obj["data"], dtype=obj["dtype"])
                .reshape(obj["shape"])
                .copy()
            )
        if "__tup__" in obj:
            return tuple(_unpack(v) for v in obj["__tup__"])
        if "__map__" in obj:
            return {_unpack(k): _unpack(v) for k, v in obj["__map__"]}
        return {k: _unpack(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_unpack(v) for v in obj]
    return obj


def save_snapshot(path: str, snap: Mapping) -> None:
    """Persist a ``Controller.snapshot()`` crash-safely.

    Same atomic-write discipline as ``repro.train.checkpoint``: write to a
    sibling temp file, flush + fsync, then ``os.replace`` — a crash
    mid-write leaves the previous snapshot intact, never a torn file."""
    import os

    import msgpack

    blob = msgpack.packb(_pack(dict(snap)), use_bin_type=True)
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_snapshot(path: str) -> dict:
    """Read a snapshot written by :func:`save_snapshot` (feed the result
    to ``Controller.restore``)."""
    import msgpack

    with open(path, "rb") as f:
        return _unpack(msgpack.unpackb(f.read(), raw=False))
