"""Stateful policy controllers for the multi-round cluster engine.

One controller per entry in ``repro.core.policies.POLICIES``.  Each wraps
the existing pure policy function but *carries warm state across rounds*:

 * ``EcoShiftController`` / ``OracleController`` cache per-receiver
   ``OptionTable``s keyed by (instance, baseline, surface identity).  The
   tables are budget-independent (built to the grid's headroom ceiling; all
   MCKP solvers already skip over-budget options), so after a node failure
   only the *pool* changes and re-optimization reuses every surviving
   table — the incremental re-solve the paper's fault-tolerance study
   needs.  Event hooks (``invalidate``) drop entries whose surface or
   baseline changed (stragglers, phase changes).
 * ``EcoShiftOnlineController`` closes the prediction loop: it sources its
   surfaces from a telemetry-driven ``repro.cluster.predictor
   .OnlinePredictor`` instead of a frozen mapping, ingests each round's
   measurements via ``ingest_telemetry``, and invalidates warm option
   tables only for instances whose served surface actually moved beyond
   the predictor's tolerance.
 * heuristic controllers (uniform / DPS / MixedAdaptive) are stateless
   wrappers, registered for a uniform interface.

Controllers register themselves into ``policies.CONTROLLERS`` so the
registry lives beside ``POLICIES`` (``policies.get_controller``).
Controller-only policies (``ecoshift_online``) have no pure-function
counterpart in ``POLICIES`` — the online phase is inherently stateful.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.core import curves, mckp
from repro.core import policies as policies_mod
from repro.core.curves import OptionTable
from repro.core.surfaces import PowerSurface
from repro.core.types import (
    Allocation,
    AppSpec,
    SystemSpec,
    as_receiver_order,
    validate_allocation,
)


class Controller:
    """Base: a policy with per-round ``allocate`` plus warm-state hooks."""

    #: key into ``POLICIES`` / the legacy ``run_round`` name
    policy: str = ""
    #: True for policies that always see ground-truth surfaces (Oracle)
    sees_truth: bool = False

    def __init__(self, system: SystemSpec):
        self.system = system

    def allocate(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        budget: float,
        surfaces: Mapping[str, PowerSurface],
    ) -> Allocation:
        raise NotImplementedError

    # -- warm-state hooks ----------------------------------------------------

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        """Drop cached per-receiver state (``None`` = everything)."""

    def ingest_telemetry(self, records: Sequence) -> None:
        """Consume one round's noisy measurements
        (:class:`repro.cluster.predictor.TelemetryRecord`).  The engine
        calls this after every measured round; predictor-backed
        controllers refresh their surfaces here, everyone else ignores
        it."""

    def reset(self) -> None:
        self.invalidate()


class _StatelessController(Controller):
    """Wraps a pure policy function; nothing carries across rounds."""

    def allocate(self, receivers, baselines, budget, surfaces):
        fn = policies_mod.POLICIES[self.policy]
        return fn(receivers, baselines, budget, self.system, surfaces)


@policies_mod.register_controller("uniform")
class UniformController(_StatelessController):
    policy = "uniform"


@policies_mod.register_controller("dps")
class DPSController(_StatelessController):
    policy = "dps"


@policies_mod.register_controller("mixed_adaptive")
class MixedAdaptiveController(_StatelessController):
    policy = "mixed_adaptive"


class _OptionCachingController(Controller):
    """Shared warm ``OptionTable`` cache for the DP-based policies."""

    def __init__(self, system: SystemSpec):
        super().__init__(system)
        #: name -> (baseline, surface, table); surface compared by identity
        self._options: dict[
            str, tuple[tuple[float, float], PowerSurface, OptionTable]
        ] = {}

    def invalidate(self, names: Sequence[str] | None = None) -> None:
        if names is None:
            self._options.clear()
        else:
            for n in names:
                self._options.pop(n, None)

    @property
    def cached_tables(self) -> int:
        return len(self._options)

    def _options_for(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        surfaces: Mapping[str, PowerSurface],
    ) -> list[OptionTable]:
        out = []
        for a in as_receiver_order(receivers):
            base = baselines[a.name]
            surf = surfaces[a.name]
            hit = self._options.get(a.name)
            if hit is not None and hit[0] == base and hit[1] is surf:
                out.append(hit[2])
                continue
            # budget-independent: enumerate to the grid headroom ceiling;
            # every solver skips options costing more than the round budget
            table = curves.build_options(
                a.name, surf, base, self.system.grid, np.inf
            )
            self._options[a.name] = (base, surf, table)
            out.append(table)
        return out


@policies_mod.register_controller("ecoshift")
class EcoShiftController(_OptionCachingController):
    """MCKP DP on (predicted) surfaces with warm option tables.

    Optionally holds the NCF predictor handle (``allocator``) so predicted
    surfaces for arriving instances resolve without re-wiring callers.
    """

    policy = "ecoshift"

    def __init__(
        self,
        system: SystemSpec,
        *,
        solver: str = "sparse",
        unit: float = 1.0,
        allocator=None,
    ):
        super().__init__(system)
        self.solver = solver
        self.unit = unit
        #: optional repro.core.allocator.EcoShiftAllocator (warm NCF handle)
        self.allocator = allocator

    def _solve(self, options, budget) -> mckp.MCKPSolution:
        if self.solver == "sparse":
            return mckp.solve_sparse(options, budget)
        if self.solver == "dense":
            return mckp.solve_dense(options, budget, unit=self.unit)
        if self.solver in ("jax", "pallas"):
            return mckp.solve_dense_jax(
                options, budget, unit=self.unit, backend=self.solver
            )
        raise ValueError(f"unknown solver {self.solver!r}")

    def allocate(self, receivers, baselines, budget, surfaces):
        options = self._options_for(receivers, baselines, surfaces)
        sol = self._solve(options, budget)
        caps = {name: pick[2] for name, pick in sol.picks.items()}
        alloc = Allocation(
            caps=caps,
            spent=sol.spent,
            predicted_improvement=sol.average_improvement(),
        )
        validate_allocation(alloc, baselines, budget, self.system.grid)
        return alloc

    def allocate_batch(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        budgets: Sequence[float],
        surfaces: Mapping[str, PowerSurface],
    ) -> list[Allocation]:
        """Solve one receiver set under many budgets in a single vmapped
        dense DP (option tables cached once, one accelerator dispatch).

        Always solves on the dense ``unit``-watt budget grid regardless of
        ``self.solver`` — with fractional option costs the unit rounding can
        pick slightly different caps than a ``solver='sparse'``
        :meth:`allocate` call at the same budget."""
        options = self._options_for(receivers, baselines, surfaces)
        backend = self.solver if self.solver in ("jax", "pallas") else "jax"
        sols = mckp.solve_dense_jax_batch(
            [options] * len(budgets),
            list(budgets),
            unit=self.unit,
            backend=backend,
        )
        allocs = []
        for budget, sol in zip(budgets, sols):
            caps = {name: pick[2] for name, pick in sol.picks.items()}
            alloc = Allocation(
                caps=caps,
                spent=sol.spent,
                predicted_improvement=sol.average_improvement(),
            )
            validate_allocation(alloc, baselines, budget, self.system.grid)
            allocs.append(alloc)
        return allocs


@policies_mod.register_controller("ecoshift_online", pure=False)
class EcoShiftOnlineController(EcoShiftController):
    """EcoShift with a telemetry-driven online predictor as surface source.

    Ignores the ``surfaces`` mapping the engine passes to ``allocate`` —
    every receiver's surface comes from the attached
    :class:`~repro.cluster.predictor.OnlinePredictor` (population prior
    for cold-start apps).  After each measured round the engine feeds the
    telemetry back via :meth:`ingest_telemetry` and the predictor
    refreshes the apps whose telemetry warrants it.  Cache invalidation
    is implicit: the warm option cache is keyed by surface *identity*
    (``_OptionCachingController._options_for``), and the predictor swaps
    a surface object only on tolerance-exceeding moves — so re-solves
    stay warm exactly while predictions are stable, with no extra
    bookkeeping here.
    """

    policy = "ecoshift_online"

    def __init__(
        self,
        system: SystemSpec,
        *,
        predictor,
        solver: str = "sparse",
        unit: float = 1.0,
    ):
        super().__init__(system, solver=solver, unit=unit)
        #: repro.cluster.predictor.OnlinePredictor (required)
        self.predictor = predictor

    def allocate(self, receivers, baselines, budget, surfaces=None):
        seen = {
            a.name: self.predictor.surface_for(a.name, a.surface_id)
            for a in receivers
        }
        return super().allocate(receivers, baselines, budget, seen)

    def ingest_telemetry(self, records) -> None:
        self.predictor.observe(records)
        self.predictor.refresh()


@policies_mod.register_controller("oracle")
class OracleController(_OptionCachingController):
    """Exhaustive/DP optimum on true surfaces (``sees_truth``)."""

    policy = "oracle"
    sees_truth = True

    def __init__(self, system: SystemSpec, *, exhaustive: bool | None = None):
        super().__init__(system)
        #: None = auto (brute force iff <= 10 receivers, like run_round)
        self.exhaustive = exhaustive

    def allocate(self, receivers, baselines, budget, surfaces):
        options = self._options_for(receivers, baselines, surfaces)
        exhaustive = (
            len(receivers) <= 10 if self.exhaustive is None else self.exhaustive
        )
        sol = (
            mckp.brute_force(options, budget)
            if exhaustive
            else mckp.solve_sparse(options, budget)
        )
        caps = {name: pick[2] for name, pick in sol.picks.items()}
        alloc = Allocation(
            caps=caps,
            spent=sol.spent,
            predicted_improvement=sol.average_improvement(),
        )
        validate_allocation(alloc, baselines, budget, self.system.grid)
        return alloc


def make_controller(policy: str, system: SystemSpec, **kwargs) -> Controller:
    """Instantiate a registered controller by policy name."""
    return policies_mod.get_controller(policy, system, **kwargs)
