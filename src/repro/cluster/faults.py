"""Declarative, seeded fault injection for cluster scenarios (DESIGN.md §18).

EcoShift's control loop assumes a perfect world: every cap the allocator
emits is applied instantly and exactly, every telemetry record arrives
intact, and the controller's warm state lives forever.  This module makes
the imperfect world *declarative*: fault events compose into any
:class:`~repro.cluster.scenario.Scenario` via ``with_faults`` /
``with_fault_storm`` and the engine's :class:`FaultInjector` resolves them
per round against three channels:

 * **telemetry** — whole-round batch drops, delayed delivery, stale
   repeats of an earlier round's batch, and seeded record corruption
   (NaN / inf / outlier / negative runtimes);
 * **actuation** — cap-apply NACKs (a node keeps its previously applied
   caps), partial application (the actuator moves only a fraction of the
   way from its current state to the command) and one-round delayed
   application (the command lands next round, displacing that round's);
 * **controller** — a crash that wipes all warm state mid-run, optionally
   restored from the last end-of-round ``Controller.snapshot()``.

Fault events are plain frozen dataclasses: a scenario with faults is
still a pure value, replayable bit-for-bit under any controller.  All
randomness (storm sampling, corruption targets, fraction-based actuation
targets) flows from explicit seeds — the same seed always produces the
same storm.

The recovery machinery lives on the other side: the engine's PowerGuard
watchdog (``cluster/sim.py``), controller NACK pinning and
snapshot/restore (``cluster/controller.py``), and the robust telemetry
ingest (``cluster/predictor.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import numpy as np

#: recognized record-corruption modes (TelemetryCorrupt.mode)
CORRUPT_MODES = ("nan", "inf", "outlier", "negative")

#: multiplicative runtime blow-up of the "outlier" corruption mode —
#: finite and positive, so only physical-plausibility checks catch it
OUTLIER_FACTOR = 1e3


# ---------------------------------------------------------------------------
# Fault events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetryDrop:
    """The whole telemetry batch of ``round`` is lost in transit."""

    round: int


@dataclasses.dataclass(frozen=True)
class TelemetryDelay:
    """The batch of ``round`` arrives ``rounds`` rounds late (delivered
    alongside that later round's own telemetry)."""

    round: int
    rounds: int = 1


@dataclasses.dataclass(frozen=True)
class TelemetryCorrupt:
    """A seeded ``fraction`` of ``round``'s records is corrupted.

    Modes: ``"nan"`` / ``"inf"`` poison the measured runtimes with
    non-finite values, ``"outlier"`` blows the allocated-caps runtime up
    by :data:`OUTLIER_FACTOR` (finite but physically impossible), and
    ``"negative"`` flips it negative.  The ``improvement`` column is
    recomputed from the corrupted runtimes, so the corruption is
    internally consistent — exactly what a broken meter produces.
    """

    round: int
    fraction: float = 0.25
    mode: str = "nan"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TelemetryStale:
    """Round ``round``'s batch is displaced by a stale repeat of the batch
    measured ``age`` rounds earlier (this round's real batch is lost)."""

    round: int
    age: int = 1


@dataclasses.dataclass(frozen=True)
class ActuationNack:
    """Cap-apply NACK: the targeted receivers keep their previously
    applied caps this round.  Targets are explicit ``node_ids`` or a
    seeded ``fraction`` of the round's receivers."""

    round: int
    node_ids: tuple[int, ...] = ()
    fraction: float = 0.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ActuationPartial:
    """Partial application: the actuator moves only ``applied_fraction``
    of the way from its current caps toward the commanded caps."""

    round: int
    node_ids: tuple[int, ...] = ()
    fraction: float = 0.0
    seed: int = 0
    applied_fraction: float = 0.5


@dataclasses.dataclass(frozen=True)
class ActuationDelay:
    """One-round delayed application: nothing lands this round; the
    command lands next round, displacing that round's own command for the
    targeted receivers."""

    round: int
    node_ids: tuple[int, ...] = ()
    fraction: float = 0.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ControllerCrash:
    """The controller process dies at the start of ``round``: every piece
    of warm state (caches, grouping, fused banks, pins, online-learned
    predictor state) is wiped.  With ``restore`` the replacement process
    restores the last end-of-round snapshot before taking over."""

    round: int
    restore: bool = True


FaultEvent = Union[
    TelemetryDrop,
    TelemetryDelay,
    TelemetryCorrupt,
    TelemetryStale,
    ActuationNack,
    ActuationPartial,
    ActuationDelay,
    ControllerCrash,
]

_TELEMETRY = (TelemetryDrop, TelemetryDelay, TelemetryCorrupt, TelemetryStale)
_ACTUATION = (ActuationNack, ActuationPartial, ActuationDelay)


def validate_faults(faults: Sequence, n_rounds: int) -> None:
    """Build-time fail-fast for ``Scenario.with_faults``."""
    for ev in faults:
        if not isinstance(ev, FaultEvent.__args__):
            known = ", ".join(c.__name__ for c in FaultEvent.__args__)
            raise TypeError(
                f"unknown fault event type {type(ev).__name__!r} "
                f"(expected one of: {known})"
            )
        if not 0 <= ev.round < n_rounds:
            raise ValueError(
                f"{type(ev).__name__} round {ev.round} outside "
                f"[0, {n_rounds})"
            )
        if isinstance(ev, TelemetryCorrupt):
            if ev.mode not in CORRUPT_MODES:
                raise ValueError(
                    f"unknown corruption mode {ev.mode!r} "
                    f"(expected one of {CORRUPT_MODES})"
                )
            if not 0.0 < ev.fraction <= 1.0:
                raise ValueError(
                    f"corrupt fraction {ev.fraction} outside (0, 1]"
                )
        if isinstance(ev, _ACTUATION):
            if not 0.0 <= ev.fraction <= 1.0:
                raise ValueError(
                    f"actuation fraction {ev.fraction} outside [0, 1]"
                )
            if not ev.node_ids and ev.fraction == 0.0:
                raise ValueError(
                    f"{type(ev).__name__} at round {ev.round} targets "
                    f"nothing: pass node_ids or fraction > 0"
                )
        if isinstance(ev, ActuationPartial) and not (
            0.0 <= ev.applied_fraction <= 1.0
        ):
            raise ValueError(
                f"applied_fraction {ev.applied_fraction} outside [0, 1]"
            )
        if isinstance(ev, TelemetryDelay) and ev.rounds < 1:
            raise ValueError("telemetry delay must be >= 1 round")
        if isinstance(ev, TelemetryStale) and ev.age < 1:
            raise ValueError("stale age must be >= 1 round")


def fault_storm(
    n_rounds: int,
    seed: int = 0,
    *,
    telemetry_drop: float = 0.0,
    telemetry_delay: float = 0.0,
    telemetry_corrupt: float = 0.0,
    corrupt_fraction: float = 0.25,
    telemetry_stale: float = 0.0,
    actuation_nack: float = 0.0,
    actuation_partial: float = 0.0,
    actuation_delay: float = 0.0,
    node_fraction: float = 0.2,
    crash_rounds: Sequence[int] = (),
    restore: bool = True,
    start_round: int = 1,
) -> tuple:
    """Sample a randomized fault storm: per round, each channel fires
    independently with its given probability.  Fully determined by
    ``seed`` — the same seed always yields the same event list.

    Rate arguments are per-round probabilities; ``corrupt_fraction`` /
    ``node_fraction`` size each fired event.  ``start_round`` keeps the
    first round(s) clean so the run establishes a healthy baseline.
    Explicit ``crash_rounds`` add :class:`ControllerCrash` events.
    """
    rng = np.random.default_rng(seed)
    events: list = []
    modes = CORRUPT_MODES
    for r in range(start_round, n_rounds):
        u = rng.random(6)
        sub = int(rng.integers(0, 2**31 - 1))
        if u[0] < telemetry_drop:
            events.append(TelemetryDrop(round=r))
        if u[1] < telemetry_delay and r + 1 < n_rounds:
            events.append(TelemetryDelay(round=r, rounds=1))
        if u[2] < telemetry_corrupt:
            mode = modes[int(rng.integers(0, len(modes)))]
            events.append(
                TelemetryCorrupt(
                    round=r, fraction=corrupt_fraction, mode=mode, seed=sub
                )
            )
        if u[3] < telemetry_stale and r >= start_round + 1:
            events.append(TelemetryStale(round=r, age=1))
        if u[4] < actuation_nack:
            events.append(
                ActuationNack(round=r, fraction=node_fraction, seed=sub + 1)
            )
        if u[5] < actuation_partial:
            events.append(
                ActuationPartial(
                    round=r, fraction=node_fraction, seed=sub + 2
                )
            )
        if actuation_delay > 0 and rng.random() < actuation_delay:
            events.append(
                ActuationDelay(round=r, fraction=node_fraction, seed=sub + 3)
            )
    for r in crash_rounds:
        if not 0 <= r < n_rounds:
            raise ValueError(f"crash round {r} outside [0, {n_rounds})")
        events.append(ControllerCrash(round=int(r), restore=restore))
    events.sort(key=lambda e: e.round)
    return tuple(events)


# ---------------------------------------------------------------------------
# Telemetry corruption
# ---------------------------------------------------------------------------


def corrupt_batch(batch, ev: TelemetryCorrupt):
    """Corrupt a seeded subset of a TelemetryBatch's records (copy-on-
    write: the engine's true measurement arrays are never mutated)."""
    n = len(batch)
    if n == 0:
        return batch
    rng = np.random.default_rng(ev.seed)
    k = max(1, int(round(ev.fraction * n)))
    idx = rng.choice(n, size=min(k, n), replace=False)
    t0 = np.array(batch.t_baseline, dtype=np.float64, copy=True)
    t1 = np.array(batch.t_allocated, dtype=np.float64, copy=True)
    if ev.mode == "nan":
        t1[idx] = np.nan
    elif ev.mode == "inf":
        t0[idx] = np.inf
    elif ev.mode == "outlier":
        t1[idx] = t1[idx] * OUTLIER_FACTOR
    elif ev.mode == "negative":
        t1[idx] = -np.abs(t1[idx]) - 1.0
    else:  # pragma: no cover - validated at build time
        raise ValueError(f"unknown corruption mode {ev.mode!r}")
    with np.errstate(invalid="ignore", divide="ignore"):
        imp = np.array(batch.improvement, dtype=np.float64, copy=True)
        imp[idx] = (t0[idx] - t1[idx]) / t0[idx]
    return dataclasses.replace(
        batch, t_baseline=t0, t_allocated=t1, improvement=imp
    )


# ---------------------------------------------------------------------------
# Engine-side resolution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ActuationReport:
    """What the actuation layer tells the controller after a round:
    receivers whose applied caps match the command (``acked``), receivers
    that deviated (``nacked``) with the caps that actually hold
    (``applied`` — the controller's "last-confirmed" values, PowerGuard
    derates included)."""

    round: int
    acked: tuple[str, ...]
    nacked: tuple[str, ...]
    applied: dict


class FaultInjector:
    """Per-run resolution of a scenario's fault events.

    Owned by one ``ClusterSim.run`` call; carries the cross-round fault
    state (delayed telemetry queue, stale-repeat history, the rolling
    controller snapshot crash-restores pull from).
    """

    def __init__(self, faults: Sequence):
        self._by_round: dict[int, list] = {}
        for ev in faults:
            self._by_round.setdefault(ev.round, []).append(ev)
        #: (deliver_round, batch) queue of delayed batches
        self._delayed: list = []
        #: round -> true batch, kept only as far back as stale events reach
        self._history: dict[int, object] = {}
        self._hist_keep = max(
            (e.age for evs in self._by_round.values() for e in evs
             if isinstance(e, TelemetryStale)),
            default=0,
        )
        self._want_snapshots = any(
            isinstance(e, ControllerCrash) and e.restore
            for evs in self._by_round.values()
            for e in evs
        )
        #: last end-of-round controller snapshot (crash-restore source)
        self.snapshot = None
        #: ControllerCrash events fired so far (round, restored) for tooling
        self.crashes: list[tuple[int, bool]] = []

    def faults_at(self, r: int) -> list:
        return self._by_round.get(r, [])

    # -- controller channel --------------------------------------------------

    def maybe_crash(self, r: int, controller) -> bool:
        """Fire any ControllerCrash scheduled at round ``r``: wipe all
        warm state (crash_reset) and, when the event says so and a
        snapshot exists, restore it — the checkpointed-failover path."""
        crashed = False
        for ev in self.faults_at(r):
            if not isinstance(ev, ControllerCrash):
                continue
            controller.crash_reset()
            restored = False
            if ev.restore and self.snapshot is not None:
                controller.restore(self.snapshot)
                restored = True
            self.crashes.append((r, restored))
            crashed = True
        return crashed

    def end_round(self, r: int, controller) -> None:
        """Roll the restore point forward: snapshot after the round's
        telemetry has been ingested, so a crash at round r+1 restores
        exactly the state the uninterrupted controller carries into it."""
        if self._want_snapshots:
            self.snapshot = controller.snapshot()

    # -- actuation channel ---------------------------------------------------

    def _targets(self, ev, names: Sequence[str], node_ids) -> list[str]:
        if ev.node_ids:
            wanted = set(int(i) for i in ev.node_ids)
            return [
                nm for nm, nid in zip(names, node_ids) if int(nid) in wanted
            ]
        if ev.fraction > 0.0 and len(names):
            rng = np.random.default_rng(ev.seed)
            k = max(1, int(round(ev.fraction * len(names))))
            idx = rng.choice(len(names), size=min(k, len(names)), replace=False)
            return [names[i] for i in sorted(int(i) for i in idx)]
        return []

    def actuation_plan(
        self, r: int, names: Sequence[str], node_ids
    ) -> dict[str, tuple[str, float]]:
        """name -> (kind, param) for this round's actuation faults.  The
        first fault claiming a receiver wins (events compose across
        disjoint target sets)."""
        plan: dict[str, tuple[str, float]] = {}
        for ev in self.faults_at(r):
            if isinstance(ev, ActuationNack):
                kind, param = "nack", 0.0
            elif isinstance(ev, ActuationPartial):
                kind, param = "partial", float(ev.applied_fraction)
            elif isinstance(ev, ActuationDelay):
                kind, param = "delay", 0.0
            else:
                continue
            for nm in self._targets(ev, names, node_ids):
                plan.setdefault(nm, (kind, param))
        return plan

    def has_actuation(self, r: int) -> bool:
        return any(isinstance(e, _ACTUATION) for e in self.faults_at(r))

    # -- telemetry channel ---------------------------------------------------

    def deliver(self, r: int, batch) -> tuple[list, tuple[str, ...]]:
        """Route round ``r``'s true batch through the telemetry faults.

        Returns (batches to ingest this round, applied fault kinds).  Due
        delayed batches from earlier rounds are delivered first; the
        current batch is corrupted, displaced by a stale repeat, dropped
        or queued for later delivery per this round's events.
        """
        out: list = []
        kinds: list[str] = []
        due = [b for (rr, b) in self._delayed if rr <= r]
        if due:
            kinds.append("delayed_delivery")
        self._delayed = [(rr, b) for rr, b in self._delayed if rr > r]
        out.extend(due)

        if self._hist_keep:
            self._history[r] = batch
            self._history.pop(r - self._hist_keep - 1, None)

        cur = batch
        evs = self.faults_at(r)
        for ev in evs:
            if isinstance(ev, TelemetryCorrupt) and cur is not None:
                cur = corrupt_batch(cur, ev)
                kinds.append(f"corrupt:{ev.mode}")
        for ev in evs:
            if isinstance(ev, TelemetryStale):
                cur = self._history.get(r - ev.age)
                kinds.append("stale")
                break
        for ev in evs:
            if isinstance(ev, TelemetryDrop):
                cur = None
                kinds.append("drop")
                break
        if cur is not None:
            for ev in evs:
                if isinstance(ev, TelemetryDelay):
                    self._delayed.append((r + ev.rounds, cur))
                    cur = None
                    kinds.append("delay")
                    break
        if cur is not None:
            out.append(cur)
        return out, tuple(kinds)
