"""Telemetry-driven online performance prediction (paper §3.1, closed loop).

After PR 1 only the *allocation* half of EcoShift's "online prediction +
DP allocation" loop was online: predictors were fit offline and controllers
consumed frozen predicted surfaces.  This module closes the loop:

 1. each round the :class:`~repro.cluster.sim.ClusterSim` engine packages
    the true noisy measurements it already computes into
    :class:`TelemetryRecord`s (bit-identical to the improvements it
    reports — certified by tests/test_online_predictor.py);
 2. an :class:`OnlinePredictor` ingests them into per-(app, instance)
    observation buffers, runs the NCF online phase for apps whose telemetry
    says their surface is wrong (batched across apps via
    ``NCFPredictor.update_apps``), and
 3. swaps an app's :class:`~repro.core.surfaces.TabulatedSurface` — thereby
    invalidating controllers' warm option-table caches — only when the
    refreshed surface moved beyond a tolerance.

Information discipline: the predictor sees only *noisy measured runtimes*
(telemetry), never true surfaces.  Straggler slowdowns are invisible to it
except through the measurements themselves; because the NCF predicts
*runtime ratios*, a multiplicatively slowed instance still contributes
unbiased ratio observations.  Per-instance buffers are normalized by each
instance's own fastest observed runtime before pooling, so instances with
different slowdown factors (or measurement epochs) pool cleanly.

Cold start is the default: an arriving app with no pretrained surface is
allocated from the population-prior surface (the geometric mean of the
currently *served* ratio tables — never including the cold app itself)
until enough telemetry accumulates to fit its embeddings — the scenario
event carries no pre-baked prediction (see
:class:`repro.cluster.scenario.NodeArrival`).

Design notes: DESIGN.md §10 (loop shape, information discipline, re-fit
and invalidation gating).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

import numpy as np

from repro.core.ncf import NCFPredictor
from repro.core.surfaces import PowerSurface, TabulatedSurface

# ---------------------------------------------------------------------------
# Telemetry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetryRecord:
    """One receiver's noisy measurement from one redistribution round.

    ``t_baseline`` / ``t_allocated`` are the mean measured runtimes at the
    baseline and allocated cap pairs (``n_repeats`` noisy executions each);
    ``improvement`` is derived from exactly those two numbers and equals the
    engine's reported improvement bit-for-bit.
    """

    round: int
    instance: str
    base_app: str
    baseline_caps: tuple[float, float]
    allocated_caps: tuple[float, float]
    t_baseline: float
    t_allocated: float
    improvement: float


@dataclasses.dataclass(frozen=True, eq=False)
class TelemetryBatch:
    """One round's telemetry as columns (DESIGN.md §11).

    The engine emits measurement arrays directly — instance/app identities
    are interned ids into the cluster's shared string table, caps and
    runtimes are [n]- or [n, 2]-arrays.  Iterating (or indexing) a batch
    materializes :class:`TelemetryRecord` views lazily, so record-oriented
    consumers keep working while :class:`OnlinePredictor` ingests the
    columns wholesale.
    """

    round: int
    inst_gids: np.ndarray  # [n] int32 into ``strings`` (instance names)
    app_gids: np.ndarray  # [n] int32 into ``strings`` (base-app names)
    strings: list  # shared interned string table (append-only)
    baseline_caps: np.ndarray  # [n, 2]
    allocated_caps: np.ndarray  # [n, 2]
    t_baseline: np.ndarray  # [n]
    t_allocated: np.ndarray  # [n]
    improvement: np.ndarray  # [n]

    def __len__(self) -> int:
        return len(self.inst_gids)

    def record(self, i: int) -> TelemetryRecord:
        return TelemetryRecord(
            round=self.round,
            instance=self.strings[self.inst_gids[i]],
            base_app=self.strings[self.app_gids[i]],
            baseline_caps=(
                float(self.baseline_caps[i, 0]),
                float(self.baseline_caps[i, 1]),
            ),
            allocated_caps=(
                float(self.allocated_caps[i, 0]),
                float(self.allocated_caps[i, 1]),
            ),
            t_baseline=float(self.t_baseline[i]),
            t_allocated=float(self.t_allocated[i]),
            improvement=float(self.improvement[i]),
        )

    def __getitem__(self, i: int) -> TelemetryRecord:
        return self.record(i)

    def __iter__(self):
        for i in range(len(self)):
            yield self.record(i)

    @property
    def instances(self) -> list[str]:
        return [self.strings[g] for g in self.inst_gids]


# ---------------------------------------------------------------------------
# Online predictor
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OnlinePredictorConfig:
    #: distinct observed grid cells an app needs before its first online fit
    min_cells: int = 3
    #: relative surface move (max |new/old - 1| over the grid) above which
    #: the refreshed surface replaces the served one (and caches invalidate)
    tol: float = 0.01
    #: re-fit a *known* app only when its running |predicted - measured|
    #: improvement error exceeds this (cold apps always re-fit); this is the
    #: drift detector that keeps well-predicted apps off the refit path
    err_threshold: float = 0.03
    #: EMA factor for the per-app prediction-error tracker
    err_ema: float = 0.5
    #: per-(app, instance) observation buffer bound (distinct cells)
    max_cells: int = 64
    #: physical-plausibility bound on one record's runtime ratio: reject
    #: records where t_allocated / t_baseline (either direction) exceeds
    #: this — cap changes on this hardware never slow/speed a job 10x, so
    #: such a record is a broken meter, not a measurement
    max_slowdown: float = 10.0
    #: rejected records from one instance before it is quarantined
    quarantine_after: int = 3
    #: rounds a quarantined instance's telemetry is dropped wholesale
    quarantine_rounds: int = 32


class OnlinePredictor:
    """Stateful wrapper turning streaming telemetry into refreshed surfaces.

    Wraps an offline-trained :class:`~repro.core.ncf.NCFPredictor` (shared
    config embeddings / MLP stay frozen — the paper's online phase) and
    maintains:

     * per-(base_app, instance) observation buffers of mean measured
       runtime per grid cell (off-grid caps snap to the nearest cell: the
       cap grid is the controller's action space, so telemetry lands at
       most half a grid step away);
     * the served surface per app (``surfaces``), swapped only on
       tolerance-exceeding moves so controllers' identity-keyed option
       caches stay warm while predictions are stable;
     * a per-app prediction-error EMA (``prediction_error``) comparing the
       served surface's predicted improvement against the measured one —
       the drift signal that triggers re-fits for already-known apps.
    """

    def __init__(
        self,
        predictor: NCFPredictor,
        cfg: OnlinePredictorConfig = OnlinePredictorConfig(),
    ):
        self.ncf = predictor
        self.system = predictor.system
        self.cfg = cfg
        #: (base_app, instance) -> {cell: [runtime_sum, count]}
        self._buffers: dict[tuple[str, str], dict[tuple[float, float], list]] = {}
        #: instance -> base_app, learned from telemetry (survives phase
        #: changes where an AppSpec's surface_id may lag the true binding)
        self._app_of_instance: dict[str, str] = {}
        self._dirty: set[str] = set()
        #: served predicted surfaces keyed by base app name
        self.surfaces: dict[str, TabulatedSurface] = {}
        #: per-app |predicted - measured| improvement EMA
        self.prediction_error: dict[str, float] = {}
        #: per-app relative move of the last refreshed surface
        self.last_moves: dict[str, float] = {}
        self.n_refits = 0
        self._prior: TabulatedSurface | None = None
        #: robust-ingest counters (DESIGN.md §18): records rejected as
        #: non-finite / non-positive / physically impossible, and records
        #: dropped because their instance is quarantined
        self.n_rejected = 0
        self.n_quarantine_dropped = 0
        #: instance -> consecutive-corruption count since last quarantine
        self._corrupt: dict[str, int] = {}
        #: instance -> round its quarantine expires
        self._quarantined_until: dict[str, int] = {}
        #: construction-time artifacts a crash wipe restores to (the
        #: offline model and offline-seeded surfaces survive a process
        #: crash on disk; everything learned online does not)
        self._initial_ncf = predictor
        self._seeded: dict[str, TabulatedSurface] = {}

    # -- surface source ------------------------------------------------------

    def prior_surface(self) -> TabulatedSurface:
        """Population prior for cold-start apps: the geometric mean of the
        *served* predicted ratio tables (seeded offline surfaces and
        telemetry-fitted refreshes).  A cold app is by definition not
        served, so its own prediction can never leak into its prior.
        Before anything is served, falls back to the wrapped predictor's
        offline apps; flat (no predicted benefit from extra watts) when
        none exist."""
        if self._prior is None:
            grid = self.system.grid
            n_c, n_g = len(grid.cpu_levels), len(grid.gpu_levels)
            if self.surfaces:
                logs = np.stack(
                    [
                        np.log(self.surfaces[n].table)
                        for n in sorted(self.surfaces)
                    ]
                )
                table = np.exp(logs.mean(axis=0))
            elif self.ncf.app_index:
                logs = np.stack(
                    [
                        self.ncf.predict_log_ratios(n)
                        for n in sorted(self.ncf.app_index)
                    ]
                )
                table = np.exp(logs.mean(axis=0)).reshape(n_c, n_g)
            else:
                table = np.ones((n_c, n_g))
            self._prior = TabulatedSurface(
                cpu_levels=grid.cpu_levels,
                gpu_levels=grid.gpu_levels,
                table=table,
            )
        return self._prior

    def seed_surfaces(
        self, predicted: Mapping[str, TabulatedSurface]
    ) -> None:
        """Adopt offline-predicted surfaces as the served starting point
        (apps not listed stay cold-start)."""
        self.surfaces.update(predicted)
        self._seeded.update(predicted)

    def surface_for(self, instance: str, surface_id: str) -> PowerSurface:
        """Served surface for one receiver instance (prior when cold)."""
        app = self._app_of_instance.get(instance, surface_id)
        return self.surfaces.get(app) or self.prior_surface()

    def is_cold(self, app: str) -> bool:
        return app not in self.surfaces

    # -- telemetry ingestion -------------------------------------------------

    def _snap(self, caps: tuple[float, float]) -> tuple[float, float]:
        grid = self.system.grid
        c = grid.cpu_levels[np.argmin(np.abs(grid.cpu_levels - caps[0]))]
        g = grid.gpu_levels[np.argmin(np.abs(grid.gpu_levels - caps[1]))]
        return float(c), float(g)

    def _push(self, app: str, instance: str, caps, t: float) -> None:
        buf = self._buffers.setdefault((app, instance), {})
        cell = self._snap(caps)
        if cell not in buf and len(buf) >= self.cfg.max_cells:
            return
        slot = buf.setdefault(cell, [0.0, 0])
        slot[0] += t
        slot[1] += 1

    def _record_ok(self, t0: float, t1: float) -> bool:
        """Physical plausibility of one record's runtimes: finite, strictly
        positive, and within ``max_slowdown`` of each other in either
        direction (a cap change can't make a job 1000x slower — that's a
        broken meter)."""
        if not (np.isfinite(t0) and np.isfinite(t1)):
            return False
        if t0 <= 0.0 or t1 <= 0.0:
            return False
        m = self.cfg.max_slowdown
        return t1 <= m * t0 and t0 <= m * t1

    def _admit(self, instance: str, rnd: int, t0: float, t1: float) -> bool:
        """Gate one record into the buffers: quarantined instances are
        dropped wholesale, implausible records are rejected and counted,
        and ``quarantine_after`` rejections quarantine the instance for
        ``quarantine_rounds`` rounds (a meter that keeps lying gets
        unplugged instead of re-probed every round)."""
        q = self._quarantined_until.get(instance)
        if q is not None and rnd < q:
            self.n_quarantine_dropped += 1
            return False
        if self._record_ok(t0, t1):
            return True
        self.n_rejected += 1
        c = self._corrupt.get(instance, 0) + 1
        if c >= self.cfg.quarantine_after:
            self._quarantined_until[instance] = rnd + self.cfg.quarantine_rounds
            self._corrupt[instance] = 0
        else:
            self._corrupt[instance] = c
        return False

    def observe(self, records: "Iterable[TelemetryRecord] | TelemetryBatch") -> None:
        """Ingest one round of telemetry: buffer both measurement points of
        every record and update the per-app prediction-error EMA.

        A :class:`TelemetryBatch` takes the columnar fast path — one
        vectorized grid snap for all caps and one served-surface evaluation
        per app over its records — bit-identical to the record loop."""
        if isinstance(records, TelemetryBatch):
            self._observe_batch(records)
            return
        for r in records:
            if not self._admit(r.instance, r.round, r.t_baseline, r.t_allocated):
                continue
            self._app_of_instance[r.instance] = r.base_app
            self._push(r.base_app, r.instance, r.baseline_caps, r.t_baseline)
            self._push(r.base_app, r.instance, r.allocated_caps, r.t_allocated)
            self._dirty.add(r.base_app)
            served = self.surfaces.get(r.base_app)
            if served is not None:
                pred = float(
                    served.improvement(r.baseline_caps, *r.allocated_caps)
                )
                err = abs(pred - r.improvement)
                prev = self.prediction_error.get(r.base_app)
                a = self.cfg.err_ema
                self.prediction_error[r.base_app] = (
                    err if prev is None else a * err + (1 - a) * prev
                )

    def _observe_batch(self, batch: TelemetryBatch) -> None:
        """Columnar ingest over the batch's interned id tables.

        Cell snapping is one vectorized nearest-level lookup for all 2n
        measurement points, and the served surface evaluates once per app
        across its records (the drift EMA folds in record order, exactly
        like the sequential path).  Buffer pushes replay the interleaved
        [baseline, allocated] stream so cell admission under ``max_cells``
        is order-identical to :meth:`observe` on the record views."""
        n = len(batch)
        if n == 0:
            return
        strings = batch.strings
        grid = self.system.grid

        def snap_cols(caps: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
            ci = np.argmin(
                np.abs(grid.cpu_levels[None, :] - caps[:, 0][:, None]), axis=1
            )
            gi = np.argmin(
                np.abs(grid.gpu_levels[None, :] - caps[:, 1][:, None]), axis=1
            )
            return grid.cpu_levels[ci], grid.gpu_levels[gi]

        bc, bg = snap_cols(batch.baseline_caps)
        ac, ag = snap_cols(batch.allocated_caps)
        max_cells = self.cfg.max_cells
        use = np.zeros(n, dtype=bool)
        for i in range(n):
            inst = strings[batch.inst_gids[i]]
            if not self._admit(
                inst,
                batch.round,
                float(batch.t_baseline[i]),
                float(batch.t_allocated[i]),
            ):
                continue
            use[i] = True
            app = strings[batch.app_gids[i]]
            self._app_of_instance[inst] = app
            buf = self._buffers.setdefault((app, inst), {})
            for cell, t in (
                ((float(bc[i]), float(bg[i])), float(batch.t_baseline[i])),
                ((float(ac[i]), float(ag[i])), float(batch.t_allocated[i])),
            ):
                if cell not in buf and len(buf) >= max_cells:
                    continue
                slot = buf.setdefault(cell, [0.0, 0])
                slot[0] += t
                slot[1] += 1

        by_app: dict[int, list[int]] = {}
        for i in range(n):
            if not use[i]:
                continue
            by_app.setdefault(int(batch.app_gids[i]), []).append(i)
        a = self.cfg.err_ema
        for gid, idx in by_app.items():
            app = strings[gid]
            self._dirty.add(app)
            served = self.surfaces.get(app)
            if served is None:
                continue
            ii = np.asarray(idx)
            t0 = np.asarray(
                served.runtime(
                    batch.baseline_caps[ii, 0], batch.baseline_caps[ii, 1]
                ),
                np.float64,
            )
            tn = np.asarray(
                served.runtime(
                    batch.allocated_caps[ii, 0], batch.allocated_caps[ii, 1]
                ),
                np.float64,
            )
            preds = (t0 - tn) / t0
            prev = self.prediction_error.get(app)
            for k, i in enumerate(idx):
                err = abs(float(preds[k]) - float(batch.improvement[i]))
                prev = err if prev is None else a * err + (1 - a) * prev
            self.prediction_error[app] = prev

    def _pooled_samples(self, app: str) -> dict[tuple[float, float], float]:
        """Pool an app's instance buffers into one {cell: runtime-ratio}.

        Each instance normalizes by its own fastest observed mean runtime,
        making observations comparable across slowdown factors; duplicate
        cells average across instances."""
        cells: dict[tuple[float, float], list[float]] = {}
        for (a, _inst), buf in self._buffers.items():
            if a != app or not buf:
                continue
            means = {cell: s / n for cell, (s, n) in buf.items()}
            ref = min(means.values())
            for cell, t in means.items():
                cells.setdefault(cell, []).append(t / ref)
        return {cell: float(np.mean(v)) for cell, v in cells.items()}

    # -- refresh -------------------------------------------------------------

    def refresh(self) -> list[str]:
        """Run the online phase for apps whose telemetry warrants it and
        return the apps whose *served* surface actually moved (> tol) —
        exactly the set whose warm controller caches must invalidate.

        An app re-fits when it is dirty (new telemetry), has at least
        ``min_cells`` distinct observed cells, and is either cold (no
        served surface) or drifting (prediction-error EMA above
        ``err_threshold``)."""
        ready: dict[str, dict] = {}
        for app in sorted(self._dirty):
            cold = self.is_cold(app)
            drifting = (
                self.prediction_error.get(app, 0.0) > self.cfg.err_threshold
            )
            if not (cold or drifting):
                self._dirty.discard(app)
                continue
            pooled = self._pooled_samples(app)
            if len(pooled) >= self.cfg.min_cells:
                ready[app] = pooled
        if not ready:
            return []
        self.ncf = self.ncf.update_apps(ready)
        self.n_refits += len(ready)
        changed = []
        for app in ready:
            self._dirty.discard(app)
            new = self.ncf.predict_surface(app)
            old = self.surfaces.get(app)
            if old is None:
                move = np.inf
            else:
                move = float(np.max(np.abs(new.table / old.table - 1.0)))
            self.last_moves[app] = move
            if move > self.cfg.tol:
                self.surfaces[app] = new
                changed.append(app)
            # restart the drift EMA after *every* refit: a swap invalidates
            # the stale readings, and a no-move refit means the served
            # surface is as good as the model can do on this buffer — only
            # freshly re-accumulated error should trigger another fit
            self.prediction_error[app] = 0.0
        return changed

    # -- crash / restore (DESIGN.md §18) --------------------------------------

    @staticmethod
    def _encode_surface(s: TabulatedSurface) -> dict:
        return {
            "cpu_levels": np.asarray(s.cpu_levels),
            "gpu_levels": np.asarray(s.gpu_levels),
            "table": np.asarray(s.table),
            "natural_cpu": float(s.natural_cpu),
            "natural_gpu": float(s.natural_gpu),
        }

    @staticmethod
    def _decode_surface(d: dict) -> TabulatedSurface:
        return TabulatedSurface(
            cpu_levels=np.asarray(d["cpu_levels"]),
            gpu_levels=np.asarray(d["gpu_levels"]),
            table=np.asarray(d["table"]),
            natural_cpu=float(d["natural_cpu"]),
            natural_gpu=float(d["natural_gpu"]),
        )

    @staticmethod
    def _tree_np(x):
        """Copy a param pytree to host numpy (dict/tuple structure kept)."""
        if isinstance(x, dict):
            return {k: OnlinePredictor._tree_np(v) for k, v in x.items()}
        if isinstance(x, tuple):
            return tuple(OnlinePredictor._tree_np(v) for v in x)
        if isinstance(x, list):
            return [OnlinePredictor._tree_np(v) for v in x]
        return np.asarray(x)

    def state_dict(self) -> dict:
        """Everything learned online, as plain numpy/python values.

        Buffers and cell keys are list-encoded (msgpack has no tuple keys);
        the wrapped NCF serializes params/app_index/cfg_feats (its frozen
        system/config come from the live replacement process).  The lazy
        ``_prior`` is derived state and is recomputed on demand after load.
        """
        return {
            "buffers": [
                [app, inst, [[list(c), s, n] for c, (s, n) in buf.items()]]
                for (app, inst), buf in self._buffers.items()
            ],
            "app_of_instance": dict(self._app_of_instance),
            "dirty": sorted(self._dirty),
            "surfaces": {
                a: self._encode_surface(s) for a, s in self.surfaces.items()
            },
            "prediction_error": dict(self.prediction_error),
            "last_moves": dict(self.last_moves),
            "n_refits": int(self.n_refits),
            "n_rejected": int(self.n_rejected),
            "n_quarantine_dropped": int(self.n_quarantine_dropped),
            "corrupt": dict(self._corrupt),
            "quarantined_until": dict(self._quarantined_until),
            "ncf": {
                "params": self._tree_np(self.ncf.params),
                "app_index": dict(self.ncf.app_index),
                "cfg_feats": np.asarray(self.ncf.cfg_feats),
            },
        }

    def load_state_dict(self, state: Mapping) -> None:
        self._buffers = {
            (app, inst): {
                (float(c[0]), float(c[1])): [float(s), int(n)]
                for c, s, n in cells
            }
            for app, inst, cells in state["buffers"]
        }
        self._app_of_instance = dict(state["app_of_instance"])
        self._dirty = set(state["dirty"])
        self.surfaces = {
            a: self._decode_surface(d) for a, d in state["surfaces"].items()
        }
        self.prediction_error = dict(state["prediction_error"])
        self.last_moves = dict(state["last_moves"])
        self.n_refits = int(state["n_refits"])
        self.n_rejected = int(state["n_rejected"])
        self.n_quarantine_dropped = int(state["n_quarantine_dropped"])
        self._corrupt = {k: int(v) for k, v in state["corrupt"].items()}
        self._quarantined_until = {
            k: int(v) for k, v in state["quarantined_until"].items()
        }
        self.ncf = NCFPredictor(
            system=self.system,
            cfg=self.ncf.cfg,
            params=state["ncf"]["params"],
            app_index=dict(state["ncf"]["app_index"]),
            cfg_feats=np.asarray(state["ncf"]["cfg_feats"]),
        )
        self._prior = None

    def wipe(self) -> None:
        """Simulate a process crash: everything learned online is gone;
        only construction-time artifacts (the offline-trained NCF and the
        offline-seeded surfaces — both on disk in a real deployment)
        survive."""
        self.ncf = self._initial_ncf
        self._buffers = {}
        self._app_of_instance = {}
        self._dirty = set()
        self.surfaces = dict(self._seeded)
        self.prediction_error = {}
        self.last_moves = {}
        self.n_refits = 0
        self.n_rejected = 0
        self.n_quarantine_dropped = 0
        self._corrupt = {}
        self._quarantined_until = {}
        self._prior = None
