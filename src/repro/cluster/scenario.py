"""Declarative scenario timelines for multi-round cluster simulation.

A ``Scenario`` is a pure description of *what happens when*: the reclaimed
budget (and optional price / CO2-intensity signals) per round and the
cluster events — node failures, arrivals, straggler onsets, workload
phase changes.  Benchmarks build one declaratively instead of
hand-rolling ``fail_nodes`` / ``add_straggler`` call sequences, and the
same scenario can be replayed against any controller
(``repro.cluster.controller``) on the engine (``repro.cluster.sim``).

Budgets and signals are **provider-backed** (``repro.cluster.budget``):
pass any :class:`~repro.cluster.budget.BudgetProvider` — trace replay of
a CO2/price/solar fixture, composed deratings, solar-following caps —
via ``with_budget_provider`` (or the ``budget=`` field).  The historical
raw trace forms keep working through a thin shim (auto-wrapped into a
``TraceReplayProvider`` with identical semantics):

 * a scalar — constant every round;
 * a sequence — one entry per round (shorter sequences hold their last
   value);
 * a callable ``round -> value``.

A budget of ``None`` means "derive the pool from donor headroom this
round", matching the single-round emulator's default.  ``with_budget``
(raw-trace access) is deprecated in favor of ``with_budget_provider``
and emits a one-release ``DeprecationWarning``.

A scenario may **attach a power topology** (``with_topology``): the
rack/PDU domain tree the engine enforces (DESIGN.md §12).  Attachment
makes node-id events *fail fast* — ``with_failure`` / ``with_straggler`` /
``with_phase_change`` referencing node ids no leaf domain owns raise at
build time instead of mid-sim — and enables ``DomainCapChange`` events
(e.g. a rack PDU derating mid-scenario).  Event/budget precedence on a
shared round is documented at :meth:`Scenario.budget_at`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence, Union

from repro.cluster import budget as budget_mod
from repro.cluster.budget import Trace, trace_at as _trace_at  # noqa: F401
from repro.core.surfaces import PowerSurface
from repro.core.types import AppSpec

# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NodeFailure:
    """Nodes die at the start of ``round``; their cap allotment returns to
    the reclaimed pool and the controller re-optimizes over survivors."""

    round: int
    node_ids: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StragglerOnset:
    """A node's true surface slows by ``slowdown`` (thermal throttle,
    failing HBM) from ``round`` on."""

    round: int
    node_id: int
    slowdown: float


@dataclasses.dataclass(frozen=True)
class PhaseChange:
    """A node's workload enters a new phase: its surface rebinds to
    ``surface_id`` (must exist in the simulation's surface table)."""

    round: int
    node_id: int
    surface_id: str


@dataclasses.dataclass(frozen=True)
class NodeArrival:
    """A new instance of ``app`` joins at ``round`` (caps default to the
    system's initial uniform caps).

    Arrivals carry **no pre-baked predicted surface** — cold start is the
    default: predictor-backed controllers serve their population prior
    until the app's own telemetry accumulates (repro.cluster.predictor).
    ``surface`` optionally registers a *ground-truth* surface for an app
    the simulation has never seen (used by the engine for measurement
    only; the information discipline of DESIGN.md §10 keeps it away from
    every predictor)."""

    round: int
    app: AppSpec
    caps: tuple[float, float] | None = None
    surface: PowerSurface | None = None
    #: leaf power-domain placement (required by topology-constrained sims
    #: when the assigned node id falls outside every leaf's range)
    domain: str | None = None


@dataclasses.dataclass(frozen=True)
class DomainCapChange:
    """A power domain's cap moves to ``cap`` watts from ``round`` on — a
    rack PDU derating, a site-level demand-response curtailment.  Applies
    to any domain (leaf or internal) of the simulation's topology."""

    round: int
    domain: str
    cap: float


Event = Union[
    NodeFailure, StragglerOnset, PhaseChange, NodeArrival, DomainCapChange
]


def _validate_against_topology(events: Sequence[Event], topology) -> None:
    """Build-time fail-fast: every node-id event must reference ids some
    leaf domain owns, and domain events must name existing domains.

    One vectorized ``leaf_of`` per node-id event — no per-id probing, so
    bulk ``with_events`` attachment validates in a single numpy pass per
    event."""
    for e in events:
        if isinstance(e, (NodeFailure, StragglerOnset, PhaseChange)):
            ids = (
                list(e.node_ids)
                if isinstance(e, NodeFailure)
                else [e.node_id]
            )
            try:
                topology.leaf_of(ids)
            except ValueError as err:
                raise ValueError(
                    f"{type(e).__name__} at round {e.round}: {err}"
                ) from None
        elif isinstance(e, NodeArrival):
            if e.domain is not None:
                try:
                    topology.require_leaf(e.domain)
                except ValueError as err:
                    raise ValueError(
                        f"arrival at round {e.round}: {err}"
                    ) from None
        elif isinstance(e, DomainCapChange):
            if e.domain not in topology.index:
                raise ValueError(
                    f"cap change at round {e.round} references unknown "
                    f"domain {e.domain!r}"
                )
            if e.cap <= 0:
                raise ValueError(
                    f"cap change at round {e.round}: cap must be positive"
                )

# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A timeline of ``n_rounds`` redistribution rounds.

    ``budget`` / ``power_price`` / ``carbon`` accept either a
    :class:`~repro.cluster.budget.BudgetProvider` or a legacy raw trace
    (auto-wrapped into a ``TraceReplayProvider`` at construction — the
    normalized field always holds a provider or None).
    """

    n_rounds: int
    #: reclaimed budget per round (None = donor-derived pool); normalized
    #: to a BudgetProvider
    budget: object = None
    #: optional $/W power price per round, recorded alongside results and
    #: usable as the horizon planner's weight signal; normalized provider
    power_price: object = None
    events: tuple[Event, ...] = ()
    #: optional power-domain tree (repro.core.topology.PowerTopology); the
    #: engine adopts and enforces it, and the builder methods validate
    #: node-id events against its leaf ranges at build time (with_topology
    #: sweeps existing events once; with_event/with_events validate only
    #: what they add, so chained builders stay O(total events))
    topology: object | None = None
    #: optional grid CO2-intensity signal (gCO2eq/kWh) — the receding-
    #: horizon allocator's preferred weight feed; normalized provider
    carbon: object = None
    #: fault-injection events (repro.cluster.faults) — telemetry /
    #: actuation / controller faults the engine resolves per round
    faults: tuple = ()

    def __post_init__(self):
        # normalize every signal field to a provider exactly once;
        # as_provider is idempotent so dataclasses.replace re-runs are free
        for field in ("budget", "power_price", "carbon"):
            v = getattr(self, field)
            p = budget_mod.as_provider(v)
            if p is not v:
                object.__setattr__(self, field, p)

    def budget_at(self, r: int) -> float | None:
        """Cluster budget at round ``r`` (None = donor-derived pool).

        **Precedence on a shared round** (engine contract, tested by
        tests/test_budget.py): the engine applies round ``r``'s events —
        including ``DomainCapChange`` — *before* resolving the budget and
        the per-domain headroom for round ``r``, so a cap change and a
        budget-trace step landing on the same round both take effect that
        round; a ``DomainCapChange`` overrides the domain's own cap trace
        from its round on (inclusive); and both sides coerce through
        ``repro.cluster.budget.as_watts``, so they can never disagree on
        rounding/float handling.
        """
        return None if self.budget is None else self.budget.budget_at(r)

    def price_at(self, r: int) -> float | None:
        return None if self.power_price is None else self.power_price.budget_at(r)

    def carbon_at(self, r: int) -> float | None:
        return None if self.carbon is None else self.carbon.budget_at(r)

    def budget_forecast(self, r: int, horizon: int) -> tuple:
        """Budgets for rounds ``r .. r+horizon-1`` (None entries where
        unset) — what the receding-horizon controller plans over."""
        if self.budget is None:
            return (None,) * int(horizon)
        return tuple(self.budget.forecast(r, horizon))

    def price_forecast(self, r: int, horizon: int) -> tuple:
        if self.power_price is None:
            return (None,) * int(horizon)
        return tuple(self.power_price.forecast(r, horizon))

    def carbon_forecast(self, r: int, horizon: int) -> tuple:
        if self.carbon is None:
            return (None,) * int(horizon)
        return tuple(self.carbon.forecast(r, horizon))

    def events_at(self, r: int) -> tuple[Event, ...]:
        # lazily indexed by round: scenario replay is O(rounds + events),
        # not O(rounds * events) — large clusters carry thousands of events
        idx = self.__dict__.get("_events_by_round")
        if idx is None:
            idx = {}
            for e in self.events:
                idx.setdefault(e.round, []).append(e)
            idx = {k: tuple(v) for k, v in idx.items()}
            object.__setattr__(self, "_events_by_round", idx)
        return idx.get(r, ())

    # -- builders ------------------------------------------------------------

    @staticmethod
    def constant(n_rounds: int, budget: float | None = None) -> "Scenario":
        return Scenario(n_rounds=n_rounds, budget=budget)

    def with_event(self, event: Event) -> "Scenario":
        if not 0 <= event.round < self.n_rounds:
            raise ValueError(
                f"event round {event.round} outside [0, {self.n_rounds})"
            )
        if self.topology is not None:
            _validate_against_topology((event,), self.topology)
        return dataclasses.replace(self, events=self.events + (event,))

    def with_topology(self, topology) -> "Scenario":
        """Attach the power-domain tree: existing events are validated
        against its leaf ranges in one sweep, and every future builder
        call validates what it adds (fail fast at build, not mid-sim)."""
        _validate_against_topology(self.events, topology)
        return dataclasses.replace(self, topology=topology)

    def with_events(self, events: Sequence[Event]) -> "Scenario":
        """Bulk variant of :meth:`with_event` (one replace, one validation
        sweep — scaling scenarios attach thousands of events)."""
        for e in events:
            if not 0 <= e.round < self.n_rounds:
                raise ValueError(
                    f"event round {e.round} outside [0, {self.n_rounds})"
                )
        if self.topology is not None:
            _validate_against_topology(events, self.topology)
        return dataclasses.replace(self, events=self.events + tuple(events))

    def with_failure(self, round: int, *node_ids: int) -> "Scenario":
        return self.with_event(NodeFailure(round=round, node_ids=tuple(node_ids)))

    def with_straggler(
        self, round: int, node_id: int, slowdown: float
    ) -> "Scenario":
        return self.with_event(
            StragglerOnset(round=round, node_id=node_id, slowdown=slowdown)
        )

    def with_phase_change(
        self, round: int, node_id: int, surface_id: str
    ) -> "Scenario":
        return self.with_event(
            PhaseChange(round=round, node_id=node_id, surface_id=surface_id)
        )

    def with_arrival(
        self,
        round: int,
        app: AppSpec,
        caps: tuple[float, float] | None = None,
        surface: PowerSurface | None = None,
        domain: str | None = None,
    ) -> "Scenario":
        return self.with_event(
            NodeArrival(
                round=round, app=app, caps=caps, surface=surface, domain=domain
            )
        )

    def with_domain_cap(self, round: int, domain: str, cap: float) -> "Scenario":
        """A rack/PDU derating (or uprating): ``domain``'s cap becomes
        ``cap`` watts from ``round`` on."""
        return self.with_event(
            DomainCapChange(round=round, domain=domain, cap=cap)
        )

    def with_faults(self, faults: Sequence) -> "Scenario":
        """Attach fault-injection events (``repro.cluster.faults``):
        telemetry drops/delays/corruption/stale repeats, actuation
        NACK/partial/delayed application, controller crashes.  Validated
        at build time; the engine resolves them per round (DESIGN.md §18)."""
        from repro.cluster import faults as faults_mod

        faults = tuple(faults)
        faults_mod.validate_faults(faults, self.n_rounds)
        return dataclasses.replace(self, faults=self.faults + faults)

    def with_fault_storm(self, seed: int = 0, **rates) -> "Scenario":
        """Attach a seeded randomized fault storm (see
        :func:`repro.cluster.faults.fault_storm` for the rate kwargs)."""
        from repro.cluster import faults as faults_mod

        return self.with_faults(
            faults_mod.fault_storm(self.n_rounds, seed, **rates)
        )

    def with_budget_provider(self, provider) -> "Scenario":
        """Attach a budget source: any
        :class:`~repro.cluster.budget.BudgetProvider` (trace replay,
        composed deratings, solar-following, ...) or a raw trace (wrapped
        via :func:`~repro.cluster.budget.as_provider`)."""
        return dataclasses.replace(
            self, budget=budget_mod.as_provider(provider)
        )

    def with_budget(self, budget: Trace) -> "Scenario":
        """Deprecated raw-trace budget attachment.

        Use :meth:`with_budget_provider` — the trace is auto-wrapped into
        a ``TraceReplayProvider`` with identical semantics, so behavior
        is unchanged for this release.
        """
        warnings.warn(
            "Scenario.with_budget(trace) is deprecated; use "
            "Scenario.with_budget_provider(...) (raw traces are "
            "auto-wrapped into a TraceReplayProvider)",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.with_budget_provider(budget)

    def with_power_price(self, provider) -> "Scenario":
        """Attach a $/MWh (or $/W) price signal — recorded per round and
        usable as the horizon planner's weight feed."""
        return dataclasses.replace(
            self, power_price=budget_mod.as_provider(provider)
        )

    def with_carbon(self, provider) -> "Scenario":
        """Attach a grid CO2-intensity signal (provider or raw trace) —
        the receding-horizon allocator weights its spend plan by it."""
        return dataclasses.replace(
            self, carbon=budget_mod.as_provider(provider)
        )

    @staticmethod
    def carbon_aware(
        n_rounds: int,
        budget,
        carbon=None,
        power_price=None,
    ) -> "Scenario":
        """Day-scale carbon-aware scenario: a budget provider plus CO2 /
        price signals (defaults: the shipped ``co2_day`` / ``price_day``
        fixtures resampled to ``n_rounds``)."""
        return Scenario(
            n_rounds=n_rounds,
            budget=budget_mod.as_provider(budget),
            carbon=budget_mod.as_provider(
                carbon
                if carbon is not None
                else budget_mod.fixture_trace("co2_day", n_rounds)
            ),
            power_price=budget_mod.as_provider(
                power_price
                if power_price is not None
                else budget_mod.fixture_trace("price_day", n_rounds)
            ),
        )

    @staticmethod
    def price_capped(
        n_rounds: int,
        pool_watts: float,
        prices: Sequence[float],
        spend_cap: float,
    ) -> "Scenario":
        """Budget follows a power-price trace: each round distributes
        ``min(pool, spend_cap / price)`` watts — expensive-power rounds
        shrink the redistribution."""
        budgets = [
            min(pool_watts, spend_cap / max(float(p), 1e-12)) for p in prices
        ]
        return Scenario(
            n_rounds=n_rounds, budget=tuple(budgets), power_price=tuple(prices)
        )
