"""Time-stepped multi-round cluster simulation engine (paper §5.4, temporal).

``ClusterSim`` owns the node states and steps a :class:`Scenario` against a
stateful :class:`~repro.cluster.controller.Controller`:

 1. apply this round's events (failures, stragglers, arrivals, phase
    changes) and invalidate the controller's per-receiver warm state;
 2. partition donors/receivers, derive (or read) the reclaimed budget;
 3. controller allocates; the engine measures true improvements.

Measurement is *vectorized*: instead of the per-node Python loop the
single-round emulator used (2 * n_repeats scalar surface lookups and RNG
draws per receiver), the engine evaluates each distinct surface once over
all of its receivers' cap vectors and draws the whole
``[n, n_repeats, 2]`` noise block in one call.  The RNG stream is
*identical* to the sequential loop (numpy ``Generator`` array fills consume
the bit stream in element order), so improvements match the legacy path
bit-for-bit — certified by tests/test_cluster.py.

``measure_improvements_loop`` keeps the legacy per-node loop as the
equivalence/benchmark reference.

Every vectorized measurement is also emitted as telemetry
(:class:`repro.cluster.predictor.TelemetryRecord` — the same mean measured
runtimes and improvements, bit-for-bit): ``run_round`` stashes the round's
records in ``last_telemetry`` and ``run`` hands them to the controller's
``ingest_telemetry`` hook after each round, closing the online
prediction loop (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.cluster import scenario as scenario_mod
from repro.cluster.predictor import TelemetryRecord
from repro.cluster.scenario import Scenario
from repro.core.surfaces import PowerSurface, measured_runtime
from repro.core.types import (
    Allocation,
    AppSpec,
    EmulationResult,
    SystemSpec,
)

#: per-round offset into the measurement RNG stream (round 0 == the legacy
#: single-round stream, so migrated paths reproduce run_round exactly)
_ROUND_STRIDE = 1000003


@dataclasses.dataclass(frozen=True)
class NodeState:
    node_id: int
    app: AppSpec  # instance (name is unique per node)
    base_app: str  # underlying app name (surface / predictor identity)
    caps: tuple[float, float]
    alive: bool = True
    slowdown: float = 1.0  # straggler factor on the true surface


@dataclasses.dataclass(frozen=True)
class _SlowedSurface(PowerSurface):
    base: PowerSurface
    slowdown: float

    def runtime(self, c, g):
        return self.base.runtime(c, g) * self.slowdown

    def power_draw(self, c, g):
        return self.base.power_draw(c, g)


def build_nodes(
    system: SystemSpec,
    apps: Sequence[AppSpec],
    *,
    n_nodes: int,
    seed: int,
    initial_caps: tuple[float, float] | None = None,
) -> list[NodeState]:
    """Place ``n_nodes`` instances by cycling a shuffled app list."""
    rng = np.random.default_rng(seed)
    order = list(apps)
    rng.shuffle(order)
    caps = initial_caps or (system.init_cpu, system.init_gpu)
    nodes = []
    for i in range(n_nodes):
        a = order[i % len(order)]
        inst = AppSpec(
            name=f"{a.name}#n{i}", sclass=a.sclass, surface_id=a.surface_id
        )
        nodes.append(NodeState(node_id=i, app=inst, base_app=a.name, caps=caps))
    return nodes


# ---------------------------------------------------------------------------
# Round records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    """Everything observed in one simulated round."""

    round: int
    result: EmulationResult
    pool: float  # donor-derived reclaimed pool this round
    n_alive: int
    events: tuple = ()
    power_price: float | None = None
    #: per-receiver noisy measurements (empty on the legacy loop path)
    telemetry: tuple[TelemetryRecord, ...] = ()

    @property
    def avg_improvement(self) -> float:
        return self.result.avg_improvement


@dataclasses.dataclass
class SimResult:
    """Trace of a whole scenario under one controller."""

    policy: str
    records: list[RoundRecord]

    @property
    def n_rounds(self) -> int:
        return len(self.records)

    @property
    def improvement_trace(self) -> np.ndarray:
        return np.array([r.avg_improvement for r in self.records])

    def improvements_of(self, name: str) -> np.ndarray:
        """Per-round improvement of one instance (NaN when not a receiver)."""
        return np.array(
            [r.result.improvements.get(name, np.nan) for r in self.records]
        )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterSim:
    system: SystemSpec
    nodes: list[NodeState]
    #: true surfaces keyed by *base* app name
    surfaces: Mapping[str, PowerSurface]
    n_repeats: int = 5
    seed: int = 0
    #: memoized straggler views: stable object identity per (app, slowdown)
    #: so controllers' identity-keyed option caches stay warm across rounds
    _slowed: dict = dataclasses.field(default_factory=dict, repr=False)
    #: telemetry emitted by the latest vectorized-measurement round
    last_telemetry: tuple = dataclasses.field(default=(), repr=False)

    @staticmethod
    def build(
        system: SystemSpec,
        apps: Sequence[AppSpec],
        surfaces: Mapping[str, PowerSurface],
        *,
        n_nodes: int = 100,
        seed: int = 0,
        initial_caps: tuple[float, float] | None = None,
    ) -> "ClusterSim":
        nodes = build_nodes(
            system, apps, n_nodes=n_nodes, seed=seed, initial_caps=initial_caps
        )
        return ClusterSim(system=system, nodes=nodes, surfaces=surfaces, seed=seed)

    # -- node state ----------------------------------------------------------

    def _surface(self, node: NodeState) -> PowerSurface:
        s = self.surfaces[node.base_app]
        if node.slowdown == 1.0:
            return s
        key = (node.base_app, node.slowdown)
        hit = self._slowed.get(key)
        if hit is None or hit.base is not s:
            hit = _SlowedSurface(s, node.slowdown)
            self._slowed[key] = hit
        return hit

    def alive_nodes(self) -> list[NodeState]:
        return [n for n in self.nodes if n.alive]

    def partition(self) -> tuple[list[NodeState], list[NodeState], float]:
        """(donors, receivers, reclaimed_pool).  A node donates iff its
        natural draw sits below its caps on both components (margin 1 W);
        a dead node donates its entire cap allotment."""
        donors, receivers = [], []
        pool = 0.0
        for node in self.nodes:
            if not node.alive:
                pool += node.caps[0] + node.caps[1]
                continue
            nat_c, nat_g = self._surface(node).power_draw(1e9, 1e9)
            slack_c = node.caps[0] - float(nat_c)
            slack_g = node.caps[1] - float(nat_g)
            if slack_c > 1.0 and slack_g > 1.0:
                donors.append(node)
                pool += slack_c + slack_g
            else:
                receivers.append(node)
        return donors, receivers, pool

    # -- events ---------------------------------------------------------------

    def apply_event(self, event) -> list[str]:
        """Apply one scenario event; returns affected instance names."""
        if isinstance(event, scenario_mod.NodeFailure):
            ids = set(event.node_ids)
            touched = [n.app.name for n in self.nodes if n.node_id in ids]
            self.nodes = [
                dataclasses.replace(n, alive=False) if n.node_id in ids else n
                for n in self.nodes
            ]
            return touched
        if isinstance(event, scenario_mod.StragglerOnset):
            self.nodes = [
                dataclasses.replace(n, slowdown=event.slowdown)
                if n.node_id == event.node_id
                else n
                for n in self.nodes
            ]
            return [n.app.name for n in self.nodes if n.node_id == event.node_id]
        if isinstance(event, scenario_mod.PhaseChange):
            if event.surface_id not in self.surfaces:
                raise KeyError(f"unknown surface {event.surface_id!r}")
            self.nodes = [
                dataclasses.replace(
                    n,
                    base_app=event.surface_id,
                    # rebind the instance's surface identity too, so
                    # predictor-backed controllers resolve the new phase
                    app=dataclasses.replace(
                        n.app, surface_id=event.surface_id
                    ),
                )
                if n.node_id == event.node_id
                else n
                for n in self.nodes
            ]
            return [n.app.name for n in self.nodes if n.node_id == event.node_id]
        if isinstance(event, scenario_mod.NodeArrival):
            if event.surface is not None:
                # a genuinely new app: register its ground-truth surface
                self.surfaces = {**self.surfaces, event.app.name: event.surface}
            if event.app.name not in self.surfaces:
                raise KeyError(f"no surface for arriving app {event.app.name!r}")
            nid = 1 + max((n.node_id for n in self.nodes), default=-1)
            caps = event.caps or (self.system.init_cpu, self.system.init_gpu)
            inst = AppSpec(
                name=f"{event.app.name}#n{nid}",
                sclass=event.app.sclass,
                surface_id=event.app.surface_id,
            )
            self.nodes = self.nodes + [
                NodeState(
                    node_id=nid, app=inst, base_app=event.app.name, caps=caps
                )
            ]
            return []
        raise TypeError(f"unknown event {event!r}")

    # -- measurement ----------------------------------------------------------

    def measure_improvements(
        self,
        recv_nodes: Sequence[NodeState],
        alloc: Allocation,
        rng: np.random.Generator,
    ) -> dict[str, float]:
        """Vectorized measurement of all receivers x repeats.

        One surface evaluation per distinct (app, slowdown) group and one
        RNG fill for the whole noise block; bit-for-bit equal to
        :func:`measure_improvements_loop`.
        """
        _, _, imp = self._measure_arrays(recv_nodes, alloc, rng)
        return {
            node.app.name: float(imp[i]) for i, node in enumerate(recv_nodes)
        }

    def _measure_arrays(
        self,
        recv_nodes: Sequence[NodeState],
        alloc: Allocation,
        rng: np.random.Generator,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized measurement core: per-receiver mean measured runtimes
        at (baseline, allocated) caps plus relative improvements — the same
        arrays back both the engine's reported improvements and the
        telemetry records, so the two are bit-identical by construction."""
        n = len(recv_nodes)
        if n == 0:
            z = np.zeros(0, dtype=np.float64)
            return z, z, z
        base = np.array([node.caps for node in recv_nodes], dtype=np.float64)
        new = np.array(
            [alloc.caps[node.app.name] for node in recv_nodes], dtype=np.float64
        )
        t_base = np.empty(n, dtype=np.float64)
        t_new = np.empty(n, dtype=np.float64)
        groups: dict[tuple[str, float], list[int]] = {}
        for i, node in enumerate(recv_nodes):
            groups.setdefault((node.base_app, node.slowdown), []).append(i)
        for (base_app, slowdown), idx in groups.items():
            surf = self.surfaces[base_app]
            ii = np.asarray(idx)
            tb = np.asarray(surf.runtime(base[ii, 0], base[ii, 1]), np.float64)
            tn = np.asarray(surf.runtime(new[ii, 0], new[ii, 1]), np.float64)
            t_base[ii] = tb * slowdown
            t_new[ii] = tn * slowdown

        sigma = self.system.noise_sigma
        if sigma > 0:
            # C-order fill == the sequential per-(node, repeat, base/new)
            # scalar draws of the legacy loop
            factors = np.exp(rng.normal(0.0, sigma, size=(n, self.n_repeats, 2)))
            t0 = (t_base[:, None] * factors[:, :, 0]).mean(axis=1)
            t1 = (t_new[:, None] * factors[:, :, 1]).mean(axis=1)
        else:
            t0, t1 = t_base, t_new
        imp = (t0 - t1) / t0
        return t0, t1, imp

    def measure_improvements_loop(
        self,
        recv_nodes: Sequence[NodeState],
        alloc: Allocation,
        rng: np.random.Generator,
    ) -> dict[str, float]:
        """Legacy per-node measurement loop (equivalence/benchmark reference)."""
        improvements: dict[str, float] = {}
        for node in recv_nodes:
            surf = self._surface(node)
            c, g = alloc.caps[node.app.name]
            base_ts, new_ts = [], []
            for _ in range(self.n_repeats):
                base_ts.append(
                    measured_runtime(
                        surf,
                        *node.caps,
                        rng=rng,
                        noise_sigma=self.system.noise_sigma,
                    )
                )
                new_ts.append(
                    measured_runtime(
                        surf, c, g, rng=rng, noise_sigma=self.system.noise_sigma
                    )
                )
            t0, t1 = float(np.mean(base_ts)), float(np.mean(new_ts))
            improvements[node.app.name] = (t0 - t1) / t0
        return improvements

    # -- rounds ---------------------------------------------------------------

    def round_rng(self, policy: str, round_index: int) -> np.random.Generator:
        """Measurement RNG: round 0 replays the legacy run_round stream."""
        return np.random.default_rng(
            self.seed
            + zlib.crc32(policy.encode()) % 100003
            + round_index * _ROUND_STRIDE
        )

    def run_round(
        self,
        controller,
        budget: float | None = None,
        *,
        policy_surfaces: Mapping[str, PowerSurface] | None = None,
        receivers: Sequence[NodeState] | None = None,
        round_index: int = 0,
        use_loop_measurement: bool = False,
    ) -> EmulationResult:
        """One redistribution round under a stateful controller.

        ``policy_surfaces`` is what the policy sees (predicted surfaces for
        EcoShift; defaults to true surfaces keyed per instance).  ``budget``
        defaults to the donor-derived reclaimed pool.
        """
        if receivers is not None and budget is not None:
            recv_nodes = list(receivers)
        else:
            _, recv_nodes, pool = self.partition()
            if receivers is not None:
                recv_nodes = list(receivers)
        b = float(pool if budget is None else budget)
        recv_apps = [n.app for n in recv_nodes]
        baselines = {n.app.name: n.caps for n in recv_nodes}
        true_by_inst = {n.app.name: self._surface(n) for n in recv_nodes}
        seen = (
            policy_surfaces if policy_surfaces is not None else true_by_inst
        )
        if controller.sees_truth:
            seen = true_by_inst

        alloc = controller.allocate(recv_apps, baselines, b, seen)
        rng = self.round_rng(controller.policy, round_index)
        if use_loop_measurement:
            improvements = self.measure_improvements_loop(recv_nodes, alloc, rng)
            self.last_telemetry = ()
        else:
            t0, t1, imp = self._measure_arrays(recv_nodes, alloc, rng)
            improvements = {
                node.app.name: float(imp[i])
                for i, node in enumerate(recv_nodes)
            }
            self.last_telemetry = tuple(
                TelemetryRecord(
                    round=round_index,
                    instance=node.app.name,
                    base_app=node.base_app,
                    baseline_caps=tuple(node.caps),
                    allocated_caps=tuple(alloc.caps[node.app.name]),
                    t_baseline=float(t0[i]),
                    t_allocated=float(t1[i]),
                    improvement=float(imp[i]),
                )
                for i, node in enumerate(recv_nodes)
            )
        return EmulationResult(
            policy=controller.policy,
            improvements=improvements,
            allocation=alloc,
            budget=b,
        )

    def run(
        self,
        scenario: Scenario,
        controller,
        *,
        policy_surfaces: Mapping[str, PowerSurface]
        | Callable[["ClusterSim"], Mapping[str, PowerSurface]]
        | None = None,
    ) -> SimResult:
        """Step a scenario: per round, apply events -> allocate -> measure
        -> feed telemetry back to the controller.

        ``policy_surfaces`` may be a mapping (static predicted surfaces) or
        a callable ``sim -> mapping`` re-evaluated each round (the node set
        changes under arrivals/failures).  Predictor-backed controllers
        (``ecoshift_online``) ignore it and serve their own surfaces; they
        receive each round's telemetry via ``ingest_telemetry`` and
        invalidate their warm caches only for surfaces that actually moved.
        """
        if isinstance(controller, str):
            from repro.core import policies as policies_mod

            controller = policies_mod.get_controller(controller, self.system)
        records: list[RoundRecord] = []
        for r in range(scenario.n_rounds):
            events = scenario.events_at(r)
            touched: list[str] = []
            for ev in events:
                touched.extend(self.apply_event(ev))
            if touched:
                controller.invalidate(touched)
            seen = (
                policy_surfaces(self)
                if callable(policy_surfaces)
                else policy_surfaces
            )
            _, recv, pool = self.partition()
            b = scenario.budget_at(r)
            res = self.run_round(
                controller,
                budget=pool if b is None else b,
                policy_surfaces=seen,
                receivers=recv,
                round_index=r,
            )
            records.append(
                RoundRecord(
                    round=r,
                    result=res,
                    pool=pool,
                    n_alive=len(self.alive_nodes()),
                    events=events,
                    power_price=scenario.price_at(r),
                    telemetry=self.last_telemetry,
                )
            )
            controller.ingest_telemetry(self.last_telemetry)
        return SimResult(policy=controller.policy, records=records)
