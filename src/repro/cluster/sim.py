"""Time-stepped multi-round cluster simulation engine (paper §5.4, temporal).

``ClusterSim`` owns the cluster state and steps a :class:`Scenario` against
a stateful :class:`~repro.cluster.controller.Controller`:

 1. apply this round's events (failures, stragglers, arrivals, phase
    changes) and invalidate the controller's per-receiver warm state;
 2. partition donors/receivers, derive (or read) the reclaimed budget;
 3. controller allocates; the engine measures true improvements.

State is **columnar** (DESIGN.md §11): a :class:`NodeTable` keeps caps,
liveness, slowdowns and interned surface/app ids as struct-of-arrays, so
partitioning, event application and measurement are numpy passes instead of
per-node Python.  ``NodeState`` dataclass views are materialized on demand
(``sim.nodes``) for compatibility — assigning a node list re-ingests it.

Measurement is *vectorized*: the engine evaluates each distinct
(surface, slowdown) class once over all of its receivers' cap vectors and
draws the whole ``[n, n_repeats, 2]`` noise block in one call.  The RNG
stream is *identical* to the sequential loop (numpy ``Generator`` array
fills consume the bit stream in element order), so improvements match the
legacy path bit-for-bit — certified by tests/test_cluster.py.
``measure_improvements_loop`` keeps the legacy per-node loop as the
equivalence/benchmark reference.

Every vectorized measurement is emitted as **array-native telemetry**
(:class:`repro.cluster.predictor.TelemetryBatch` — the same mean measured
runtimes and improvements, bit-for-bit, with lazy
:class:`~repro.cluster.predictor.TelemetryRecord` views): ``run_round``
stashes the round's batch in ``last_telemetry`` and ``run`` hands it to the
controller's ``ingest_telemetry`` hook after each round, closing the online
prediction loop (DESIGN.md §10).

Controllers exposing ``supports_grouped`` (the DP policies) receive a
:class:`~repro.core.types.ReceiverBatch` instead of per-instance AppSpec
lists, enabling group-collapsed allocation: one option table and one DP
super-stage per behaviour class (DESIGN.md §11).

A :class:`~repro.core.topology.PowerTopology` attaches a **hierarchical
power-domain tree** (DESIGN.md §12): the table interns each node's owning
leaf domain, the engine accounts per-domain committed draw (receiver
baselines + donor natural draw) each round, hierarchy-aware controllers
(``supports_hierarchical``) allocate through per-domain capped frontiers,
and a sim-side conservation check asserts no domain ever draws above its
cap — including mid-scenario ``DomainCapChange`` deratings.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time as _time
import zlib
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.cluster import budget as budget_mod
from repro.cluster import scenario as scenario_mod
from repro.cluster.predictor import TelemetryBatch
from repro.cluster.scenario import Scenario
from repro.core.surfaces import PowerSurface, measured_runtime
from repro.core.types import (
    Allocation,
    AppSpec,
    EmulationResult,
    ReceiverBatch,
    SystemSpec,
)

#: per-round offset into the measurement RNG stream (round 0 == the legacy
#: single-round stream, so migrated paths reproduce run_round exactly)
_ROUND_STRIDE = 1000003

#: process-global batch sequence: seq values are unique across *all* sims,
#: so a controller reused by two sims can never mistake one sim's batch
#: chain for the other's (the delta contract keys on seq continuity)
_BATCH_SEQ = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class NodeState:
    node_id: int
    app: AppSpec  # instance (name is unique per node)
    base_app: str  # underlying app name (surface / predictor identity)
    caps: tuple[float, float]
    alive: bool = True
    slowdown: float = 1.0  # straggler factor on the true surface


@dataclasses.dataclass(frozen=True)
class _SlowedSurface(PowerSurface):
    base: PowerSurface
    slowdown: float

    def runtime(self, c, g):
        return self.base.runtime(c, g) * self.slowdown

    def power_draw(self, c, g):
        return self.base.power_draw(c, g)

    def improvement(self, base, c, g):
        # relative improvement is *exactly* invariant under a constant
        # slowdown: delegate so a straggler's option table digests
        # bit-identical to its healthy peers' (the class-merge invariant
        # the grouped solvers rely on; computing (s*t0 - s*t1)/(s*t0)
        # instead would drift in the last float bit and split the class)
        return self.base.improvement(base, c, g)


# ---------------------------------------------------------------------------
# Columnar node state
# ---------------------------------------------------------------------------


class _Interner:
    """Append-only string -> small-int table shared by a NodeTable."""

    __slots__ = ("strings", "_ids")

    def __init__(self):
        self.strings: list[str] = []
        self._ids: dict[str, int] = {}

    def intern(self, s: str) -> int:
        i = self._ids.get(s)
        if i is None:
            i = len(self.strings)
            self.strings.append(s)
            self._ids[s] = i
        return i

    def __getitem__(self, i: int) -> str:
        return self.strings[i]


#: dirty-row log horizon: consumers lagging more than this many bumps
#: behind fall back to a full rebuild
_DIRTY_HORIZON = 64


@functools.cache
def _device_patch_fn():
    """Donated row scatter shared by every device-view column: the donation
    reuses the resident buffer so a steady-state refresh uploads only the
    dirty rows."""
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def patch(col, rows, vals):
        return col.at[rows].set(vals)

    return patch


class DeviceView:
    """Device-resident mirror of the hot :class:`NodeTable` columns.

    The fused steady-state round (DESIGN.md §14/§17) keeps its decision
    pipeline on device; this view gives the engine the matching residency
    for the numeric cluster state: ``caps``/``alive``/``slowdown``/
    ``domain_id`` live as jax device arrays (float64 preserved), and
    :meth:`refresh` syncs them against the table's dirty-row log — one
    donated row scatter per changed column in steady state.  Growth is
    O(growth), not O(cluster): the resident prefix is reused as-is on
    device and only the appended tail uploads (``extends`` counts these
    repacks, mirroring the fused banks' compaction story).  A full
    re-upload happens only on an unprovable delta or when more than half
    the table moved.  Counters (``uploads_full`` / ``uploads_rows`` /
    ``extends``) expose the churn boundary to profiling tools.
    """

    _COLS = ("caps", "alive", "slowdown", "domain_id")

    def __init__(self, table: "NodeTable"):
        self._table = table
        self.version = -1
        self._n = -1
        self.uploads_full = 0
        self.uploads_rows = 0
        self.extends = 0
        self.caps = None
        self.alive = None
        self.slowdown = None
        self.domain_id = None

    def refresh(self) -> "DeviceView":
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        t = self._table
        if t.version == self.version and self._n == len(t):
            return self
        dirty = t.dirty_since(self.version) if self.version >= 0 else None
        with enable_x64():
            # patching more than half the table costs more dispatches than
            # one bulk upload
            if dirty is None or len(dirty) > max(1, len(t) // 2):
                for c in self._COLS:
                    setattr(self, c, jnp.asarray(getattr(t, c)))
                self.uploads_full += 1
            else:
                if len(t) > self._n:
                    # device-side extend (rows are append-only): keep the
                    # resident prefix, upload only the appended tail
                    for c in self._COLS:
                        tail = jnp.asarray(getattr(t, c)[self._n:])
                        setattr(
                            self, c,
                            jnp.concatenate([getattr(self, c), tail]),
                        )
                    self.extends += 1
                    self.uploads_rows += len(t) - self._n
                    dirty = dirty[dirty < self._n]
                if len(dirty):
                    rows = jnp.asarray(dirty)
                    patch = _device_patch_fn()
                    for c in self._COLS:
                        vals = jnp.asarray(getattr(t, c)[dirty])
                        setattr(self, c, patch(getattr(self, c), rows, vals))
                    self.uploads_rows += int(len(dirty))
        self.version = t.version
        self._n = len(t)
        return self


class NodeTable:
    """Struct-of-arrays cluster node state.

    Columns: ``caps [n,2]``, ``alive [n]``, ``slowdown [n]``,
    ``node_ids [n]`` plus interned-id columns ``base_gid`` (true-surface /
    base-app name), ``sid_gid`` (the instance AppSpec's surface id),
    ``name_gid`` (instance name) and ``sclass_gid``, all indexing the shared
    :class:`_Interner`.  Rows are append-only (failures flip ``alive``).

    **Delta tracking** (DESIGN.md §13): every mutation bumps ``version``
    and logs the *dirty rows* it touched.  Consumers remember the version
    they last materialized against and ask :meth:`dirty_since` for exactly
    the rows that moved — natural-draw caching, partitioning, receiver
    batches and the per-domain draw accounting all update O(churn) state
    instead of rebuilding whole-cluster arrays each round.  A coarse
    ``bump()`` (no rows) marks everything dirty, so legacy callers stay
    correct by falling back to full rebuilds.
    """

    def __init__(self):
        self.interner = _Interner()
        self.node_ids = np.empty(0, dtype=np.int64)
        self.caps = np.empty((0, 2), dtype=np.float64)
        self.alive = np.empty(0, dtype=bool)
        self.slowdown = np.empty(0, dtype=np.float64)
        self.base_gid = np.empty(0, dtype=np.int32)
        self.sid_gid = np.empty(0, dtype=np.int32)
        self.name_gid = np.empty(0, dtype=np.int32)
        self.sclass_gid = np.empty(0, dtype=np.int32)
        #: owning leaf power-domain id (PowerTopology preorder; -1 = none)
        self.domain_id = np.empty(0, dtype=np.int32)
        self.names: list[str] = []
        self.version = 0
        self._row_of: dict[int, int] | None = None
        #: (version, dirty row array | None-for-everything) ring
        self._dirty_log: list[tuple[int, np.ndarray | None]] = []
        self._device_view: DeviceView | None = None

    def __len__(self) -> int:
        return len(self.node_ids)

    @property
    def strings(self) -> list[str]:
        return self.interner.strings

    def bump(self, rows: Sequence[int] | np.ndarray | None = None) -> None:
        """Advance ``version``; ``rows`` are the row indices this mutation
        touched (``None`` marks the whole table dirty)."""
        self.version += 1
        if rows is not None:
            rows = np.asarray(rows, dtype=np.int64)
        self._dirty_log.append((self.version, rows))
        if len(self._dirty_log) > _DIRTY_HORIZON:
            del self._dirty_log[: len(self._dirty_log) - _DIRTY_HORIZON]

    def dirty_since(self, version: int) -> np.ndarray | None:
        """Rows dirtied in ``(version, self.version]``, or None when the
        log can't prove a bound (horizon exceeded, unbounded bump, or a
        ``version`` this table never issued)."""
        if version == self.version:
            return np.empty(0, dtype=np.int64)
        if version > self.version:
            return None
        log = self._dirty_log
        if not log or log[0][0] > version + 1:
            return None
        parts = []
        for v, rows in log:
            if v <= version:
                continue
            if rows is None:
                return None
            parts.append(rows)
        if not parts:
            return None
        return np.unique(np.concatenate(parts))

    def device_view(self) -> DeviceView:
        """Refreshed device-resident mirror of the hot numeric columns
        (lazily created; O(churn) donated row patches in steady state)."""
        if self._device_view is None:
            self._device_view = DeviceView(self)
        return self._device_view.refresh()

    @staticmethod
    def from_nodes(nodes: Sequence[NodeState]) -> "NodeTable":
        t = NodeTable()
        if not nodes:
            return t
        t.node_ids = np.array([n.node_id for n in nodes], dtype=np.int64)
        t.caps = np.array([n.caps for n in nodes], dtype=np.float64)
        t.alive = np.array([n.alive for n in nodes], dtype=bool)
        t.slowdown = np.array([n.slowdown for n in nodes], dtype=np.float64)
        t.names = [n.app.name for n in nodes]
        t.base_gid = np.array(
            [t.interner.intern(n.base_app) for n in nodes], dtype=np.int32
        )
        t.sid_gid = np.array(
            [t.interner.intern(n.app.surface_id) for n in nodes], dtype=np.int32
        )
        t.name_gid = np.array(
            [t.interner.intern(n.app.name) for n in nodes], dtype=np.int32
        )
        t.sclass_gid = np.array(
            [t.interner.intern(n.app.sclass) for n in nodes], dtype=np.int32
        )
        t.domain_id = np.full(len(nodes), -1, dtype=np.int32)
        return t

    def append(
        self,
        *,
        node_id: int,
        name: str,
        base_app: str,
        surface_id: str,
        sclass: str,
        caps: tuple[float, float],
        domain_id: int = -1,
    ) -> None:
        self.node_ids = np.append(self.node_ids, np.int64(node_id))
        self.caps = np.concatenate(
            [self.caps, np.asarray([caps], dtype=np.float64)]
        )
        self.alive = np.append(self.alive, True)
        self.slowdown = np.append(self.slowdown, 1.0)
        self.names.append(name)
        self.base_gid = np.append(
            self.base_gid, np.int32(self.interner.intern(base_app))
        )
        self.sid_gid = np.append(
            self.sid_gid, np.int32(self.interner.intern(surface_id))
        )
        self.name_gid = np.append(
            self.name_gid, np.int32(self.interner.intern(name))
        )
        self.sclass_gid = np.append(
            self.sclass_gid, np.int32(self.interner.intern(sclass))
        )
        self.domain_id = np.append(self.domain_id, np.int32(domain_id))
        if self._row_of is not None:
            self._row_of[int(node_id)] = len(self.node_ids) - 1

    def next_node_id(self) -> int:
        return 1 + int(self.node_ids.max()) if len(self) else 0

    def rows_for_ids(self, ids: Sequence[int]) -> np.ndarray:
        if self._row_of is None:
            self._row_of = {
                int(nid): r for r, nid in enumerate(self.node_ids)
            }
        return np.array([self._row_of[int(i)] for i in ids], dtype=np.int64)

    def view(self, row: int) -> NodeState:
        s = self.interner.strings
        return NodeState(
            node_id=int(self.node_ids[row]),
            app=AppSpec(
                name=self.names[row],
                sclass=s[self.sclass_gid[row]],
                surface_id=s[self.sid_gid[row]],
            ),
            base_app=s[self.base_gid[row]],
            caps=(float(self.caps[row, 0]), float(self.caps[row, 1])),
            alive=bool(self.alive[row]),
            slowdown=float(self.slowdown[row]),
        )

    def views(self, rows: Sequence[int] | None = None) -> list[NodeState]:
        if rows is None:
            rows = range(len(self))
        return [self.view(r) for r in rows]


def build_nodes(
    system: SystemSpec,
    apps: Sequence[AppSpec],
    *,
    n_nodes: int,
    seed: int,
    initial_caps: tuple[float, float] | None = None,
) -> list[NodeState]:
    """Place ``n_nodes`` instances by cycling a shuffled app list."""
    rng = np.random.default_rng(seed)
    order = list(apps)
    rng.shuffle(order)
    caps = initial_caps or (system.init_cpu, system.init_gpu)
    nodes = []
    for i in range(n_nodes):
        a = order[i % len(order)]
        inst = AppSpec(
            name=f"{a.name}#n{i}", sclass=a.sclass, surface_id=a.surface_id
        )
        nodes.append(NodeState(node_id=i, app=inst, base_app=a.name, caps=caps))
    return nodes


# ---------------------------------------------------------------------------
# Round records
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    """Everything observed in one simulated round."""

    round: int
    result: EmulationResult
    pool: float  # donor-derived reclaimed pool this round
    n_alive: int
    events: tuple = ()
    power_price: float | None = None
    #: grid CO2 intensity this round (scenario carbon signal), if any
    carbon_intensity: float | None = None
    #: per-receiver noisy measurements: a TelemetryBatch on the vectorized
    #: path (iterable of TelemetryRecord views), () on the legacy loop path
    telemetry: object = ()
    #: per-domain draw / cap watts this round (topology sims only)
    domain_draw: dict | None = None
    domain_caps: dict | None = None
    #: PowerGuard columns (fault-injected runs, DESIGN.md §18): worst
    #: pre-derate cap excursion in watts, total watts the emergency derate
    #: clawed back, and the domains that excursed this round
    overdraw_w: float = 0.0
    derate_w: float = 0.0
    excursion_domains: tuple = ()
    #: receivers whose applied caps deviated from the command (NACK /
    #: partial / delayed actuation, or a PowerGuard derate)
    nacked: tuple = ()
    #: telemetry fault kinds applied to this round's batch
    telemetry_faults: tuple = ()

    @property
    def avg_improvement(self) -> float:
        return self.result.avg_improvement


@dataclasses.dataclass
class SimResult:
    """Trace of a whole scenario under one controller."""

    policy: str
    records: list[RoundRecord]

    @property
    def n_rounds(self) -> int:
        return len(self.records)

    @property
    def improvement_trace(self) -> np.ndarray:
        return np.array([r.avg_improvement for r in self.records])

    def improvements_of(self, name: str) -> np.ndarray:
        """Per-round improvement of one instance (NaN when not a receiver)."""
        return np.array(
            [r.result.improvements.get(name, np.nan) for r in self.records]
        )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ClusterSim:
    """Columnar multi-round cluster engine.

    Constructed either from a ``nodes`` list (ingested into a
    :class:`NodeTable`) or from an existing ``table``.  ``sim.nodes`` stays
    a readable/assignable list of :class:`NodeState` views for
    compatibility with the pre-columnar engine.
    """

    def __init__(
        self,
        system: SystemSpec,
        nodes: Sequence[NodeState] | None = None,
        surfaces: Mapping[str, PowerSurface] | None = None,
        n_repeats: int = 5,
        seed: int = 0,
        *,
        table: NodeTable | None = None,
        topology=None,
    ):
        self.system = system
        #: true surfaces keyed by *base* app name
        self.surfaces: Mapping[str, PowerSurface] = surfaces or {}
        self.n_repeats = n_repeats
        self.seed = seed
        self.table = (
            table if table is not None else NodeTable.from_nodes(nodes or [])
        )
        #: memoized straggler views: stable object identity per (app, slowdown)
        #: so controllers' identity-keyed option caches stay warm across rounds
        self._slowed: dict = {}
        #: natural-draw cache per base-app gid (identity-checked)
        self._naturals: dict[int, tuple[PowerSurface, float, float]] = {}
        #: whole-cluster natural-draw array, keyed by table version (the
        #: partition and the per-domain accounting both read it each round);
        #: delta-patched via the table's dirty-row log
        self._nat_cache: tuple[int, np.ndarray, np.ndarray] | None = None
        #: memoized partition per (version, nat identity): stable row-array
        #: objects double as identity tokens for downstream caches
        self._part_cache: tuple | None = None
        #: cached deterministic baseline runtimes (version, rows, t_base,
        #: per-(gid, slowdown) surface identities)
        self._tbase_cache: tuple | None = None
        #: memoized (base surface, slowdown) grouping per (version, rows)
        self._measure_groups_cache: tuple | None = None
        #: receiver-batch cache: (mode, version, rows, batch)
        self._batch_cache: tuple | None = None
        #: (alloc, names list, [n,2] caps array) of the latest round — the
        #: conservation check and measurement share one gather, and a
        #: cache-hit allocation skips it entirely
        self._alloc_caps_cache: tuple | None = None
        #: per-phase wall-clock of the latest run_round plus the fused
        #: split: alloc_device_s / alloc_solver (tools/profile_round)
        self.last_round_profile: dict[str, float | str] = {}
        #: telemetry emitted by the latest vectorized-measurement round
        self.last_telemetry: object = ()
        self._views_cache: tuple[int, list[NodeState]] | None = None
        #: hierarchical power-domain tree (repro.core.topology.PowerTopology)
        self.topology = None
        #: DomainCapChange routing: per-domain (round, cap) steps resolved
        #: through the provider-backed budget subsystem — a step applies
        #: from its round on, with the same float coercion as scenario
        #: budgets (repro.cluster.budget.OverrideBook)
        self._cap_overrides = budget_mod.OverrideBook()
        #: per-domain draw/cap observed by the latest topology round
        self.last_domain_draw: dict[str, float] | None = None
        self.last_domain_caps: dict[str, float] | None = None
        #: actuator registers (fault-injected runs): name -> (c, g) caps
        #: physically applied last round (absent = at table baseline), and
        #: name -> command queued by a one-round delayed application
        self._applied_caps: dict[str, tuple[float, float]] = {}
        self._pending_cmds: dict[str, tuple[float, float]] = {}
        #: ActuationReport / PowerGuard stats of the latest faulted round
        self.last_actuation: object | None = None
        self.last_guard: dict | None = None
        if topology is not None:
            self.attach_topology(topology)

    @staticmethod
    def build(
        system: SystemSpec,
        apps: Sequence[AppSpec],
        surfaces: Mapping[str, PowerSurface],
        *,
        n_nodes: int = 100,
        seed: int = 0,
        initial_caps: tuple[float, float] | None = None,
        topology=None,
    ) -> "ClusterSim":
        nodes = build_nodes(
            system, apps, n_nodes=n_nodes, seed=seed, initial_caps=initial_caps
        )
        return ClusterSim(
            system=system,
            nodes=nodes,
            surfaces=surfaces,
            seed=seed,
            topology=topology,
        )

    # -- power-domain topology ------------------------------------------------

    def attach_topology(self, topology) -> None:
        """Adopt a power-domain tree: intern every node's owning leaf.

        Raises if any current node id sits outside every leaf range —
        the engine-side counterpart of the scenario's build-time check.
        Interning happens before any state changes, so a failed attach
        leaves the sim exactly as it was.
        """
        t = self.table
        domain_id = (
            topology.leaf_of(t.node_ids).astype(np.int32) if len(t) else None
        )
        self.topology = topology
        self._cap_overrides = budget_mod.OverrideBook()
        if domain_id is not None:
            t.domain_id = domain_id
            t.bump()

    def _committed_draw(
        self, recv_rows: np.ndarray | None = None
    ) -> np.ndarray:
        """[n] per-node committed watts: a receiver pins its baseline cap
        allotment, a donor its natural draw, a dead node nothing.

        ``recv_rows`` forces those rows to receiver accounting — when a
        caller overrides ``run_round(receivers=...)``, a node the slack
        heuristic would call a donor still gets grown from its baseline,
        so it must commit its caps, not its natural draw.
        """
        t = self.table
        nat, donor = self._donor_mask()
        committed = np.where(donor, nat.sum(axis=1), t.caps.sum(axis=1))
        if recv_rows is not None and len(recv_rows):
            committed[recv_rows] = t.caps[recv_rows].sum(axis=1)
        committed[~t.alive] = 0.0
        return committed

    def domain_headroom(
        self,
        round_index: int = 0,
        recv_rows: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-domain ``(extra, committed, caps)`` at ``round_index``.

        ``caps`` resolves each domain's cap trace with persisted
        ``DomainCapChange`` overrides applied; ``committed`` aggregates the
        per-node committed draw up the tree (``recv_rows`` as in
        :meth:`_committed_draw`); ``extra`` is the headroom the
        hierarchical allocator may spend inside each domain (>= 0).
        """
        topo = self.topology
        caps = topo.cap_at(round_index, self._cap_overrides.active(round_index))
        leaf = np.zeros(len(topo), dtype=np.float64)
        t = self.table
        if len(t):
            owned = t.domain_id >= 0
            leaf += np.bincount(
                t.domain_id[owned],
                weights=self._committed_draw(recv_rows)[owned],
                minlength=len(topo),
            )
        committed = topo.aggregate_leaves(leaf)
        extra = np.clip(caps - committed, 0.0, None)
        return extra, committed, caps

    # -- node state ----------------------------------------------------------

    @property
    def nodes(self) -> list[NodeState]:
        """NodeState views of the columnar table (fresh list each access).

        Views are snapshots: mutate cluster state by *assigning* a node
        list (``sim.nodes = [...]``) or via :meth:`apply_events` — editing
        the returned list in place has no effect on the table.
        """
        cache = self._views_cache
        if cache is None or cache[0] != self.table.version:
            cache = (self.table.version, self.table.views())
            self._views_cache = cache
        return list(cache[1])

    @nodes.setter
    def nodes(self, value: Sequence[NodeState]) -> None:
        table = NodeTable.from_nodes(value)
        if self.topology is not None and len(table):
            # intern before swapping state in: a failed leaf_of leaves the
            # sim's previous table intact
            table.domain_id = self.topology.leaf_of(table.node_ids).astype(
                np.int32
            )
        self.table = table
        self._views_cache = None
        self._naturals.clear()
        self._nat_cache = None
        self._part_cache = None
        self._batch_cache = None
        self._tbase_cache = None
        self._measure_groups_cache = None

    def _surface(self, node: NodeState) -> PowerSurface:
        return self._surface_of(node.base_app, node.slowdown)

    def _surface_of(self, base_app: str, slowdown: float) -> PowerSurface:
        s = self.surfaces[base_app]
        if slowdown == 1.0:
            return s
        key = (base_app, slowdown)
        hit = self._slowed.get(key)
        if hit is None or hit.base is not s:
            hit = _SlowedSurface(s, slowdown)
            self._slowed[key] = hit
        return hit

    def alive_nodes(self) -> list[NodeState]:
        return [n for n in self.nodes if n.alive]

    def _nat_of_gid(self, gid: int) -> tuple[float, float]:
        """Cached natural draw of one base-app gid (identity-validated)."""
        t = self.table
        surf = self.surfaces[t.strings[gid]]
        hit = self._naturals.get(gid)
        if hit is None or hit[0] is not surf:
            c, g = surf.power_draw(1e9, 1e9)
            hit = (surf, float(c), float(g))
            self._naturals[gid] = hit
        return hit[1:]

    def _nat_gids_fresh(self, gids: np.ndarray) -> bool:
        t = self.table
        for gid in gids:
            hit = self._naturals.get(int(gid))
            if hit is None or hit[0] is not self.surfaces[t.strings[gid]]:
                return False
        return True

    def _natural_draws(self) -> np.ndarray:
        """[n, 2] natural (uncapped) component draws, one surface query per
        distinct base app (draws are cap- and slowdown-independent).

        The assembled array is cached per table version (validated against
        per-gid surface identity, so online surface swaps still refresh).
        When the table's dirty-row log bounds what moved since the cached
        version, only the dirty rows are refilled — the steady-state round
        never rebuilds the whole-cluster array (DESIGN.md §13).
        """
        t = self.table
        cache = self._nat_cache
        if cache is not None and cache[0] == t.version:
            if self._nat_gids_fresh(cache[2]):
                return cache[1]
            cache = None
        if cache is not None:
            dirty = t.dirty_since(cache[0])
            if dirty is not None and self._nat_gids_fresh(cache[2]):
                nat = cache[1]
                if len(nat) < len(t):
                    nat = np.concatenate(
                        [nat, np.empty((len(t) - len(nat), 2), np.float64)]
                    )
                gids = cache[2]
                if len(dirty):
                    d_gids = t.base_gid[dirty]
                    for gid in np.unique(d_gids):
                        nat[dirty[d_gids == gid]] = self._nat_of_gid(int(gid))
                    gids = np.union1d(gids, np.unique(d_gids))
                self._nat_cache = (t.version, nat, gids)
                return nat
        nat = np.empty((len(t), 2), dtype=np.float64)
        gids = np.unique(t.base_gid)
        for gid in gids:
            nat[t.base_gid == gid] = self._nat_of_gid(int(gid))
        self._nat_cache = (t.version, nat, gids)
        return nat

    def _donor_mask(self) -> tuple[np.ndarray, np.ndarray]:
        """(natural draws [n, 2], donor mask [n]): a node donates iff its
        natural draw sits below its caps on both components (margin 1 W).
        The one donor predicate shared by partitioning and the per-domain
        committed-draw accounting."""
        t = self.table
        nat = self._natural_draws()
        slack = t.caps - nat
        donor = t.alive & (slack[:, 0] > 1.0) & (slack[:, 1] > 1.0)
        return nat, donor

    def partition_rows(self) -> tuple[np.ndarray, np.ndarray, float]:
        """Array-native partition: (donor_rows, receiver_rows, pool).

        A node donates iff its natural draw sits below its caps on both
        components (margin 1 W); a dead node donates its entire cap
        allotment.  The result is memoized per (table version, natural-draw
        array): steady-state rounds return the *same* row-array objects,
        which downstream caches (receiver batches, measurement groups) use
        as identity tokens.
        """
        t = self.table
        if not len(t):
            z = np.empty(0, dtype=np.int64)
            return z, z, 0.0
        nat = self._natural_draws()
        c = self._part_cache
        if c is not None and c[0] == t.version and c[1] is nat:
            return c[2], c[3], c[4]
        _, donor = self._donor_mask()
        recv = t.alive & ~donor
        dead = ~t.alive
        pool = float(
            t.caps[dead].sum() + (t.caps - nat)[donor].sum()
        )
        out = (np.flatnonzero(donor), np.flatnonzero(recv), pool)
        self._part_cache = (t.version, nat, *out)
        return out

    def partition(self) -> tuple[list[NodeState], list[NodeState], float]:
        """(donors, receivers, reclaimed_pool) as NodeState views."""
        donors, recv, pool = self.partition_rows()
        return self.table.views(donors), self.table.views(recv), pool

    # -- events ---------------------------------------------------------------

    def apply_events(self, events: Sequence) -> list[str]:
        """Apply one round's scenario events in a single columnar pass.

        Events mutate the table's columns in place (order preserved —
        later events see earlier ones), replacing the legacy one-O(n)-
        list-rebuild-per-event path; returns affected instance names.
        """
        t = self.table
        touched: list[str] = []
        dirty: list[np.ndarray] = []
        for event in events:
            if isinstance(event, scenario_mod.NodeFailure):
                rows = np.flatnonzero(
                    np.isin(t.node_ids, np.asarray(event.node_ids))
                )
                touched.extend(t.names[r] for r in rows)
                t.alive[rows] = False
                dirty.append(rows)
            elif isinstance(event, scenario_mod.StragglerOnset):
                rows = np.flatnonzero(t.node_ids == event.node_id)
                t.slowdown[rows] = event.slowdown
                touched.extend(t.names[r] for r in rows)
                dirty.append(rows)
            elif isinstance(event, scenario_mod.PhaseChange):
                if event.surface_id not in self.surfaces:
                    raise KeyError(f"unknown surface {event.surface_id!r}")
                rows = np.flatnonzero(t.node_ids == event.node_id)
                gid = np.int32(t.interner.intern(event.surface_id))
                # rebind the instance's surface identity too, so
                # predictor-backed controllers resolve the new phase
                t.base_gid[rows] = gid
                t.sid_gid[rows] = gid
                touched.extend(t.names[r] for r in rows)
                dirty.append(rows)
            elif isinstance(event, scenario_mod.NodeArrival):
                if event.surface is not None:
                    # a genuinely new app: register its ground-truth surface
                    self.surfaces = {
                        **self.surfaces, event.app.name: event.surface
                    }
                if event.app.name not in self.surfaces:
                    raise KeyError(
                        f"no surface for arriving app {event.app.name!r}"
                    )
                nid = t.next_node_id()
                domain_id = -1
                if self.topology is not None:
                    if event.domain is not None:
                        domain_id = self.topology.require_leaf(event.domain)
                    else:
                        # the assigned id must fall inside some leaf range
                        try:
                            domain_id = int(self.topology.leaf_of([nid])[0])
                        except ValueError:
                            raise ValueError(
                                f"arrival of {event.app.name!r} at round "
                                f"{event.round} got node id {nid}, which no "
                                f"leaf domain owns — pass "
                                f"NodeArrival(domain=...) to place it"
                            ) from None
                caps = event.caps or (self.system.init_cpu, self.system.init_gpu)
                t.append(
                    node_id=nid,
                    name=f"{event.app.name}#n{nid}",
                    base_app=event.app.name,
                    surface_id=event.app.surface_id,
                    sclass=event.app.sclass,
                    caps=caps,
                    domain_id=domain_id,
                )
                dirty.append(np.array([len(t) - 1], dtype=np.int64))
            elif isinstance(event, scenario_mod.DomainCapChange):
                if self.topology is None:
                    raise ValueError(
                        "DomainCapChange requires an attached PowerTopology"
                    )
                if event.domain not in self.topology.index:
                    raise KeyError(f"unknown domain {event.domain!r}")
                self._cap_overrides.set(
                    self.topology.index[event.domain], event.round, event.cap
                )
            else:
                known = ", ".join(
                    c.__name__ for c in scenario_mod.Event.__args__
                )
                raise TypeError(
                    f"unknown event type {type(event).__name__!r}: {event!r} "
                    f"(expected one of: {known}; fault events attach via "
                    f"Scenario.with_faults, not the event timeline)"
                )
        rows = (
            np.unique(np.concatenate(dirty))
            if dirty
            else np.empty(0, dtype=np.int64)
        )
        t.bump(rows)
        return touched

    def apply_event(self, event) -> list[str]:
        """Apply one scenario event; returns affected instance names."""
        return self.apply_events([event])

    # -- measurement ----------------------------------------------------------

    def _measure_groups(self, rows: np.ndarray):
        """Distinct (base surface, slowdown) classes among ``rows`` as
        (gid, slowdown, member positions into ``rows``) triples.

        Keys pack (gid, interned slowdown rank) into one int64 so the
        grouping is a cheap integer sort instead of a structured-array
        argsort; the (gid asc, slowdown asc) group order and ascending
        member positions match the structured form exactly.  Memoized per
        (table version, rows object) — the batch freshness probe, the
        surface fill and the measurement all share one grouping per round.
        """
        t = self.table
        c = self._measure_groups_cache
        if c is not None and c[0] == t.version and c[1] is rows:
            return c[2]
        sl = t.slowdown[rows]
        uniq_s, s_rank = np.unique(sl, return_inverse=True)
        key = t.base_gid[rows].astype(np.int64) * len(uniq_s) + s_rank
        uniq, inv = np.unique(key, return_inverse=True)
        order = np.argsort(inv, kind="stable")
        counts = np.bincount(inv, minlength=len(uniq))
        splits = np.split(order, np.cumsum(counts)[:-1])
        ns = len(uniq_s)
        groups = [
            (int(uniq[k] // ns), float(uniq_s[uniq[k] % ns]), splits[k])
            for k in range(len(uniq))
        ]
        self._measure_groups_cache = (t.version, rows, groups)
        return groups

    def _measure_rows(
        self,
        rows: np.ndarray,
        base: np.ndarray,
        new: np.ndarray,
        rng: np.random.Generator,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized measurement core: per-receiver mean measured runtimes
        at (baseline, allocated) caps plus relative improvements — the same
        arrays back both the engine's reported improvements and the
        telemetry batch, so the two are bit-identical by construction.

        Baseline runtimes are deterministic per (surface, slowdown, caps)
        row, so they are cached across rounds and re-evaluated only for
        groups touching dirty rows or swapped surfaces — allocated-caps
        runtimes (and the per-round noise) are always fresh.
        """
        n = len(rows)
        if n == 0:
            z = np.zeros(0, dtype=np.float64)
            return z, z, z
        t = self.table
        strings = t.strings
        groups = self._measure_groups(rows)
        t_base: np.ndarray | None = None
        dirty_mask: np.ndarray | None = None
        csurfs: dict = {}
        c = self._tbase_cache
        if c is not None:
            cv, crows, ctb, cs = c
            if cv == t.version and crows is rows:
                t_base = ctb.copy()
                csurfs = dict(cs)
                dirty_mask = np.zeros(n, dtype=bool)
            else:
                d = t.dirty_since(cv)
                if (
                    d is not None
                    and len(crows) == n
                    and self._rows_ascending(rows)
                    and np.array_equal(crows, rows)
                ):
                    t_base = ctb.copy()
                    csurfs = dict(cs)
                    dirty_mask = np.zeros(n, dtype=bool)
                    dirty_mask[
                        np.searchsorted(rows, np.intersect1d(d, rows))
                    ] = True
        if t_base is None:
            t_base = np.empty(n, dtype=np.float64)
        t_new = np.empty(n, dtype=np.float64)
        for gid, slowdown, ii in groups:
            surf = self.surfaces[strings[gid]]
            tn = np.asarray(surf.runtime(new[ii, 0], new[ii, 1]), np.float64)
            t_new[ii] = tn * slowdown
            if (
                dirty_mask is None
                or csurfs.get((gid, slowdown)) is not surf
                or dirty_mask[ii].any()
            ):
                tb = np.asarray(
                    surf.runtime(base[ii, 0], base[ii, 1]), np.float64
                )
                t_base[ii] = tb * slowdown
            csurfs[(gid, slowdown)] = surf
        self._tbase_cache = (t.version, rows, t_base, csurfs)

        sigma = self.system.noise_sigma
        if sigma > 0:
            # C-order fill == the sequential per-(node, repeat, base/new)
            # scalar draws of the legacy loop
            factors = np.exp(rng.normal(0.0, sigma, size=(n, self.n_repeats, 2)))
            t0 = (t_base[:, None] * factors[:, :, 0]).mean(axis=1)
            t1 = (t_new[:, None] * factors[:, :, 1]).mean(axis=1)
        else:
            t0, t1 = t_base, t_new
        imp = (t0 - t1) / t0
        return t0, t1, imp

    def _rows_for_nodes(self, recv_nodes: Sequence[NodeState]) -> np.ndarray:
        return self.table.rows_for_ids([n.node_id for n in recv_nodes])

    def measure_improvements(
        self,
        recv_nodes: Sequence[NodeState],
        alloc: Allocation,
        rng: np.random.Generator,
    ) -> dict[str, float]:
        """Vectorized measurement of all receivers x repeats.

        One surface evaluation per distinct (app, slowdown) class and one
        RNG fill for the whole noise block; bit-for-bit equal to
        :func:`measure_improvements_loop`.
        """
        rows = self._rows_for_nodes(recv_nodes)
        base = self.table.caps[rows]
        names = [self.table.names[r] for r in rows]
        new = np.array([alloc.caps[nm] for nm in names], dtype=np.float64)
        _, _, imp = self._measure_rows(rows, base, new, rng)
        return {nm: float(imp[i]) for i, nm in enumerate(names)}

    def measure_improvements_loop(
        self,
        recv_nodes: Sequence[NodeState],
        alloc: Allocation,
        rng: np.random.Generator,
    ) -> dict[str, float]:
        """Legacy per-node measurement loop (equivalence/benchmark reference)."""
        improvements: dict[str, float] = {}
        for node in recv_nodes:
            surf = self._surface(node)
            c, g = alloc.caps[node.app.name]
            base_ts, new_ts = [], []
            for _ in range(self.n_repeats):
                base_ts.append(
                    measured_runtime(
                        surf,
                        *node.caps,
                        rng=rng,
                        noise_sigma=self.system.noise_sigma,
                    )
                )
                new_ts.append(
                    measured_runtime(
                        surf, c, g, rng=rng, noise_sigma=self.system.noise_sigma
                    )
                )
            t0, t1 = float(np.mean(base_ts)), float(np.mean(new_ts))
            improvements[node.app.name] = (t0 - t1) / t0
        return improvements

    # -- rounds ---------------------------------------------------------------

    def round_rng(self, policy: str, round_index: int) -> np.random.Generator:
        """Measurement RNG: round 0 replays the legacy run_round stream."""
        return np.random.default_rng(
            self.seed
            + zlib.crc32(policy.encode()) % 100003
            + round_index * _ROUND_STRIDE
        )

    def _fill_true_surfaces(
        self, rows: np.ndarray, surfaces: list
    ) -> None:
        strings = self.table.strings
        for gid, slowdown, ii in self._measure_groups(rows):
            surf = self._surface_of(strings[gid], slowdown)
            for i in ii:
                surfaces[i] = surf

    @staticmethod
    def _rows_ascending(rows: np.ndarray) -> bool:
        """The delta-patch caches position-match via searchsorted/setdiff1d,
        which require ascending (partition-ordered) row arrays; explicit
        ``run_round(receivers=...)`` callers may pass any order and must
        fall back to full rebuilds."""
        return len(rows) < 2 or bool(np.all(rows[1:] > rows[:-1]))

    def _batch_surfaces_fresh(self, rows: np.ndarray, batch) -> bool:
        """One identity probe per (surface, slowdown) class: catches true
        surfaces swapped without a table bump (direct reassignment)."""
        strings = self.table.strings
        for gid, slowdown, ii in self._measure_groups(rows):
            if batch.surfaces[ii[0]] is not self._surface_of(
                strings[gid], slowdown
            ):
                return False
        return True

    def _patch_batch(
        self, mode: str, c: tuple, rows: np.ndarray
    ) -> ReceiverBatch | None:
        """Derive this round's batch from the cached one, or None to force
        a full rebuild.

        Three outcomes, in order: the cached batch is returned unchanged
        when nothing moved (same version, same rows, surfaces still
        identity-fresh); a copy-on-write *patched* batch carrying the
        delta contract is returned when the dirty-row log bounds what
        changed and the patched surfaces probe fresh; otherwise None —
        unbounded change, non-partition row order (searchsorted/setdiff
        need ascending rows), or a surface swapped without dirtying its
        rows (e.g. NodeArrival re-registering an app's ground truth).
        """
        t = self.table
        _, c_version, c_rows, c_batch = c
        if c_version == t.version and c_rows is rows:
            if mode != "true" or self._batch_surfaces_fresh(rows, c_batch):
                return c_batch
            return None  # surfaces swapped underneath: rebuild
        dirty = t.dirty_since(c_version)
        if (
            dirty is None
            or not self._rows_ascending(rows)
            or not self._rows_ascending(c_rows)
        ):
            return None
        joined = np.setdiff1d(rows, c_rows, assume_unique=True)
        left = np.setdiff1d(c_rows, rows, assume_unique=True)
        changed = np.union1d(
            np.intersect1d(dirty, rows, assume_unique=False), joined
        )
        pos = np.searchsorted(rows, changed)
        strings = t.strings
        if mode == "skip":
            surfaces: list = [None] * len(rows)
        else:
            surfaces = list(c_batch.surfaces)
        if len(joined) or len(left):
            # membership moved: carry surviving surfaces over by row id
            # (vectorized), rebuild the positional columns
            names = [t.names[r] for r in rows]
            surface_ids = [strings[t.sid_gid[r]] for r in rows]
            if mode == "true":
                common = np.setdiff1d(rows, joined, assume_unique=True)
                sarr = np.empty(len(rows), dtype=object)
                old = np.array(c_batch.surfaces, dtype=object)
                sarr[np.searchsorted(rows, common)] = old[
                    np.searchsorted(c_rows, common)
                ]
                surfaces = sarr.tolist()
        else:
            names = list(c_batch.names)
            surface_ids = list(c_batch.surface_ids)
            for p in pos:
                surface_ids[p] = strings[t.sid_gid[rows[p]]]
        if mode == "true":
            for p in pos:
                r = rows[p]
                surfaces[p] = self._surface_of(
                    strings[t.base_gid[r]], float(t.slowdown[r])
                )
        batch = ReceiverBatch(
            names=names,
            surface_ids=surface_ids,
            baselines=t.caps[rows],
            surfaces=surfaces,
            domain_ids=(
                t.domain_id[rows] if self.topology is not None else None
            ),
            seq=next(_BATCH_SEQ),
            prev_seq=c_batch.seq,
            delta=tuple(int(p) for p in pos),
            removed=tuple(t.names[r] for r in left),
        )
        if mode == "true" and not self._batch_surfaces_fresh(rows, batch):
            return None
        # carry the name -> baseline map across patched batches: row
        # baselines are immutable, so only joins/leaves/changes need
        # touching (the map is read-only by convention)
        prev_map = c_batch.__dict__.get("_baselines_map")
        if prev_map is not None:
            if len(joined) or len(left):
                m = dict(prev_map)
                for nm in batch.removed:
                    m.pop(nm, None)
                bl = batch.baselines
                for p in batch.delta:
                    m[names[p]] = (float(bl[p, 0]), float(bl[p, 1]))
                object.__setattr__(batch, "_baselines_map", m)
            else:
                object.__setattr__(batch, "_baselines_map", prev_map)
        self._batch_cache = (mode, t.version, rows, batch)
        return batch

    def _receiver_batch(
        self,
        rows: np.ndarray,
        policy_surfaces: Mapping[str, PowerSurface] | None,
        sees_truth: bool,
        *,
        skip_surfaces: bool = False,
    ) -> ReceiverBatch:
        """Columnar receiver view for group-collapsing controllers.

        ``skip_surfaces`` leaves the surface column unfilled for
        controllers that serve their own surfaces (``ecoshift_online``) —
        ground truth must never even transit their inputs (DESIGN.md §10
        information discipline).

        Batches are cached per (mode, table version, receiver rows): an
        event-free round returns the previous batch object unchanged
        (``delta == ()``), and a round whose dirty rows are bounded by the
        table's delta log ships a patched copy with the changed positions
        in ``delta`` — the O(churn) contract incremental controllers key
        their warm grouping state on (DESIGN.md §13).
        """
        t = self.table
        mode = (
            "skip" if skip_surfaces
            else "true" if (policy_surfaces is None or sees_truth)
            else None
        )
        c = self._batch_cache
        if mode is not None and c is not None and c[0] == mode:
            batch = self._patch_batch(mode, c, rows)
            if batch is not None:
                return batch
        names = [t.names[r] for r in rows]
        strings = t.strings
        surface_ids = [strings[t.sid_gid[r]] for r in rows]
        surfaces = [None] * len(rows)  # type: ignore[list-item]
        if skip_surfaces:
            pass
        elif policy_surfaces is not None and not sees_truth:
            surfaces = [policy_surfaces[nm] for nm in names]
        else:
            self._fill_true_surfaces(rows, surfaces)
        batch = ReceiverBatch(
            names=names,
            surface_ids=surface_ids,
            baselines=t.caps[rows],
            surfaces=surfaces,
            domain_ids=t.domain_id[rows] if self.topology is not None else None,
            seq=next(_BATCH_SEQ),
        )
        if mode is not None:
            self._batch_cache = (mode, t.version, rows, batch)
        return batch

    def _alloc_caps_array(self, alloc: Allocation, names) -> np.ndarray:
        """[n, 2] allocated caps aligned with ``names`` — one gather shared
        by the conservation check and the measurement, memoized while both
        the allocation and the names list are the reused warm objects."""
        c = self._alloc_caps_cache
        if c is not None and c[0] is alloc and c[1] is names:
            return c[2]
        new = np.array([alloc.caps[nm] for nm in names], dtype=np.float64)
        self._alloc_caps_cache = (alloc, names, new)
        return new

    def _check_domain_conservation(
        self,
        recv_rows: np.ndarray,
        names: Sequence[str],
        base: np.ndarray,
        alloc: Allocation,
        round_index: int,
        headroom: tuple[np.ndarray, np.ndarray, np.ndarray],
        *,
        enforce: bool,
    ) -> None:
        """Sim-side per-domain draw accounting after an allocation.

        Every domain's draw (committed + allocated extra, aggregated up the
        tree) is recorded in ``last_domain_draw`` / ``last_domain_caps``;
        with ``enforce`` a cap violation raises — the conservation
        guarantee of the hierarchical allocator.  Flat controllers on a
        topology sim only get the accounting (their violations are the
        point of the comparison benchmarks).
        """
        topo = self.topology
        t = self.table
        new = self._alloc_caps_array(alloc, names)
        extra_node = new.sum(axis=1) - base.sum(axis=1) if len(names) else []
        leaf = np.zeros(len(topo), dtype=np.float64)
        if len(names):
            leaf += np.bincount(
                t.domain_id[recv_rows],
                weights=extra_node,
                minlength=len(topo),
            )
        spend = topo.aggregate_leaves(leaf)
        extra, committed, caps = headroom
        draw = committed + spend
        dnames = topo.names
        self.last_domain_draw = dict(zip(dnames, draw.tolist()))
        self.last_domain_caps = dict(zip(dnames, caps.tolist()))
        if enforce:
            # the allocator is accountable for the *extra* it places: it can
            # never spend past a domain's headroom.  (A cap already below
            # the committed baseline draw is unsatisfiable under the
            # monotone-upgrade model — the allocator just gets 0 headroom.)
            over = np.flatnonzero(spend > extra + 1e-6)
            if over.size:
                i = int(over[0])
                raise RuntimeError(
                    f"round {round_index}: domain {dnames[i]!r} draws "
                    f"{draw[i]:.3f} W over its {caps[i]:.3f} W cap "
                    f"(allocated {spend[i]:.3f} W > {extra[i]:.3f} W headroom)"
                )

    def _actuate_and_guard(
        self,
        recv_rows: np.ndarray,
        names: Sequence[str],
        base: np.ndarray,
        new: np.ndarray,
        budget: float,
        round_index: int,
        headroom,
        injector,
    ):
        """Resolve actuation faults, then run the PowerGuard watchdog.

        **Actuation** replays this round's commanded caps through the
        per-receiver actuator registers: a NACKed receiver keeps its
        previously applied caps, a partial application moves only a
        fraction of the way from them, a delayed command lands *next*
        round (displacing that round's own command).  **PowerGuard** is
        the firmware-level safety net below the control-plane RPC channel:
        it checks the *applied* (post-fault) per-domain draw against the
        topology caps — and the cluster total against the round budget —
        and claws any overdraw back with the proportional emergency
        derate of ``PowerTopology.derate_factors``.  The derate lands
        within the same round, so a stuck actuator causes at most a
        sub-round excursion; registers settle on the post-derate caps, so
        the stuck state itself is safe from the next round on (DESIGN.md
        §18).

        Returns ``(applied, report, guard)``: the settled [n, 2] caps that
        measurement (and therefore telemetry) sees, the
        :class:`~repro.cluster.faults.ActuationReport` for the controller,
        and the PowerGuard stats dict (overdraw/derate/excursions).
        """
        from repro.cluster import faults as faults_mod

        t = self.table
        node_ids = t.node_ids[recv_rows]
        applied = new.copy()
        plan = injector.actuation_plan(round_index, list(names), node_ids)
        pend = self._pending_cmds
        for i, nm in enumerate(names):
            reg = self._applied_caps.get(nm)
            prev = np.asarray(reg, dtype=np.float64) if reg is not None else base[i]
            cmd = new[i]
            queued = pend.pop(nm, None)
            if queued is not None:
                # last round's delayed command lands now, displacing this
                # round's own command for this receiver
                cmd = np.asarray(queued, dtype=np.float64)
            kind, param = plan.get(nm, (None, 0.0))
            if kind == "nack":
                applied[i] = prev
            elif kind == "partial":
                applied[i] = prev + param * (cmd - prev)
            elif kind == "delay":
                pend[nm] = (float(new[i, 0]), float(new[i, 1]))
                applied[i] = prev
            else:
                applied[i] = cmd

        # -- PowerGuard: settle the applied caps under every power cap ----
        guard = {
            "overdraw_w": 0.0,
            "derate_w": 0.0,
            "excursion_domains": (),
        }
        extra_node = (
            applied.sum(axis=1) - base.sum(axis=1)
            if len(names)
            else np.zeros(0)
        )
        excursions: list[str] = []
        worst = 0.0
        pre_total = float(extra_node.sum()) if len(names) else 0.0
        if self.topology is not None and len(names):
            topo = self.topology
            leaf = np.zeros(len(topo), dtype=np.float64)
            leaf += np.bincount(
                t.domain_id[recv_rows], weights=extra_node, minlength=len(topo)
            )
            spend = topo.aggregate_leaves(leaf)
            allowed, committed, caps = headroom
            over = spend - allowed
            hot = np.flatnonzero(over > 1e-9)
            if hot.size:
                worst = float(over[hot].max())
                excursions.extend(topo.names[int(i)] for i in hot)
                factors = topo.derate_factors(spend, allowed)
                f_leaf = factors[t.domain_id[recv_rows]]
                applied = base + f_leaf[:, None] * (applied - base)
                extra_node = applied.sum(axis=1) - base.sum(axis=1)
        if len(names):
            tot = float(extra_node.sum())
            if tot > budget + 1e-9:
                worst = max(worst, tot - budget)
                if not excursions:
                    excursions.append("__budget__")
                scale = budget / tot if tot > 0 else 0.0
                applied = base + scale * (applied - base)
                extra_node = applied.sum(axis=1) - base.sum(axis=1)
            guard["derate_w"] = max(0.0, pre_total - float(extra_node.sum()))
        guard["overdraw_w"] = worst
        guard["excursion_domains"] = tuple(excursions)
        if self.topology is not None and len(names):
            # settled per-domain draw overwrites the commanded accounting
            topo = self.topology
            leaf = np.zeros(len(topo), dtype=np.float64)
            leaf += np.bincount(
                t.domain_id[recv_rows], weights=extra_node, minlength=len(topo)
            )
            spend = topo.aggregate_leaves(leaf)
            _, committed, caps = headroom
            self.last_domain_draw = dict(
                zip(topo.names, (committed + spend).tolist())
            )

        # -- settle registers + report ------------------------------------
        acked: list[str] = []
        nacked: list[str] = []
        applied_map: dict[str, tuple[float, float]] = {}
        for i, nm in enumerate(names):
            a = (float(applied[i, 0]), float(applied[i, 1]))
            self._applied_caps[nm] = a
            if (
                abs(a[0] - new[i, 0]) <= 1e-9
                and abs(a[1] - new[i, 1]) <= 1e-9
            ):
                acked.append(nm)
            else:
                nacked.append(nm)
                applied_map[nm] = a
        # non-receivers revert to baseline caps: drop their registers so a
        # later receiver round starts from the table baseline again
        cur = set(names)
        for nm in [k for k in self._applied_caps if k not in cur]:
            del self._applied_caps[nm]
            self._pending_cmds.pop(nm, None)
        report = faults_mod.ActuationReport(
            round=round_index,
            acked=tuple(acked),
            nacked=tuple(nacked),
            applied=applied_map,
        )
        return applied, report, guard

    def run_round(
        self,
        controller,
        budget: float | None = None,
        *,
        policy_surfaces: Mapping[str, PowerSurface] | None = None,
        receivers: Sequence[NodeState] | None = None,
        round_index: int = 0,
        use_loop_measurement: bool = False,
        _recv_rows: np.ndarray | None = None,
        _fault_injector=None,
    ) -> EmulationResult:
        """One redistribution round under a stateful controller.

        ``policy_surfaces`` is what the policy sees (predicted surfaces for
        EcoShift; defaults to true surfaces keyed per instance).  ``budget``
        defaults to the donor-derived reclaimed pool.  Controllers with
        ``supports_grouped`` allocate from a columnar ``ReceiverBatch``
        (group-collapsed DP); everyone else gets the per-instance view.
        """
        prof = self.last_round_profile = {}
        t = self.table
        tp = _time.perf_counter()
        if receivers is not None:
            _recv_rows = self._rows_for_nodes(receivers)
        if _recv_rows is not None and budget is not None:
            recv_rows = np.asarray(_recv_rows)
        else:
            _, part_rows, pool = self.partition_rows()
            recv_rows = (
                np.asarray(_recv_rows) if _recv_rows is not None else part_rows
            )
        b = float(pool if budget is None else budget)
        base = t.caps[recv_rows]

        hierarchical = self.topology is not None and getattr(
            controller, "supports_hierarchical", False
        )
        headroom = (
            self.domain_headroom(round_index, recv_rows)
            if self.topology is not None
            else None
        )
        prof["partition_s"] = _time.perf_counter() - tp

        tp = _time.perf_counter()
        names: Sequence[str] | None = None
        batch = None
        if hierarchical or getattr(controller, "supports_grouped", False):
            batch = self._receiver_batch(
                recv_rows,
                policy_surfaces,
                controller.sees_truth,
                skip_surfaces=getattr(controller, "serves_own_surfaces", False),
            )
            names = batch.names
        prof["batch_s"] = _time.perf_counter() - tp

        tp = _time.perf_counter()
        if hierarchical:
            controller.bind_topology(self.topology)
            alloc = controller.allocate_hierarchical(batch, b, headroom[0])
        elif batch is not None:
            alloc = controller.allocate_grouped(batch, b)
        else:
            recv_nodes = t.views(recv_rows)
            names = [n.app.name for n in recv_nodes]
            recv_apps = [n.app for n in recv_nodes]
            baselines = {n.app.name: n.caps for n in recv_nodes}
            true_by_inst = {n.app.name: self._surface(n) for n in recv_nodes}
            seen = (
                policy_surfaces if policy_surfaces is not None else true_by_inst
            )
            if controller.sees_truth:
                seen = true_by_inst
            alloc = controller.allocate(recv_apps, baselines, b, seen)
        prof["allocate_s"] = _time.perf_counter() - tp
        # fused-round split (DESIGN.md §14): seconds inside the jitted
        # device pipeline and which path produced the solution
        prof["alloc_device_s"] = float(
            getattr(controller, "last_device_s", 0.0) or 0.0
        )
        prof["alloc_solver"] = getattr(controller, "last_solver", None) or ""
        prof["alloc_fallback_reason"] = (
            getattr(controller, "last_fallback_reason", "") or ""
        )
        # resident-bank sync counters (DESIGN.md §17): cumulative cold
        # rebuilds / device compactions and the last round's slack
        # occupancy, so scenario tooling can prove churn stayed O(churn)
        fstats_fn = getattr(controller, "fused_stats", None)
        if fstats_fn is not None:
            fstats = fstats_fn()
            prof["alloc_fused_rebuilds"] = fstats.rebuilds
            prof["alloc_fused_compactions"] = fstats.compactions
            prof["alloc_fused_slack_utilization"] = fstats.slack_utilization

        tp = _time.perf_counter()
        if self.topology is not None:
            self._check_domain_conservation(
                recv_rows, names, base, alloc, round_index, headroom,
                enforce=hierarchical,
            )
        prof["conserve_s"] = _time.perf_counter() - tp

        # -- actuation + PowerGuard (fault-injected runs, DESIGN.md §18) --
        tp = _time.perf_counter()
        self.last_actuation = None
        self.last_guard = None
        applied: np.ndarray | None = None
        if _fault_injector is not None and names is not None:
            cmd = self._alloc_caps_array(alloc, names)
            applied, report, guard = self._actuate_and_guard(
                recv_rows, names, base, cmd, b, round_index,
                headroom, _fault_injector,
            )
            self.last_actuation = report
            self.last_guard = guard
            notify = getattr(controller, "notify_actuation", None)
            if notify is not None:
                notify(report)
        prof["actuate_s"] = _time.perf_counter() - tp

        tp = _time.perf_counter()
        rng = self.round_rng(controller.policy, round_index)
        if use_loop_measurement:
            recv_nodes = t.views(recv_rows)
            improvements = self.measure_improvements_loop(recv_nodes, alloc, rng)
            self.last_telemetry = ()
        else:
            new = (
                applied
                if applied is not None
                else self._alloc_caps_array(alloc, names)
            )
            t0, t1, imp = self._measure_rows(recv_rows, base, new, rng)
            improvements = dict(zip(names, imp.tolist()))
            self.last_telemetry = TelemetryBatch(
                round=round_index,
                inst_gids=t.name_gid[recv_rows],
                app_gids=t.base_gid[recv_rows],
                strings=t.strings,
                baseline_caps=base,
                allocated_caps=new,
                t_baseline=t0,
                t_allocated=t1,
                improvement=imp,
            )
        prof["measure_s"] = _time.perf_counter() - tp
        return EmulationResult(
            policy=controller.policy,
            improvements=improvements,
            allocation=alloc,
            budget=b,
        )

    def run(
        self,
        scenario: Scenario,
        controller,
        *,
        policy_surfaces: Mapping[str, PowerSurface]
        | Callable[["ClusterSim"], Mapping[str, PowerSurface]]
        | None = None,
    ) -> SimResult:
        """Step a scenario: per round, apply events -> allocate -> measure
        -> feed telemetry back to the controller.

        ``policy_surfaces`` may be a mapping (static predicted surfaces) or
        a callable ``sim -> mapping`` re-evaluated each round (the node set
        changes under arrivals/failures).  Predictor-backed controllers
        (``ecoshift_online``) ignore it and serve their own surfaces; they
        receive each round's telemetry via ``ingest_telemetry`` and
        invalidate their warm caches only for surfaces that actually moved.
        """
        if isinstance(controller, str):
            from repro.core import policies as policies_mod

            controller = policies_mod.get_controller(controller, self.system)
        if scenario.topology is not None:
            if self.topology is None:
                self.attach_topology(scenario.topology)
            elif self.topology is not scenario.topology:
                raise ValueError(
                    "scenario topology differs from the sim's attached one"
                )
        injector = None
        if getattr(scenario, "faults", ()):
            from repro.cluster import faults as faults_mod

            injector = faults_mod.FaultInjector(scenario.faults)
            # fresh actuator state per run: registers model the physical
            # caps of *this* run's actuation channel
            self._applied_caps.clear()
            self._pending_cmds.clear()
        records: list[RoundRecord] = []
        # receding-horizon controllers get a per-round budget outlook: the
        # provider-backed cap forecast plus the CO2 (or price) weight
        # signal over the controller's horizon (DESIGN.md §15)
        horizon = int(getattr(controller, "horizon", 1) or 1)
        feeds_outlook = horizon > 1 and hasattr(
            controller, "set_budget_outlook"
        )
        for r in range(scenario.n_rounds):
            if injector is not None:
                # controller crashes fire at round start, before the round's
                # events and solve — the replacement process (restored or
                # cold) must handle everything the round throws at it
                injector.maybe_crash(r, controller)
            events = scenario.events_at(r)
            touched = self.apply_events(events) if events else []
            if touched:
                controller.invalidate(touched)
            seen = (
                policy_surfaces(self)
                if callable(policy_surfaces)
                else policy_surfaces
            )
            _, recv_rows, pool = self.partition_rows()
            b = scenario.budget_at(r)
            if feeds_outlook:
                caps = [
                    pool if c is None else float(c)
                    for c in scenario.budget_forecast(r, horizon)
                ]
                caps[0] = float(pool if b is None else b)
                weights = scenario.carbon_forecast(r, horizon)
                if all(w is None for w in weights):
                    weights = scenario.price_forecast(r, horizon)
                controller.set_budget_outlook(
                    caps,
                    None
                    if all(w is None for w in weights)
                    else [1.0 if w is None else float(w) for w in weights],
                )
            res = self.run_round(
                controller,
                budget=pool if b is None else b,
                policy_surfaces=seen,
                round_index=r,
                _recv_rows=recv_rows,
                _fault_injector=injector,
            )
            if injector is not None:
                delivered, tkinds = injector.deliver(r, self.last_telemetry)
            else:
                delivered, tkinds = [self.last_telemetry], ()
            guard = self.last_guard or {}
            report = self.last_actuation
            records.append(
                RoundRecord(
                    round=r,
                    result=res,
                    pool=pool,
                    n_alive=int(np.count_nonzero(self.table.alive)),
                    events=events,
                    power_price=scenario.price_at(r),
                    carbon_intensity=scenario.carbon_at(r),
                    telemetry=self.last_telemetry,
                    domain_draw=self.last_domain_draw,
                    domain_caps=self.last_domain_caps,
                    overdraw_w=float(guard.get("overdraw_w", 0.0)),
                    derate_w=float(guard.get("derate_w", 0.0)),
                    excursion_domains=tuple(
                        guard.get("excursion_domains", ())
                    ),
                    nacked=tuple(report.nacked) if report is not None else (),
                    telemetry_faults=tkinds,
                )
            )
            for tb in delivered:
                controller.ingest_telemetry(tb)
            if injector is not None:
                injector.end_round(r, controller)
        return SimResult(policy=controller.policy, records=records)
