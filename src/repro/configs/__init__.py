"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full published config; ``smoke_config``
returns the reduced same-family config used by CPU smoke tests (full
configs are exercised only via the abstract dry-run).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig, MoEConfig, SSMConfig, XLSTMConfig

ARCHS = (
    "chatglm3_6b",
    "granite_3_2b",
    "mistral_nemo_12b",
    "gemma3_27b",
    "hubert_xlarge",
    "mixtral_8x22b",
    "grok_1_314b",
    "zamba2_2_7b",
    "llama_3_2_vision_11b",
    "xlstm_1_3b",
)

#: canonical ids (as in the assignment) -> module names
ALIASES = {
    "chatglm3-6b": "chatglm3_6b",
    "granite-3-2b": "granite_3_2b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma3-27b": "gemma3_27b",
    "hubert-xlarge": "hubert_xlarge",
    "mixtral-8x22b": "mixtral_8x22b",
    "grok-1-314b": "grok_1_314b",
    "zamba2-2.7b": "zamba2_2_7b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config: small widths/layers/experts, tiny vocab."""
    cfg = get_config(arch)
    unit, _, _ = cfg.scan_pattern()
    # two scan units so every layer kind and the scan path are exercised
    small_layers = len(unit) * 2 if unit else 2
    replace: dict = dict(
        n_layers=small_layers,
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        head_dim=32 if cfg.head_dim else None,
        frontend_dim=32 if cfg.frontend_dim else None,
        n_image_tokens=16 if cfg.family == "vlm" else cfg.n_image_tokens,
        d_vision=48 if cfg.family == "vlm" else cfg.d_vision,
        sliding_window=64 if cfg.sliding_window else None,
        grad_accum=1,
        remat="none",
    )
    if cfg.moe:
        replace["moe"] = MoEConfig(
            n_experts=4, top_k=2, capacity_factor=cfg.moe.capacity_factor,
            group_size=64,
        )
    if cfg.ssm:
        replace["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32
        )
    if cfg.xlstm:
        replace["xlstm"] = XLSTMConfig(
            slstm_every=cfg.xlstm.slstm_every, mlstm_chunk=32,
            conv_window=cfg.xlstm.conv_window,
        )
    return dataclasses.replace(cfg, **replace)


def all_arch_ids() -> list[str]:
    return list(ALIASES)
