"""chatglm3-6b [dense]: 28L d4096 32H GQA(kv=2) ff13696 v65024.

RoPE "2d" = partial rotary on half the head dim (rotary_fraction=0.5),
the GLM-family convention.  [arXiv:2406.12793; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rotary_fraction=0.5,
    rope_theta=10000.0,
    grad_accum=2,
    scan_unit=1,
    remat="full",
)
