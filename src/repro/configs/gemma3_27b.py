"""gemma3-27b [dense]: 62L d5376 32H GQA(kv=16) ff21504 v262144.

5:1 local(1024-token sliding window):global layer pattern, 128k context.
Scan unit = 6 (5 local + 1 global); 62 = 6*10 + 2 tail local layers.
[hf:google/gemma-3-27b-pt family; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    rope_theta=1000000.0,
    sliding_window=1024,
    local_per_global=5,
    scan_unit=6,
    grad_accum=8,
    remat="full",
)
