"""granite-3-2b [dense]: 40L d2048 32H GQA(kv=8) ff8192 v49155.

[hf:ibm-granite/granite-3.0-2b-base; hf].  Vocab 49155 pads to 49408 for
even sharding (ArchConfig.padded_vocab).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    rope_theta=10000.0,
    grad_accum=2,
    scan_unit=1,
    remat="full",
)
