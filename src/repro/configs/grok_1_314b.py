"""grok-1-314b [moe]: 64L d6144 48H GQA(kv=8) ff32768 v131072.

8 experts top-2.  [hf:xai-org/grok-1; unverified]
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25, group_size=1024),
    scan_unit=1,
    grad_accum=8,
    opt_factored=True,
    opt_moment_dtype="bfloat16",
    accum_dtype="bfloat16",

    param_dtype="bfloat16",
    remat="full",
)
