"""hubert-xlarge [audio]: 48L d1280 16H ff5120, 504 cluster targets.

Encoder-only (bidirectional attention, no decode path).  The conv waveform
frontend is a STUB: input_specs supply precomputed frame embeddings
[B, S, frontend_dim] (DESIGN.md §4).  [arXiv:2106.07447; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    encoder_only=True,
    frontend_dim=512,
    act="gelu",
    grad_accum=2,
    scan_unit=1,
    remat="full",
)
