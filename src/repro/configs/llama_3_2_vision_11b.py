"""llama-3.2-vision-11b [vlm]: 40L d4096 32H GQA(kv=8) ff14336 v128256.

Gated cross-attention image layers every 5th layer; the vision tower is a
STUB (input_specs supply patch embeddings [B, n_img, d_vision]).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1024,
    d_vision=1280,
    grad_accum=4,
    scan_unit=5,
    remat="full",
)
