"""mistral-nemo-12b [dense]: 40L d5120 32H GQA(kv=8) ff14336 v131072.

128k context; explicit head_dim=128 (not d_model/n_heads=160).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1000000.0,
    grad_accum=2,
    scan_unit=1,
    remat="full",
)
