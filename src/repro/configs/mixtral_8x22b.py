"""mixtral-8x22b [moe]: 56L d6144 48H GQA(kv=8) ff16384 v32768.

8 experts top-2, sliding-window attention (4096).  [arXiv:2401.04088; hf]
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    rope_theta=1000000.0,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25, group_size=1024),
    scan_unit=1,
    grad_accum=8,
    opt_factored=True,
    remat="full",
)
