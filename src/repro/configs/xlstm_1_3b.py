"""xlstm-1.3b [ssm]: 48L d2048 4H v50304, d_ff=0 (no FFN blocks).

sLSTM + mLSTM stack at ratio 7:1 (one sLSTM every 8 blocks); attention-free,
O(1)-state decode (the long_500k cell).  [arXiv:2405.04517; unverified]
"""

from repro.models.config import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8, mlstm_chunk=128, conv_window=4),
    grad_accum=4,
    scan_unit=8,
    remat="full",
)
