"""zamba2-2.7b [hybrid]: 54L d2560 32H(kv=32) ff10240 v32000 ssm_state=64.

Mamba2 backbone with ONE shared-weight attention+MLP block applied every
6th position (9 applications of the same parameters).  Scan unit = 6
(shared-attn+mamba, then 5 mamba).  [arXiv:2411.15242; hf]
"""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    shared_attn_every=6,
    grad_accum=4,
    scan_unit=6,
    remat="full",
)
