"""EcoShift core: the paper's contribution (predictor + DP allocator)."""
