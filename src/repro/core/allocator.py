"""EcoShift end-to-end allocator: profile -> predict -> DP (paper Fig. 3).

Ties the pieces together exactly as the workflow figure describes:

 1. offline: train the NCF predictor on historical applications
    (``train_offline``), emulating the continual production stream that the
    predictor of [39] learns from;
 2. online: for each unseen receiver, run the brief profiling phase and fit
    its embeddings (``onboard``);
 3. per redistribution round: predict surfaces for all receivers and solve
    the MCKP DP (``allocate``).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import ncf, policies, profiler
from repro.core.surfaces import PowerSurface
from repro.core.types import Allocation, AppSpec, SystemSpec


@dataclasses.dataclass
class EcoShiftAllocator:
    system: SystemSpec
    predictor: ncf.NCFPredictor
    #: per-app predicted surfaces, populated by onboard()
    predicted: dict[str, PowerSurface] = dataclasses.field(default_factory=dict)
    n_online_samples: int = 8

    @staticmethod
    def train_offline(
        system: SystemSpec,
        historical: Mapping[str, PowerSurface],
        cfg: ncf.NCFConfig = ncf.NCFConfig(),
        *,
        observed_fraction: float = 1.0,
        seed: int = 0,
    ) -> "EcoShiftAllocator":
        """Train the predictor on full/partial sweeps of historical apps."""
        rng = np.random.default_rng(seed)
        observations: dict[str, dict[tuple[float, float], float]] = {}
        for name, surf in historical.items():
            obs = profiler.dense_profile(surf, system, rng=rng)
            if observed_fraction < 1.0:
                keys = list(obs)
                keep = rng.choice(
                    len(keys),
                    size=max(4, int(observed_fraction * len(keys))),
                    replace=False,
                )
                obs = {keys[i]: obs[keys[i]] for i in keep}
            observations[name] = obs
        predictor = ncf.NCFPredictor.fit(system, observations, cfg)
        return EcoShiftAllocator(system=system, predictor=predictor)

    def onboard(self, name: str, true_surface: PowerSurface, *, seed: int = 0) -> None:
        """Online phase for an unseen app: profile K cells, fit embeddings,
        cache the predicted surface for subsequent allocation rounds."""
        samples = profiler.profile_app(
            true_surface, self.system, n_samples=self.n_online_samples, seed=seed
        )
        self.predictor = self.predictor.infer_app(name, samples)
        self.predicted[name] = self.predictor.predict_surface(name)

    def onboard_known(self, name: str) -> None:
        """Reuse a historical app's learned surface (repeat submission)."""
        self.predicted[name] = self.predictor.predict_surface(name)

    def allocate(
        self,
        receivers: Sequence[AppSpec],
        baselines: Mapping[str, tuple[float, float]],
        budget: float,
        *,
        solver: str = "sparse",
        surface_of: Mapping[str, str] | None = None,
    ) -> Allocation:
        """Solve one redistribution round on the *predicted* surfaces.

        ``surface_of`` maps receiver instance names to predictor app names
        (cluster emulation runs many instances of each app).
        """
        surface_of = surface_of or {a.name: a.name for a in receivers}
        surfaces = {a.name: self.predicted[surface_of[a.name]] for a in receivers}
        return policies.ecoshift(
            receivers, baselines, budget, self.system, surfaces, solver=solver
        )
