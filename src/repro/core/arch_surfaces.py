"""Power-performance surfaces for the 10 assigned architectures.

This is the integration that makes the framework's jobs first-class
EcoShift applications (DESIGN.md §2): the multi-pod dry-run's compiled-HLO
analysis (per-device flops / HBM bytes / collective bytes) feeds the
power-scaled roofline, producing T(host_cap, chip_cap) surfaces for every
(arch x shape) cell.  EcoShift then allocates reclaimed pod power across
training and serving jobs exactly as the paper allocates across CPU-GPU
benchmarks.

CPU(host)-vs-chip sensitivity emerges structurally:
 * decode jobs: small per-step device work + fixed host overhead
   (batching, sampling, detokenization) -> host-cap sensitive;
 * train/prefill of big models: MXU/HBM-bound -> chip-cap sensitive;
 * collective-bound jobs: ICI doesn't scale with either cap -> insensitive
   (pure donors, like the paper's minisweep class).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np

from repro.core.surfaces import PowerSurface
from repro.core.types import AppSpec, SYSTEM_TPU_V5E
from repro.roofline import model as roof

#: host-side fixed overhead per step (s) at full host clock
HOST_BASE_S = {"train": 0.010, "prefill": 0.010, "decode": 0.020}
#: host pipeline bandwidth at full clock (bytes/s)
HOST_BW = 2.0e9


@dataclasses.dataclass(frozen=True)
class RooflineSurface(PowerSurface):
    """T(host_cap, chip_cap) from per-device roofline terms."""

    flops_pd: float
    bytes_pd: float
    coll_pd: float
    host_bytes_pd: float
    host_base_s: float

    def runtime(self, c, g) -> np.ndarray:
        c = np.asarray(c, np.float64)
        g = np.asarray(g, np.float64)

        def one(ci, gi):
            ff = roof.freq_fraction(float(gi))
            hf = roof.host_fraction(float(ci))
            compute = self.flops_pd / (roof.PEAK_BF16_FLOPS * ff)
            memory = self.bytes_pd / (roof.HBM_BW * ff**0.5)
            coll = self.coll_pd / roof.ICI_BW
            host = self.host_base_s / hf + self.host_bytes_pd / (HOST_BW * hf)
            return max(compute, memory, coll, host)

        return np.vectorize(one)(c, g)

    def power_draw(self, c, g):
        """Natural draw scales with engine utilization at the cap."""
        t = self.runtime(c, g)
        ff = np.vectorize(lambda gi: roof.freq_fraction(float(gi)))(g)
        compute = self.flops_pd / (roof.PEAK_BF16_FLOPS * ff)
        memory = self.bytes_pd / (roof.HBM_BW * ff**0.5)
        util_chip = np.maximum(compute, memory) / np.maximum(t, 1e-12)
        hf = np.vectorize(lambda ci: roof.host_fraction(float(ci)))(c)
        host_t = self.host_base_s / hf + self.host_bytes_pd / (HOST_BW * hf)
        util_host = host_t / np.maximum(t, 1e-12)
        draw_g = np.minimum(g, (0.35 + 0.65 * util_chip) * roof.CHIP_TDP_W)
        draw_c = np.minimum(c, (0.30 + 0.70 * util_host) * roof.HOST_TDP_W)
        return draw_c, draw_g


DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _host_bytes(rec: dict) -> float:
    """Per-device host pipeline bytes per step, from the cell's batch."""
    kind = rec["kind"]
    if kind == "train":
        # tokens + targets + mask, amortized per device
        shape = {"train_4k": (256, 4096)}.get(rec["shape"], (256, 4096))
        return shape[0] * shape[1] * 12 / rec["n_devices"]
    if kind == "prefill":
        return 32768 * 32 * 4 / rec["n_devices"]
    return 128 * 8 / rec["n_devices"]  # one token per sequence


def build_arch_suite(
    dryrun_dir: pathlib.Path | str | None = None,
    *,
    mesh: str = "16x16",
) -> tuple[list[AppSpec], dict[str, PowerSurface]]:
    """Load every successful dry-run cell as an EcoShift application.

    Class labels are derived from the cell's bottleneck at nominal power:
    host-bound -> 'C', compute/memory-bound -> 'G', near-tied -> 'B',
    collective-bound -> 'N' (insensitive: ICI scales with neither cap).
    """
    d = pathlib.Path(dryrun_dir or DRYRUN_DIR)
    apps: list[AppSpec] = []
    surfaces: dict[str, PowerSurface] = {}
    for path in sorted(d.glob("*.json")):
        rec = json.loads(path.read_text())
        if "error" in rec or "skipped" in rec or rec.get("mesh") != mesh:
            continue
        if rec.get("layout", "fsdp_tp") != "fsdp_tp":
            continue  # hillclimb-variant artifacts duplicate baseline cells
        surf = RooflineSurface(
            flops_pd=rec["hlo_dot_flops_per_device"],
            bytes_pd=rec["hlo_traffic_bytes_per_device"],
            coll_pd=rec["hlo_collective_bytes_per_device"],
            host_bytes_pd=_host_bytes(rec),
            host_base_s=HOST_BASE_S[rec["kind"]],
        )
        name = f"{rec['arch']}:{rec['shape']}"
        # classify by sensitivity of the actual surface on the TPU grid
        grid = SYSTEM_TPU_V5E.grid
        base = (grid.cpu_min + 50, grid.gpu_min + 30)
        d_cpu = float(surf.improvement(base, grid.cpu_max, base[1]))
        d_gpu = float(surf.improvement(base, base[0], grid.gpu_max))
        if d_cpu > 0.05 and d_gpu > 0.05:
            sclass = "B"
        elif d_cpu > 0.05:
            sclass = "C"
        elif d_gpu > 0.05:
            sclass = "G"
        else:
            sclass = "N"
        apps.append(AppSpec(name=name, sclass=sclass, surface_id=name))
        surfaces[name] = surf
    return apps, surfaces
