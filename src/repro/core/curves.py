"""Per-application improvement curves F_i(b) (paper §3.2.2, Eq. 1).

For receiver ``i`` with baseline caps ``(c̄, ḡ)`` we enumerate upgraded cap
pairs on the feasible grid, compute the predicted relative improvement
``I_i(c, g)`` and the extra-power cost ``e = (c - c̄) + (g - ḡ)``, and then

 * keep only the best improvement at each distinct cost (Algorithm 1 l.2-18),
 * prune dominated options (an option is dominated if a cheaper-or-equal
   option achieves >= improvement),
 * optionally densify to a monotone value-vs-budget curve F_i(b) on a 1 W
   (or coarser) budget grid.

The sparse option table is what the faithful Algorithm-1 solver consumes;
the dense curve feeds the vectorized/JAX/Pallas (max,+) DP.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.surfaces import PowerSurface
from repro.core.types import CapGrid


@dataclasses.dataclass(frozen=True)
class OptionTable:
    """Pruned options for one receiver, sorted by increasing cost.

    Always contains the zero-cost option (0 extra power, 0 improvement,
    baseline caps) so a receiver may legally receive nothing.
    """

    name: str
    costs: np.ndarray  # [K] float64, strictly increasing, costs[0] == 0
    values: np.ndarray  # [K] float64, strictly increasing after pruning
    caps: np.ndarray  # [K, 2] the (c, g) pair realizing each option

    def __post_init__(self):
        assert self.costs.shape == self.values.shape
        assert self.caps.shape == (len(self.costs), 2)
        assert self.costs[0] == 0.0

    @property
    def k(self) -> int:
        return len(self.costs)


def build_options(
    name: str,
    surface: PowerSurface,
    baseline: tuple[float, float],
    grid: CapGrid,
    budget: float,
) -> OptionTable:
    """Enumerate + prune the upgraded-cap option set for one receiver.

    Matches Algorithm 1 lines 2-18: for every grid pair with
    ``c >= c̄, g >= ḡ`` and cost ``e <= B`` keep the best improvement at each
    distinct ``e``; then drop options dominated by cheaper ones, producing a
    strictly-increasing (cost, value) staircase.
    """
    c0, g0 = baseline
    pairs = grid.pairs()
    keep = (pairs[:, 0] >= c0 - 1e-9) & (pairs[:, 1] >= g0 - 1e-9)
    pairs = pairs[keep]
    cost = (pairs[:, 0] - c0) + (pairs[:, 1] - g0)
    feas = cost <= budget + 1e-9
    pairs, cost = pairs[feas], cost[feas]
    impr = np.asarray(surface.improvement(baseline, pairs[:, 0], pairs[:, 1]))

    # best improvement at each distinct cost
    order = np.lexsort((-impr, cost))
    pairs, cost, impr = pairs[order], cost[order], impr[order]
    first = np.ones(len(cost), dtype=bool)
    first[1:] = cost[1:] > cost[:-1] + 1e-9
    pairs, cost, impr = pairs[first], cost[first], impr[first]

    # ensure the zero-cost baseline option exists with value exactly 0
    if len(cost) == 0 or cost[0] > 1e-9:
        pairs = np.concatenate([[[c0, g0]], pairs], axis=0)
        cost = np.concatenate([[0.0], cost])
        impr = np.concatenate([[0.0], impr])
    else:
        impr[0] = 0.0
        pairs[0] = (c0, g0)

    # prune dominated: keep only strictly-improving staircase
    keep_idx = [0]
    best = impr[0]
    for j in range(1, len(cost)):
        if impr[j] > best + 1e-12:
            keep_idx.append(j)
            best = impr[j]
    sel = np.array(keep_idx)
    return OptionTable(name=name, costs=cost[sel], values=impr[sel], caps=pairs[sel])


def dense_curve(
    opts: OptionTable, budget: float, unit: float = 1.0
) -> tuple[np.ndarray, np.ndarray]:
    """Densify an option table to F_i(b) on a budget grid of ``unit`` watts.

    Returns ``(F, choice)`` with ``F[b] = max improvement at cost <= b*unit``
    (Eq. 1; monotone non-decreasing) and ``choice[b]`` the index into
    ``opts`` realizing it.  Costs are *rounded up* to the next unit so the
    densified solution never overspends.
    """
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    f = np.zeros(nb, dtype=np.float64)
    choice = np.zeros(nb, dtype=np.int32)
    cost_units = np.ceil(opts.costs / unit - 1e-9).astype(np.int64)
    # scatter the best option onto each occupied grid position: sort by
    # (unit cost asc, value desc, index asc) and keep each position's first
    # row — the first option attaining the position's max value, exactly the
    # strict-improvement sequential update; positions whose max value is
    # <= 0 keep the (0, choice 0) default
    valid = np.nonzero(cost_units < nb)[0]
    if valid.size:
        order = valid[
            np.lexsort((valid, -opts.values[valid], cost_units[valid]))
        ]
        cu_s = cost_units[order]
        first = np.ones(len(order), dtype=bool)
        first[1:] = cu_s[1:] != cu_s[:-1]
        take = order[first & (opts.values[order] > 0.0)]
        f[cost_units[take]] = opts.values[take]
        choice[cost_units[take]] = take
    # running max to enforce "cost <= b": a position keeps its own choice iff
    # it attains the running max (ties keep the later index, matching the
    # sequential update which only overwrote on strict decrease)
    run = np.maximum.accumulate(f)
    kept = np.empty(nb, dtype=bool)
    kept[0] = True
    kept[1:] = f[1:] >= run[:-1]
    src = np.maximum.accumulate(np.where(kept, np.arange(nb), 0))
    return run, choice[src]


def dense_curves_matrix(
    options: list[OptionTable], budget: float, unit: float = 1.0
) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-receiver dense curves: F [N, B+1], choices [N, B+1].

    Receivers sharing an ``OptionTable`` object (group-collapsed clusters
    replicate one table across a whole behaviour class) densify once; the
    stacked result gathers the shared rows.
    """
    slot_of: dict[int, int] = {}
    inv = np.empty(len(options), dtype=np.int64)
    fs, chs = [], []
    for i, o in enumerate(options):
        slot = slot_of.get(id(o))
        if slot is None:
            slot = len(fs)
            slot_of[id(o)] = slot
            f, ch = dense_curve(o, budget, unit)
            fs.append(f)
            chs.append(ch)
        inv[i] = slot
    return np.stack(fs)[inv], np.stack(chs)[inv]
