"""Emulation-based cluster evaluation (paper §5.4) — single-round facade.

Since the cluster control loop moved into ``repro.cluster`` (scenario /
controller / sim), this module is a thin wrapper kept for the paper-figure
benchmarks and tests: one ``ClusterEmulator`` is one ``ClusterSim`` plus
the legacy ``run_round(policy_name, ...)`` calling convention (a fresh
stateless controller per call, measurement RNG seeded exactly as before).

Multi-round studies — failures mid-run, straggler onsets, budget traces —
should use :class:`repro.cluster.sim.ClusterSim` with a
:class:`~repro.cluster.scenario.Scenario` directly; ``fail_nodes`` /
``add_straggler`` here mutate state between independent single rounds.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.cluster.sim import (  # noqa: F401  (re-exported legacy names)
    ClusterSim,
    NodeState,
    _SlowedSurface,
)
from repro.core import policies as policies_mod
from repro.core.surfaces import PowerSurface
from repro.core.types import AppSpec, EmulationResult, SystemSpec


@dataclasses.dataclass
class ClusterEmulator:
    system: SystemSpec
    nodes: list[NodeState]
    #: true surfaces keyed by *base* app name
    surfaces: Mapping[str, PowerSurface]
    n_repeats: int = 5
    seed: int = 0

    # -- construction --------------------------------------------------------

    @staticmethod
    def build(
        system: SystemSpec,
        apps: Sequence[AppSpec],
        surfaces: Mapping[str, PowerSurface],
        *,
        n_nodes: int = 100,
        seed: int = 0,
        initial_caps: tuple[float, float] | None = None,
    ) -> "ClusterEmulator":
        """Place ``n_nodes`` instances by cycling a shuffled app list."""
        sim = ClusterSim.build(
            system,
            apps,
            surfaces,
            n_nodes=n_nodes,
            seed=seed,
            initial_caps=initial_caps,
        )
        return ClusterEmulator(
            system=system, nodes=sim.nodes, surfaces=surfaces, seed=seed
        )

    def _sim(self) -> ClusterSim:
        """Engine view sharing this emulator's node list."""
        return ClusterSim(
            system=self.system,
            nodes=self.nodes,
            surfaces=self.surfaces,
            n_repeats=self.n_repeats,
            seed=self.seed,
        )

    # -- donor / receiver partition ------------------------------------------

    def _surface(self, node: NodeState) -> PowerSurface:
        return self._sim()._surface(node)

    def partition(self) -> tuple[list[NodeState], list[NodeState], float]:
        """(donors, receivers, reclaimed_pool) — see ClusterSim.partition."""
        return self._sim().partition()

    # -- one redistribution round ---------------------------------------------

    def run_round(
        self,
        policy: str,
        budget: float | None = None,
        *,
        policy_surfaces: Mapping[str, PowerSurface] | None = None,
        solver: str = "sparse",
        receivers: Sequence[NodeState] | None = None,
    ) -> EmulationResult:
        """Apply ``policy`` and measure improvements on true surfaces.

        ``policy_surfaces`` is what the policy sees (predicted surfaces for
        EcoShift; defaults to true surfaces keyed per instance).  ``budget``
        defaults to the donor-derived reclaimed pool.
        """
        kwargs = {"solver": solver} if policy == "ecoshift" else {}
        controller = policies_mod.get_controller(policy, self.system, **kwargs)
        return self._sim().run_round(
            controller,
            budget=budget,
            policy_surfaces=policy_surfaces,
            receivers=receivers,
        )

    # -- fault tolerance / stragglers -----------------------------------------

    def fail_nodes(self, node_ids: Sequence[int]) -> None:
        """Kill nodes; their power returns to the pool on the next round."""
        ids = set(node_ids)
        self.nodes = [
            dataclasses.replace(n, alive=False) if n.node_id in ids else n
            for n in self.nodes
        ]

    def add_straggler(self, node_id: int, slowdown: float) -> None:
        self.nodes = [
            dataclasses.replace(n, slowdown=slowdown) if n.node_id == node_id else n
            for n in self.nodes
        ]

    def alive_nodes(self) -> list[NodeState]:
        return [n for n in self.nodes if n.alive]
