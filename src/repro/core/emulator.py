"""Emulation-based cluster evaluation (paper §5.4).

A simulated cluster of N nodes, each running one application instance under
per-node (cpu, gpu) caps.  The emulator:

 * partitions instances into donors (natural draw below assigned caps) and
   receivers, and can derive the reclaimed pool B from donor headroom or
   accept B as an explicit input (the paper's policy studies sweep B
   directly — "EcoShift treats reclaimed power as an explicit input");
 * applies a distribution policy to get per-receiver caps;
 * "executes" each receiver under its caps — a true-surface lookup with
   multiplicative measurement noise, repeated ``n_repeats`` times (the paper
   repeats 5x) — and reports relative improvements vs the no-distribution
   baseline;
 * supports fault-tolerance studies: node failures return the failed node's
   whole budget to the pool and trigger re-optimization; stragglers degrade
   a node's surface by a slowdown factor.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core import policies as policies_mod
from repro.core.surfaces import PowerSurface, measured_runtime
from repro.core.types import Allocation, AppSpec, EmulationResult, SystemSpec


@dataclasses.dataclass(frozen=True)
class NodeState:
    node_id: int
    app: AppSpec  # instance (name is unique per node)
    base_app: str  # underlying app name (predictor identity)
    caps: tuple[float, float]
    alive: bool = True
    slowdown: float = 1.0  # straggler factor on the true surface


@dataclasses.dataclass
class ClusterEmulator:
    system: SystemSpec
    nodes: list[NodeState]
    #: true surfaces keyed by *base* app name
    surfaces: Mapping[str, PowerSurface]
    n_repeats: int = 5
    seed: int = 0

    # -- construction --------------------------------------------------------

    @staticmethod
    def build(
        system: SystemSpec,
        apps: Sequence[AppSpec],
        surfaces: Mapping[str, PowerSurface],
        *,
        n_nodes: int = 100,
        seed: int = 0,
        initial_caps: tuple[float, float] | None = None,
    ) -> "ClusterEmulator":
        """Place ``n_nodes`` instances by cycling a shuffled app list."""
        rng = np.random.default_rng(seed)
        order = list(apps)
        rng.shuffle(order)
        caps = initial_caps or (system.init_cpu, system.init_gpu)
        nodes = []
        for i in range(n_nodes):
            a = order[i % len(order)]
            inst = AppSpec(
                name=f"{a.name}#n{i}", sclass=a.sclass, surface_id=a.surface_id
            )
            nodes.append(
                NodeState(node_id=i, app=inst, base_app=a.name, caps=caps)
            )
        return ClusterEmulator(
            system=system, nodes=nodes, surfaces=surfaces, seed=seed
        )

    # -- donor / receiver partition ------------------------------------------

    def _surface(self, node: NodeState) -> PowerSurface:
        s = self.surfaces[node.base_app]
        if node.slowdown != 1.0:
            return _SlowedSurface(s, node.slowdown)
        return s

    def partition(self) -> tuple[list[NodeState], list[NodeState], float]:
        """(donors, receivers, reclaimed_pool).  A node donates iff its
        natural draw sits below its caps on both components (margin 1 W)."""
        donors, receivers = [], []
        pool = 0.0
        for node in self.nodes:
            if not node.alive:
                # a dead node donates its entire cap allotment
                pool += node.caps[0] + node.caps[1]
                continue
            nat_c, nat_g = self._surface(node).power_draw(1e9, 1e9)
            slack_c = node.caps[0] - float(nat_c)
            slack_g = node.caps[1] - float(nat_g)
            if slack_c > 1.0 and slack_g > 1.0:
                donors.append(node)
                pool += slack_c + slack_g
            else:
                receivers.append(node)
        return donors, receivers, pool

    # -- one redistribution round ---------------------------------------------

    def run_round(
        self,
        policy: str,
        budget: float | None = None,
        *,
        policy_surfaces: Mapping[str, PowerSurface] | None = None,
        solver: str = "sparse",
        receivers: Sequence[NodeState] | None = None,
    ) -> EmulationResult:
        """Apply ``policy`` and measure improvements on true surfaces.

        ``policy_surfaces`` is what the policy sees (predicted surfaces for
        EcoShift; defaults to true surfaces keyed per instance).  ``budget``
        defaults to the donor-derived reclaimed pool.
        """
        donors, recv_nodes, pool = self.partition()
        if receivers is not None:
            recv_nodes = list(receivers)
        b = float(pool if budget is None else budget)
        recv_apps = [n.app for n in recv_nodes]
        baselines = {n.app.name: n.caps for n in recv_nodes}
        true_by_inst = {n.app.name: self._surface(n) for n in recv_nodes}
        seen = (
            policy_surfaces
            if policy_surfaces is not None
            else true_by_inst
        )

        fn = policies_mod.POLICIES[policy]
        kwargs = {}
        if policy == "ecoshift":
            kwargs["solver"] = solver
        if policy == "oracle":
            kwargs["exhaustive"] = len(recv_nodes) <= 10
            seen = true_by_inst  # the Oracle always sees ground truth
        alloc: Allocation = fn(recv_apps, baselines, b, self.system, seen, **kwargs)

        import zlib

        rng = np.random.default_rng(self.seed + zlib.crc32(policy.encode()) % 100003)
        improvements: dict[str, float] = {}
        for node in recv_nodes:
            surf = true_by_inst[node.app.name]
            c, g = alloc.caps[node.app.name]
            base_ts, new_ts = [], []
            for _ in range(self.n_repeats):
                base_ts.append(
                    measured_runtime(
                        surf, *node.caps, rng=rng, noise_sigma=self.system.noise_sigma
                    )
                )
                new_ts.append(
                    measured_runtime(
                        surf, c, g, rng=rng, noise_sigma=self.system.noise_sigma
                    )
                )
            t0, t1 = float(np.mean(base_ts)), float(np.mean(new_ts))
            improvements[node.app.name] = (t0 - t1) / t0
        return EmulationResult(
            policy=policy, improvements=improvements, allocation=alloc, budget=b
        )

    # -- fault tolerance / stragglers -----------------------------------------

    def fail_nodes(self, node_ids: Sequence[int]) -> None:
        """Kill nodes; their power returns to the pool on the next round."""
        ids = set(node_ids)
        self.nodes = [
            dataclasses.replace(n, alive=False) if n.node_id in ids else n
            for n in self.nodes
        ]

    def add_straggler(self, node_id: int, slowdown: float) -> None:
        self.nodes = [
            dataclasses.replace(n, slowdown=slowdown) if n.node_id == node_id else n
            for n in self.nodes
        ]

    def alive_nodes(self) -> list[NodeState]:
        return [n for n in self.nodes if n.alive]


@dataclasses.dataclass(frozen=True)
class _SlowedSurface(PowerSurface):
    base: PowerSurface
    slowdown: float

    def runtime(self, c, g):
        return self.base.runtime(c, g) * self.slowdown

    def power_draw(self, c, g):
        return self.base.power_draw(c, g)
