"""Multiple-choice-knapsack solvers for reclaimed-power distribution (§3.2.2).

Three equivalent solvers (equivalence-tested against each other and against
exhaustive brute force):

 * ``solve_sparse``   — faithful Algorithm 1: dict-keyed sparse DP over the
                        distinct per-app extra-power levels, O(B * Σ K_i).
 * ``solve_dense``    — vectorized numpy DP over dense F_i(b) curves; each
                        stage is a (max,+)-convolution restricted to the K_i
                        option costs, O(B * Σ K_i) with numpy inner loops.
 * ``solve_dense_jax``— the same dense DP as a jit-compiled ``lax.scan``
                        (one stage per receiver), used by the Pallas kernel
                        path (repro.kernels.mckp_dp) and by the scaling
                        benchmarks.

All solvers return allocations in *watts spent per receiver* plus the cap
pair realizing it, and they all respect the monotone-upgrade model: a
receiver may always take the zero-cost baseline option.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.curves import OptionTable, dense_curves_matrix


@dataclasses.dataclass
class MCKPSolution:
    """Solution of one distribution round."""

    total_value: float  # Σ_i I_i  (N * average improvement)
    spent: float  # watts used out of the budget
    #: per-receiver picks: name -> (cost_watts, value, (c, g))
    picks: dict[str, tuple[float, float, tuple[float, float]]]

    def average_improvement(self) -> float:
        n = len(self.picks)
        return self.total_value / n if n else 0.0


# ---------------------------------------------------------------------------
# Faithful Algorithm 1 (sparse dict DP)
# ---------------------------------------------------------------------------


def solve_sparse(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Paper Algorithm 1 with parent-pointer backtracking.

    States are keyed by *used power* (floats straight from the option
    tables — no budget discretization), exactly like the pseudo-code's
    ``DP`` dict.  Costs within 1e-6 W are merged to keep the state count
    equal to the number of distinct achievable sums.
    """

    def qkey(u: float) -> float:
        return round(u, 6)

    # DP: used -> (score, parent_used, option_index)
    dp: dict[float, tuple[float, float, int]] = {0.0: (0.0, -1.0, -1)}
    stages: list[dict[float, tuple[float, float, int]]] = []
    for opt in options:
        ndp: dict[float, tuple[float, float, int]] = {}
        for u, (score, _, _) in dp.items():
            for j in range(opt.k):
                e = float(opt.costs[j])
                if u + e > budget + 1e-9:
                    continue
                key = qkey(u + e)
                s = score + float(opt.values[j])
                cur = ndp.get(key)
                if cur is None or s > cur[0]:
                    ndp[key] = (s, u, j)
        stages.append(ndp)
        dp = ndp

    # best end state, then walk parents backwards
    best_u = max(dp, key=lambda u: dp[u][0])
    total = dp[best_u][0]
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    u = best_u
    for i in range(len(options) - 1, -1, -1):
        score, parent, j = stages[i][qkey(u)]
        opt = options[i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        u = parent
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Dense-grid DP (numpy)
# ---------------------------------------------------------------------------


def _stage_maxplus(
    dp: np.ndarray, costs_u: np.ndarray, values: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """One (max,+) stage restricted to option costs.

    dp' [b] = max_j dp[b - cost_j] + value_j   (invalid b-cost_j masked)
    Returns (dp', argmax_j).
    """
    nb = dp.shape[0]
    # cand[j, b] = dp[b - c_j] + v_j
    idx = np.arange(nb)[None, :] - costs_u[:, None]  # [k, nb]
    valid = idx >= 0
    cand = np.where(valid, dp[np.clip(idx, 0, nb - 1)], -np.inf) + values[:, None]
    arg = np.argmax(cand, axis=0)  # [nb]
    out = cand[arg, np.arange(nb)]
    return out, arg.astype(np.int32)


def solve_dense(
    options: Sequence[OptionTable], budget: float, unit: float = 1.0
) -> MCKPSolution:
    """Vectorized dense DP at ``unit``-watt budget granularity."""
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    dp = np.zeros(nb, dtype=np.float64)
    args: list[np.ndarray] = []
    costs_per_app: list[np.ndarray] = []
    kept_per_app: list[np.ndarray] = []
    for opt in options:
        cu = np.ceil(opt.costs / unit - 1e-9).astype(np.int64)
        keep = cu < nb
        cu, vals = cu[keep], opt.values[keep]
        dp, arg = _stage_maxplus(dp, cu, vals)
        args.append(arg)
        costs_per_app.append(cu)
        kept_per_app.append(np.nonzero(keep)[0])

    b = int(np.argmax(dp))
    total = float(dp[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        j_local = int(args[i][b])
        j = int(kept_per_app[i][j_local])
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= int(costs_per_app[i][j_local])
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Dense-grid DP (JAX, scan over receivers)
# ---------------------------------------------------------------------------


def _jax_dp(f_mat, backend: str = "jax"):
    """jit-compiled forward DP over dense curves.

    f_mat: [N, NB] monotone curves (F_i). Returns (dp_final [NB],
    argk [N, NB]) where argk[i, b] is the spend chosen for receiver i when b
    units are available to receivers 0..i.

    The inner maximization DP'[b] = max_k DP[b-k] + F[k] is a full
    (max,+)-convolution; ``backend='pallas'`` routes it through the Pallas
    TPU kernel (repro.kernels.mckp_dp), 'jax' uses a pure-jnp masked gather.
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv = kops.maxplus_conv
    else:
        from repro.kernels import ref as kref

        conv = kref.maxplus_conv

    def stage(dp, f_row):
        out, arg = conv(dp, f_row)
        return out, arg

    @jax.jit
    def run(f_mat):
        dp0 = jnp.zeros(f_mat.shape[1], dtype=f_mat.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mat)
        return dp_final, args

    return run(f_mat)


def solve_dense_jax(
    options: Sequence[OptionTable],
    budget: float,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Dense DP via jit'd lax.scan (+ optional Pallas (max,+) kernel)."""
    import numpy as np

    f_mat, choices = dense_curves_matrix(list(options), budget, unit)
    dp_final, args = _jax_dp(f_mat, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    b = int(np.argmax(dp_final))
    total = float(dp_final[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        k = int(args[i, b])  # units granted to receiver i
        j = int(choices[i][k])  # option index realizing F_i(k)
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= k
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def _jax_dp_batch(f_mats, backend: str = "jax"):
    """Batched forward DP over R independent rounds.

    f_mats: [R, N, NB].  Returns (dp_final [R, NB], args [R, N, NB]): one
    scan over the N receiver stages where each stage is the *batched*
    (max,+) convolution over all R rounds at once (vmap over the Pallas
    kernel for ``backend='pallas'``).
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv_b = kops.maxplus_conv_batched
    else:
        from repro.kernels import ref as kref

        def conv_b(dp, f):
            return jax.vmap(kref.maxplus_conv)(dp, f)

    def stage(dp, f_rows):  # dp, f_rows: [R, NB]
        out, arg = conv_b(dp, f_rows)
        return out, arg

    @jax.jit
    def run(f_mats):
        r, _, nb = f_mats.shape
        dp0 = jnp.zeros((r, nb), dtype=f_mats.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mats.swapaxes(0, 1))
        return dp_final, args.swapaxes(0, 1)

    return run(f_mats)


def solve_dense_jax_batch(
    rounds: Sequence[Sequence[OptionTable]],
    budgets: Sequence[float],
    unit: float = 1.0,
    backend: str = "jax",
) -> list[MCKPSolution]:
    """Solve R independent dense-DP rounds with one vmapped scan.

    Each round is an (option tables, budget) pair — e.g. the rounds of a
    scenario trace, or one receiver set under a budget sweep.  Curves are
    densified on the widest budget grid; rounds with fewer receivers are
    padded with identity stages (F = [0, -inf, ...], which picks zero
    spend), and each round's argmax is restricted to its own budget range,
    so every solution equals its standalone ``solve_dense_jax`` call.
    """
    if len(rounds) != len(budgets):
        raise ValueError("rounds and budgets must have equal length")
    nbs = [int(np.floor(b / unit + 1e-9)) + 1 for b in budgets]
    nb = max(nbs)
    n_max = max(len(r) for r in rounds)
    f_all = np.empty((len(rounds), n_max, nb), dtype=np.float64)
    ch_all = np.zeros((len(rounds), n_max, nb), dtype=np.int32)
    pad_row = np.full(nb, -np.inf)
    pad_row[0] = 0.0
    for r, opts in enumerate(rounds):
        f, ch = dense_curves_matrix(list(opts), (nb - 1) * unit, unit)
        f_all[r, : len(opts)] = f
        ch_all[r, : len(opts)] = ch
        f_all[r, len(opts) :] = pad_row

    dp_final, args = _jax_dp_batch(f_all, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    sols: list[MCKPSolution] = []
    for r, opts in enumerate(rounds):
        b = int(np.argmax(dp_final[r, : nbs[r]]))
        total = float(dp_final[r, b])
        picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
        for i in range(n_max - 1, -1, -1):
            k = int(args[r, i, b])
            if i < len(opts):
                opt = opts[i]
                j = int(ch_all[r, i][k])
                picks[opt.name] = (
                    float(opt.costs[j]),
                    float(opt.values[j]),
                    (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
                )
            b -= k
        spent = sum(c for c, _, _ in picks.values())
        sols.append(MCKPSolution(total_value=total, spent=spent, picks=picks))
    return sols


# ---------------------------------------------------------------------------
# Exhaustive brute force (Oracle ground truth for small cases)
# ---------------------------------------------------------------------------


def brute_force(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Exhaustive DFS over the cross product of option sets.

    Exponential — used for the §6.3 Oracle on <= ~10 apps with pruned
    option sets, and to certify the DP solvers in tests.  A simple
    optimistic bound (sum of per-app max remaining values) prunes branches.
    """
    n = len(options)
    # optimistic suffix bound
    suffix_max = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        suffix_max[i] = suffix_max[i + 1] + float(np.max(options[i].values))

    best = {"total": -1.0, "choice": [0] * n}
    choice = [0] * n

    def dfs(i: int, used: float, value: float) -> None:
        if value + suffix_max[i] <= best["total"]:
            return
        if i == n:
            if value > best["total"]:
                best["total"] = value
                best["choice"] = list(choice)
            return
        opt = options[i]
        for j in range(opt.k - 1, -1, -1):
            e = float(opt.costs[j])
            if used + e > budget + 1e-9:
                continue
            choice[i] = j
            dfs(i + 1, used + e, value + float(opt.values[j]))
        choice[i] = 0

    dfs(0, 0.0, 0.0)
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i, opt in enumerate(options):
        j = best["choice"][i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=best["total"], spent=spent, picks=picks)
