"""Multiple-choice-knapsack solvers for reclaimed-power distribution (§3.2.2).

Three equivalent solvers (equivalence-tested against each other and against
exhaustive brute force):

 * ``solve_sparse``   — faithful Algorithm 1: dict-keyed sparse DP over the
                        distinct per-app extra-power levels, O(B * Σ K_i).
 * ``solve_dense``    — vectorized numpy DP over dense F_i(b) curves; each
                        stage is a (max,+)-convolution restricted to the K_i
                        option costs, O(B * Σ K_i) with numpy inner loops.
 * ``solve_dense_jax``— the same dense DP as a jit-compiled ``lax.scan``
                        (one stage per receiver), used by the Pallas kernel
                        path (repro.kernels.mckp_dp) and by the scaling
                        benchmarks.

All solvers return allocations in *watts spent per receiver* plus the cap
pair realizing it, and they all respect the monotone-upgrade model: a
receiver may always take the zero-cost baseline option.

**Group-collapsed solving** (DESIGN.md §11): real clusters replicate a small
number of behaviour classes across thousands of nodes, so receivers sharing
one option table collapse into a :class:`GroupedOptions` with multiplicity
``m``:

 * ``solve_sparse_grouped``    — bounded MCKP: each group's m-fold aggregate
                                 curve is built by binary-split (max,+)
                                 self-convolution (O(log m) convolutions),
                                 then one sparse DP runs over the ~G group
                                 super-stages instead of the N receivers.
                                 Bit-for-bit equal to ``solve_sparse`` on
                                 the name-sorted ungrouped expansion.
 * ``solve_dense_jax_grouped`` — repeated-stage scan: the lax.scan walks a
                                 per-receiver group-id sequence and gathers
                                 its stage curve from a [G, NB] matrix, so
                                 curves are densified once per group.
                                 Bitwise identical to ``solve_dense_jax``
                                 (same convolutions, same order).
 * ``solve_dense_grouped``     — the numpy analogue of the gather scan.

**Hierarchical solving** (DESIGN.md §12): facilities cascade caps down a
site → rack/PDU tree, so :func:`solve_hierarchical` turns each domain's
group-collapsed aggregates into a *capped value-vs-spend frontier* and an
upper-level DP convolves sibling frontiers to split every parent budget
subject to each domain's local cap.  A single root domain with cap >= the
cluster budget reproduces the flat grouped solve bit-for-bit.

Determinism contract: receivers with *byte-identical* option tables are
interchangeable, so every optimum is degenerate under permutations of their
picks.  ``solve_sparse`` canonicalizes — identical-table stages exchange
their chosen options so costs ascend in stage order, and ``total_value`` /
``spent`` are re-accumulated in stage order — which is exactly the form the
group-collapsed solver reproduces.  (Parity assumes option costs are well
above the 1e-6 W state-merge tolerance; true for watt-granular cap grids.)
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from collections import OrderedDict
from typing import MutableMapping, Sequence

import numpy as np

from repro.core.curves import OptionTable, dense_curve, dense_curves_matrix


class LRUCache(MutableMapping):
    """Bounded mapping with least-recently-used eviction.

    Drop-in for the plain-dict warm caches (aggregate curves, frontiers,
    pick multisets): ``get``/``[]`` refresh recency, inserts beyond
    ``maxsize`` evict the coldest entry.  Keeps long scenarios from growing
    warm state without bound across distinct budgets/digests.
    """

    def __init__(self, maxsize: int = 512):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()

    def __getitem__(self, key):
        val = self._d[key]
        self._d.move_to_end(key)
        return val

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def __setitem__(self, key, val):
        self._d[key] = val
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def __delitem__(self, key):
        del self._d[key]

    def __iter__(self):
        return iter(self._d)

    def __len__(self):
        return len(self._d)

    def clear(self):
        self._d.clear()

    def resize(self, maxsize: int) -> None:
        """Shrink or grow the bound in place, evicting coldest entries as
        needed.  In-place matters: solver state (e.g. ``HierState``) holds
        references to the same cache objects, so resizing must not rebind."""
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = maxsize
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)


@dataclasses.dataclass
class MCKPSolution:
    """Solution of one distribution round."""

    total_value: float  # Σ_i I_i  (N * average improvement)
    spent: float  # watts used out of the budget
    #: per-receiver picks: name -> (cost_watts, value, (c, g))
    picks: dict[str, tuple[float, float, tuple[float, float]]]
    #: hierarchical solves only: domain name -> watts spent inside it
    domain_spent: dict[str, float] | None = None

    def average_improvement(self) -> float:
        n = len(self.picks)
        return self.total_value / n if n else 0.0


# ---------------------------------------------------------------------------
# Faithful Algorithm 1 (sparse dict DP)
# ---------------------------------------------------------------------------


def _qkey(u: float) -> float:
    """State key: costs within 1e-6 W merge into one DP state.

    Defined as floor(u * 1e6 + 0.5) * 1e-6 so the scalar form and the
    vectorized :func:`_qkey_np` are bitwise identical (same float64 ops) —
    the grouped solver's array DP and the ungrouped dict DP must agree on
    every state key.  For grid-exact watt costs the key equals the sum
    itself, so per-step rounding order cannot diverge between the two.
    """
    return math.floor(u * 1e6 + 0.5) * 1e-6


def _qkey_np(u: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_qkey` (bitwise-identical float64 pipeline)."""
    return np.floor(u * 1e6 + 0.5) * 1e-6


def table_digest(opt: OptionTable) -> tuple:
    """Content identity of an option table (costs, values, caps bytes).

    Receivers whose tables digest equally are *interchangeable* in any MCKP
    — permuting their picks preserves value and feasibility.  This is the
    group key of the collapsed solvers, and the equivalence class within
    which ``solve_sparse`` canonicalizes its assignment.  Note a
    multiplicatively-slowed straggler digests equally to its healthy peers:
    relative improvements are invariant under constant slowdown.

    Memoized on the (frozen, content-immutable) table instance so warm
    controllers pay the bytes conversion once per table, not once per round.
    """
    d = opt.__dict__.get("_digest")
    if d is None:
        d = (opt.costs.tobytes(), opt.values.tobytes(), opt.caps.tobytes())
        object.__setattr__(opt, "_digest", d)
    return d


def _pick_tuples(opt: OptionTable) -> list:
    """Per-option ``(cost, value, (c, g))`` pick tuples, memoized on the
    table — the one representation every solver's ``picks`` dict uses."""
    pt = opt.__dict__.get("_pick_tuples")
    if pt is None:
        pt = [
            (float(c), float(v), (float(cc[0]), float(cc[1])))
            for c, v, cc in zip(opt.costs, opt.values, opt.caps)
        ]
        object.__setattr__(opt, "_pick_tuples", pt)
    return pt


_group_counter = itertools.count(1)


def _group_token(g: "GroupedOptions") -> int:
    """Process-unique identity token of one (immutable) GroupedOptions.

    Incremental controllers reuse group objects across rounds while their
    membership is unchanged, so token tuples are cheap round-over-round
    cache keys for merged-class plans (unlike ``id()``, tokens are never
    reused after garbage collection)."""
    t = g.__dict__.get("_token")
    if t is None:
        t = next(_group_counter)
        object.__setattr__(g, "_token", t)
    return t


def _canonical_solution(
    options: Sequence[OptionTable], js: list[int]
) -> MCKPSolution:
    """Assemble a solution from per-stage option choices in canonical form.

    Identical-table stages (same :func:`table_digest`) exchange their
    chosen options so option indices ascend in stage order, and
    ``total_value`` / ``spent`` are accumulated stage by stage — the one
    deterministic representative of the optimum's permutation class, and
    exactly what :func:`solve_sparse_grouped` reconstructs.
    """
    by_digest: dict[tuple, list[int]] = {}
    for i, opt in enumerate(options):
        by_digest.setdefault(table_digest(opt), []).append(i)
    for idxs in by_digest.values():
        if len(idxs) > 1:
            for i, j in zip(idxs, sorted(js[i] for i in idxs)):
                js[i] = j
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    total = 0.0
    spent = 0.0
    for i, opt in enumerate(options):
        j = js[i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        total += float(opt.values[j])
        spent += float(opt.costs[j])
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def solve_sparse(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Paper Algorithm 1 with parent-pointer backtracking.

    States are keyed by *used power* (floats straight from the option
    tables — no budget discretization), exactly like the pseudo-code's
    ``DP`` dict.  Costs within 1e-6 W are merged to keep the state count
    equal to the number of distinct achievable sums.  The returned solution
    is canonicalized (see :func:`_canonical_solution`) so interchangeable
    receivers always get their picks in ascending-cost stage order.
    """
    qkey = _qkey
    # DP: used -> (score, parent_used, option_index)
    dp: dict[float, tuple[float, float, int]] = {0.0: (0.0, -1.0, -1)}
    stages: list[dict[float, tuple[float, float, int]]] = []
    for opt in options:
        ndp: dict[float, tuple[float, float, int]] = {}
        for u, (score, _, _) in dp.items():
            for j in range(opt.k):
                e = float(opt.costs[j])
                if u + e > budget + 1e-9:
                    continue
                key = qkey(u + e)
                s = score + float(opt.values[j])
                cur = ndp.get(key)
                if cur is None or s > cur[0]:
                    ndp[key] = (s, u, j)
        stages.append(ndp)
        dp = ndp

    # best end state, then walk parents backwards
    best_u = max(dp, key=lambda u: dp[u][0])
    js: list[int] = [0] * len(options)
    u = best_u
    for i in range(len(options) - 1, -1, -1):
        _, parent, j = stages[i][qkey(u)]
        js[i] = j
        u = parent
    return _canonical_solution(options, js)


# ---------------------------------------------------------------------------
# Group-collapsed sparse DP (bounded MCKP via binary-split multiplicity)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupedOptions:
    """One behaviour class: a shared option table with its member receivers.

    All members share the table (same surface identity, baseline and
    slowdown class), so the group acts as a bounded multiple-choice item
    with multiplicity ``m = len(members)``.
    """

    table: OptionTable
    members: tuple[str, ...]

    @property
    def m(self) -> int:
        return len(self.members)


def expand_groups(groups: Sequence[GroupedOptions]) -> list[OptionTable]:
    """Ungrouped, name-sorted expansion (the parity reference ordering)."""
    out = [
        dataclasses.replace(g.table, name=name)
        for g in groups
        for name in g.members
    ]
    out.sort(key=lambda o: o.name)
    return out


def collapse_receivers(
    names: Sequence[str],
    surfaces: Sequence,
    baselines: Sequence[tuple[float, float]],
    build_table,
) -> list[GroupedOptions]:
    """Collapse aligned receiver columns into behaviour-class groups.

    Receivers sharing (surface identity, baseline) form one class;
    ``build_table(surface, baseline)`` is called once per class (a warm
    cache lookup on the controller path, a fresh ``curves.build_options``
    on the pure-policy path).
    """
    classes: dict[tuple, list] = {}
    for name, surf, base in zip(names, surfaces, baselines):
        key = (id(surf), base[0], base[1])
        slot = classes.get(key)
        if slot is None:
            classes[key] = [surf, (float(base[0]), float(base[1])), [name]]
        else:
            slot[2].append(name)
    return [
        GroupedOptions(
            table=build_table(surf, base), members=tuple(sorted(members))
        )
        for surf, base, members in classes.values()
    ]


def solve_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    solver: str = "sparse",
    unit: float = 1.0,
    curve_cache: MutableMapping | None = None,
    pick_cache: MutableMapping | None = None,
    plan_cache: MutableMapping | None = None,
    chain_cache: MutableMapping | None = None,
) -> MCKPSolution:
    """Solver dispatch for the group-collapsed paths (see ``solve_*_grouped``)."""
    if solver == "sparse":
        return solve_sparse_grouped(
            groups,
            budget,
            curve_cache=curve_cache,
            pick_cache=pick_cache,
            plan_cache=plan_cache,
            chain_cache=chain_cache,
        )
    if solver == "dense":
        return solve_dense_grouped(groups, budget, unit=unit)
    if solver in ("jax", "pallas"):
        return solve_dense_jax_grouped(groups, budget, unit=unit, backend=solver)
    raise ValueError(f"unknown solver {solver!r}")


def _dedupe_first_max(
    keys: np.ndarray, vals: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per distinct key keep the max value — first occurrence on ties.

    Mirrors the dict DP's ``cur is None or s > cur[0]`` update over the
    candidates in array order.  Returns (sorted unique keys, selector into
    the input arrays).
    """
    order = np.lexsort((np.arange(len(keys)), -vals, keys))
    k_sorted = keys[order]
    first = np.ones(len(order), dtype=bool)
    first[1:] = k_sorted[1:] != k_sorted[:-1]
    sel = order[first]
    return keys[sel], sel


def _micro_int(keys: np.ndarray) -> np.ndarray | None:
    """Exact micro-watt integers of quantized spend keys, or None.

    Every spend key in the sparse solvers is a :func:`_qkey` multiple of
    1e-6, i.e. ``float64(n) * 1e-6`` for an integer ``n`` — so ``n`` is
    recoverable exactly and ``float64(n) * 1e-6`` reproduces the key
    *bitwise*.  Returns None when any key fails the round-trip (non-qkey
    floats), which routes the caller to the generic lexsort path.
    """
    ints = np.round(keys * 1e6).astype(np.int64)
    recon = ints.astype(np.float64) * 1e-6
    if recon.tobytes() != keys.tobytes():
        return None
    return ints


#: int-lattice fast path bound: skip when the dense spend grid would exceed
#: this many states (degenerate tiny-gcd key sets fall back to lexsort)
_INT_LATTICE_MAX_STATES = 1 << 21

#: spend-grid chunk for the [K, chunk] candidate tile of the int path
_INT_LATTICE_CHUNK = 1 << 14


def _maxplus_pair(
    a_keys: np.ndarray,
    a_vals: np.ndarray,
    b_keys: np.ndarray,
    b_vals: np.ndarray,
    budget: float,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(max,+)-convolve two sparse value-vs-spend curves under ``budget``.

    Returns ``(keys, vals, left_keys, right_keys)``: the deduped combined
    curve (ascending quantized spends, best value each) plus, per state,
    the (a, b) spend split realizing it.  Tie-breaking is the scalar dict
    DP's: among equal (key, value) candidates the smallest a-spend wins
    (first occurrence in (a index, b index) order).

    This is the one convolution primitive behind ``_AggCurve.combine``,
    the super-stage DP and the hierarchical frontier tree.  When both key
    sets sit on a common integer watt lattice (grid-aligned costs — the
    production case) the outer-product + lexsort dedupe collapses to a
    dense gather + argmax over the integer spend grid, bitwise identical
    and ~10x faster; otherwise the generic lexsort path runs.
    """
    if len(a_keys) * len(b_keys) > 2048:
        # the int-lattice setup only pays off past a few thousand candidates
        ia = _micro_int(a_keys)
        ib = _micro_int(b_keys) if ia is not None else None
        if ib is not None and len(ia) and len(ib):
            out = _maxplus_pair_int(
                ia, a_keys, a_vals, ib, b_keys, b_vals, budget
            )
            if out is not None:
                return out
    # generic path: full outer product, feasibility prune, first-max dedupe
    raw = (a_keys[:, None] + b_keys[None, :]).ravel()
    vals = (a_vals[:, None] + b_vals[None, :]).ravel()
    feas = np.flatnonzero(raw <= budget + 1e-9)
    keys, sel = _dedupe_first_max(_qkey_np(raw[feas]), vals[feas])
    sel = feas[sel]
    nb = len(b_keys)
    return keys, vals[sel], a_keys[sel // nb], b_keys[sel % nb]


def _maxplus_pair_int(
    ia: np.ndarray,
    a_keys: np.ndarray,
    a_vals: np.ndarray,
    ib: np.ndarray,
    b_keys: np.ndarray,
    b_vals: np.ndarray,
    budget: float,
) -> tuple | None:
    """Integer-lattice (max,+) pair convolution (see :func:`_maxplus_pair`).

    Spends become indices on the gcd-pitch grid; each output state gathers
    its candidates as ``a_dense[t - b] + b_val`` and an argmax with
    last-maximizer tie-breaking reproduces the dict DP's first-max over
    (a asc, b asc) candidate order (for a fixed sum, ascending a-spend is
    descending b-spend).  Returns None when the grid would be too large.
    """
    g = int(np.gcd(np.gcd.reduce(ia), np.gcd.reduce(ib)))
    if g <= 0:
        # all spends are zero: single state (0, best value pair)
        g = 1
    # largest feasible grid index (micro-watt bound mirrors `<= budget+1e-9`)
    bound = np.floor((budget + 1e-9) * 1e6 / g)
    if not np.isfinite(bound):
        return None
    tmax = min(int(bound), int(ia.max() // g + ib.max() // g))
    if tmax < 0:
        # no feasible state at all (negative budget cannot happen upstream,
        # but keep the generic path authoritative for it)
        return None
    if tmax + 1 > _INT_LATTICE_MAX_STATES:
        return None
    nb = tmax + 1
    iag = ia // g
    ibg = ib // g
    keep_a = np.flatnonzero(iag <= tmax)
    keep_b = np.flatnonzero(ibg <= tmax)
    if not len(keep_a) or not len(keep_b):
        return None
    kmax = int(ibg[keep_b].max())
    # a side densified on the grid, left-padded by kmax so every gather
    # index t - kb + kmax is in-bounds (holes and padding are -inf)
    a_pad = np.full(nb + kmax, -np.inf)
    a_pos = np.zeros(nb, dtype=np.int64)
    a_pad[iag[keep_a] + kmax] = a_vals[keep_a]
    a_pos[iag[keep_a]] = keep_a
    # b options in descending-spend order: a plain row argmax then picks,
    # among ties, the largest b spend == the smallest a spend — the dict
    # DP's first max in (a asc, b asc) candidate order
    kbr = ibg[keep_b][::-1].copy()
    vbr = b_vals[keep_b][::-1].copy()
    k = len(kbr)

    out_vals = np.empty(nb, dtype=np.float64)
    out_jr = np.empty(nb, dtype=np.int64)
    for t0 in range(0, nb, _INT_LATTICE_CHUNK):
        t = np.arange(t0, min(t0 + _INT_LATTICE_CHUNK, nb))
        idx = t[:, None] - kbr[None, :] + kmax  # [chunk, K], all in-bounds
        cand = a_pad[idx]
        cand += vbr[None, :]
        jr = np.argmax(cand, axis=1)
        out_jr[t] = jr
        out_vals[t] = cand[np.arange(len(t)), jr]

    feas = np.flatnonzero(out_vals > -np.inf)
    jr = out_jr[feas]
    ta = feas - kbr[jr]
    keys = ((feas * g).astype(np.float64)) * 1e-6
    return (
        keys,
        out_vals[feas],
        a_keys[a_pos[ta]],
        b_keys[keep_b[k - 1 - jr]],
    )


class _AggCurve:
    """Sparse aggregate curve of ``t`` copies of one option table.

    Columns over the curve's states (ascending spend key): ``keys`` are
    quantized spends, ``vals`` the best achievable value at each.  For a
    leaf curve (t == 1) ``back`` holds option indices; for a combined curve
    ``back_left`` / ``back_right`` hold the (left, right) spend split, so
    :meth:`unwind` can walk the binary-split tree back down to the multiset
    of single-receiver picks.  All convolutions are vectorized outer
    (max,+) products deduped by :func:`_dedupe_first_max` — the same
    candidate order and tie-breaking as the scalar dict DP.
    """

    __slots__ = ("keys", "vals", "back", "back_left", "back_right", "left", "right")

    def __init__(self, keys, vals, back=None, back_left=None, back_right=None,
                 left=None, right=None):
        self.keys: np.ndarray = keys
        self.vals: np.ndarray = vals
        self.back = back
        self.back_left = back_left
        self.back_right = back_right
        self.left: _AggCurve | None = left
        self.right: _AggCurve | None = right

    @staticmethod
    def leaf(table: OptionTable, budget: float) -> "_AggCurve":
        feas = np.flatnonzero(table.costs <= budget + 1e-9)
        keys = _qkey_np(table.costs[feas])
        _, sel = _dedupe_first_max(keys, table.values[feas])
        return _AggCurve(
            keys=keys[sel], vals=table.values[feas][sel], back=feas[sel]
        )

    @staticmethod
    def combine(a: "_AggCurve", b: "_AggCurve", budget: float) -> "_AggCurve":
        keys, vals, left, right = _maxplus_pair(
            a.keys, a.vals, b.keys, b.vals, budget
        )
        return _AggCurve(
            keys=keys,
            vals=vals,
            back_left=left,
            back_right=right,
            left=a,
            right=b,
        )

    def _at(self, spend: float) -> int:
        i = int(np.searchsorted(self.keys, spend))
        if i >= len(self.keys) or self.keys[i] != spend:
            raise KeyError(f"aggregate curve has no state at {spend!r}")
        return i

    def unwind(self, spend: float, out: list[int]) -> None:
        """Collect the option-index multiset realizing ``spend``."""
        i = self._at(spend)
        if self.left is None:
            out.append(int(self.back[i]))
        else:
            self.left.unwind(float(self.back_left[i]), out)
            self.right.unwind(float(self.back_right[i]), out)


def aggregate_curve(
    table: OptionTable, m: int, budget: float,
    chain: list[_AggCurve] | None = None,
) -> _AggCurve:
    """m-fold (max,+) self-convolution of a table's sparse staircase.

    Binary split: O(log m) pairwise convolutions build the doubling chain
    P_1, P_2, P_4, ... and the set bits of ``m`` combine into the final
    curve.  State count stays bounded by the distinct achievable sums
    <= budget, so each convolution is one small vectorized outer product.

    ``chain`` optionally persists the doubling chain across calls (keyed by
    (digest, budget) in ``_class_curves``): the powers are multiplicity-
    independent, so when membership churn shifts a class from m to m', only
    the popcount(m') set-bit combines rerun — not the whole chain.
    """
    if chain is None:
        chain = []
    if not chain:
        chain.append(_AggCurve.leaf(table, budget))
    acc: _AggCurve | None = None
    bit = m
    i = 0
    while bit:
        if i >= len(chain):
            chain.append(_AggCurve.combine(chain[-1], chain[-1], budget))
        if bit & 1:
            acc = (
                chain[i] if acc is None
                else _AggCurve.combine(acc, chain[i], budget)
            )
        bit >>= 1
        i += 1
    assert acc is not None
    return acc


def _merge_classes(groups: Sequence[GroupedOptions]) -> list[list]:
    """Merge interchangeable groups (equal table content) into classes.

    Returns ``[table, members, digest]`` triples sorted by min member name —
    the deterministic class order every grouped/hierarchical solver shares.
    """
    merged: dict[tuple, list] = {}
    for g in groups:
        d = table_digest(g.table)
        slot = merged.get(d)
        if slot is None:
            merged[d] = [g.table, list(g.members), d]
        else:
            slot[1].extend(g.members)
    return sorted(merged.values(), key=lambda s: min(s[1]))


class _LeafPlan:
    """Merged-class layout of one behaviour-class set.

    Precomputes everything about the *stage structure* that is independent
    of budget and spends: the digest-merged classes in canonical order
    (sorted by min member name, members name-sorted within each class), the
    ``layout`` content key of the frontier caches, and the permutation
    taking class-concatenated members to the globally name-sorted order the
    canonical assembly uses.  Plans are cached by the group-token tuple so
    incremental controllers reusing unchanged ``GroupedOptions`` objects
    skip the per-round merge + sorts entirely.
    """

    __slots__ = ("classes", "layout", "names_sorted", "order", "key")

    def __init__(self, classes, layout, names_sorted, order, key):
        self.classes: list[list] = classes
        self.layout: tuple = layout
        self.names_sorted: list[str] = names_sorted
        self.order: np.ndarray = order
        #: group-token tuple when plan-cached (None on ephemeral plans)
        self.key: tuple | None = key


def _leaf_plan(
    groups: Sequence[GroupedOptions],
    plan_cache: MutableMapping | None = None,
) -> _LeafPlan:
    """Build (or fetch) the :class:`_LeafPlan` of a behaviour-class set."""
    key = None
    if plan_cache is not None:
        key = tuple(sorted(_group_token(g) for g in groups))
        hit = plan_cache.get(key)
        if hit is not None:
            return hit
    classes = _merge_classes(groups)
    for slot in classes:
        slot[1].sort()
    concat = [nm for _, members, _ in classes for nm in members]
    if concat:
        arr = np.asarray(concat)
        order = np.argsort(arr, kind="stable")
        names_sorted = arr[order].tolist()
    else:
        order = np.empty(0, dtype=np.int64)
        names_sorted = []
    plan = _LeafPlan(
        classes=classes,
        layout=tuple((d, len(m)) for _, m, d in classes),
        names_sorted=names_sorted,
        order=order,
        key=key,
    )
    if plan_cache is not None:
        plan_cache[key] = plan
    return plan


def _curve_cutoff(budget: float) -> float:
    """Canonical aggregate-curve cutoff: the smallest power-of-two multiple
    of 64 W at or above ``budget``.

    Aggregate curves truncated to any cutoff >= the DP budget produce the
    *same* feasible states, values and backtracked multisets (costs are
    non-negative, so an over-cutoff state can never parent a feasible one,
    and dropping it changes no candidate order among survivors).  Keying
    curves and chains by this quantized cutoff instead of the raw budget
    keeps them warm while per-domain headroom drifts watt-by-watt under
    failures and deratings — the curve caches then miss only on genuine
    class changes, not on accounting noise.
    """
    b = 64.0
    while b < budget:
        b *= 2.0
    return b


def _class_curves(
    classes: Sequence[list],
    budget: float,
    curve_cache: MutableMapping | None,
    chain_cache: MutableMapping | None = None,
) -> tuple[list[_AggCurve], list[tuple]]:
    """m-fold aggregate curve per class, memoized by (digest, m, budget).

    ``chain_cache`` persists the multiplicity-independent doubling chains
    by (digest, budget) — kept apart from ``curve_cache`` because churny
    (digest, m) keys would otherwise evict the far-more-valuable chains.
    Returns the curves plus their content cache keys (the pick-multiset
    cache reuses them)."""
    if chain_cache is None:
        chain_cache = curve_cache
    cutoff = _curve_cutoff(budget)
    qc = _qkey(cutoff)
    curves_: list[_AggCurve] = []
    keys: list[tuple] = []
    for table, members, d in classes:
        key = (d, len(members), qc)
        curve = curve_cache.get(key) if curve_cache is not None else None
        if curve is None:
            chain = None
            if chain_cache is not None:
                # membership churn (m -> m') then reruns only the set-bit
                # combines, never the whole chain
                ckey = (d, "powers", qc)
                chain = chain_cache.get(ckey)
                if chain is None:
                    chain = []
                    chain_cache[ckey] = chain  # type: ignore[index]
            curve = aggregate_curve(table, len(members), cutoff, chain=chain)
            if curve_cache is not None:
                curve_cache[key] = curve  # type: ignore[index]
        curves_.append(curve)
        keys.append(key)
    return curves_, keys


def _superstage_dp(
    stage_curves: Sequence[tuple[np.ndarray, np.ndarray]], budget: float
) -> tuple[np.ndarray, np.ndarray, list]:
    """Sparse DP over (keys, vals) super-stages under ``budget``.

    Each stage is one vectorized outer (max,+) product over
    [states x stage spends].  Stages may be class aggregate curves (grouped
    solve) or whole domain frontiers (hierarchical solve).  Returns the
    final ``(dp_keys, dp_vals, stages)`` where each backtracking stage is a
    (keys, parent spend, stage spend) triple.
    """
    dp_keys = np.zeros(1, dtype=np.float64)
    dp_vals = np.zeros(1, dtype=np.float64)
    stages: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for c_keys, c_vals in stage_curves:
        # keys come back ascending from the dedupe, so the stage arrays
        # are searchsorted-ready as-is
        keys, vals, parents, spends = _maxplus_pair(
            dp_keys, dp_vals, c_keys, c_vals, budget
        )
        stages.append((keys, parents, spends))
        dp_keys = keys
        dp_vals = vals
    return dp_keys, dp_vals, stages


class _IntStages:
    """Backtracking record of one leaf solved by the *batched* integer-
    lattice super-stage DP (:func:`_superstage_dp_batch`).

    Holds, per stage, the dense winner table over the leaf's spend grid
    plus the descending-spend stage key arrays; :meth:`backtrack` walks
    them exactly like :func:`_backtrack_superstages` walks sparse stage
    tuples — same states, same spends, bitwise.
    """

    __slots__ = ("g", "win", "kb_desc", "keys_desc", "nstages")

    def __init__(self, g, win, kb_desc, keys_desc, nstages):
        self.g = g
        self.win = win
        self.kb_desc = kb_desc
        self.keys_desc = keys_desc
        self.nstages = nstages

    def backtrack(self, u: float) -> list[float]:
        t = int(round(u * 1e6)) // self.g
        spends = [0.0] * self.nstages
        for s in range(self.nstages - 1, -1, -1):
            j = int(self.win[s][t])
            spends[s] = float(self.keys_desc[s][j])
            t -= int(self.kb_desc[s][j])
        return spends


def _backtrack_superstages(stages, u: float) -> list[float]:
    """Walk the super-stage DP backwards from end state ``u``: the per-stage
    spends realizing it (stage order)."""
    if isinstance(stages, _IntStages):
        return stages.backtrack(u)
    spends: list[float] = [0.0] * len(stages)
    for i in range(len(stages) - 1, -1, -1):
        keys, parents, spends_stage = stages[i]
        pos = int(np.searchsorted(keys, u))
        spends[i] = float(spends_stage[pos])
        u = float(parents[pos])
    return spends


def _superstage_dp_batch(
    jobs: Sequence[tuple[Sequence[tuple[np.ndarray, np.ndarray]], float]],
) -> list[tuple[np.ndarray, np.ndarray, _IntStages]] | None:
    """Solve many leaves' super-stage DPs in one vectorized pass.

    ``jobs`` is a list of (stage curves, eff budget) pairs — one per dirty
    leaf.  All leaves advance through their stages *together*: stage ``s``
    of every leaf is a single [L, K, NB] gather + argmax on the per-leaf
    integer spend lattice, replacing L x S per-leaf convolution calls with
    S batched numpy ops (the sparse-path analogue of the Pallas
    ``maxplus_conv_batched`` dispatch).  Per-leaf results — frontier keys,
    values and backtracking stages — are **bitwise identical** to running
    :func:`_superstage_dp` on each leaf alone: the candidate sets, float64
    adds and (value desc, a-spend asc) tie-breaking are data-parallel
    across leaves, padding rows are exact identities (+0.0), and per-leaf
    feasibility masks mirror the per-stage pruning.  Returns None when any
    leaf's keys leave the integer lattice or the padded grid would be
    degenerate — callers then fall back to the per-leaf path.
    """
    L = len(jobs)
    per_leaf = []
    nb_max = 1
    s_max = 1
    k_max = 1
    for stage_curves, eff in jobs:
        ints = []
        g = 0
        for ck, cv in stage_curves:
            ia = _micro_int(ck)
            if ia is None or not len(ia):
                return None
            ints.append(ia)
            g = int(np.gcd(g, np.gcd.reduce(ia)))
        if g <= 0:
            g = 1
        bound = np.floor((eff + 1e-9) * 1e6 / g)
        if not np.isfinite(bound) or bound < 0:
            return None
        tmax = int(bound)
        if tmax + 1 > _INT_LATTICE_MAX_STATES // max(1, L):
            return None
        nb_max = max(nb_max, tmax + 1)
        s_max = max(s_max, len(stage_curves))
        stages_desc = []
        for ia, (ck, cv) in zip(ints, stage_curves):
            keep = np.flatnonzero(ia // g <= tmax)
            if not len(keep):
                return None
            kb = (ia[keep] // g)[::-1].copy()
            stages_desc.append(
                (kb, cv[keep][::-1].copy(), ck[keep][::-1].copy())
            )
            k_max = max(k_max, len(kb))
        per_leaf.append((g, tmax, stages_desc))

    kmax_glob = 0
    for g, tmax, stages_desc in per_leaf:
        for kb, _, _ in stages_desc:
            kmax_glob = max(kmax_glob, int(kb[0]) if len(kb) else 0)
    if L * nb_max * k_max > _INT_LATTICE_MAX_STATES * 8:
        # the per-stage [L, NB, K] candidate tile would be huge; the
        # per-leaf path (chunked _maxplus_pair_int) handles such grids
        return None

    dp = np.full((L, kmax_glob + nb_max), -np.inf)
    dp[:, kmax_glob] = 0.0
    t_grid = np.arange(nb_max)
    leaf_idx = np.arange(L)[:, None, None]
    results_win: list[np.ndarray] = []
    for s in range(s_max):
        kbr = np.zeros((L, k_max), dtype=np.int64)
        vbr = np.full((L, k_max), -np.inf)
        for li, (g, tmax, stages_desc) in enumerate(per_leaf):
            if s < len(stages_desc):
                kb, vb, _ = stages_desc[s]
                kbr[li, : len(kb)] = kb
                vbr[li, : len(vb)] = vb
            else:
                vbr[li, 0] = 0.0  # identity stage: spend 0, value +0.0
        # [L, NB, K] layout: the options axis is contiguous, so the
        # tie-breaking argmax (first max over descending spends) is a
        # cache-friendly row reduction
        idx = t_grid[None, :, None] - kbr[:, None, :] + kmax_glob
        cand = dp[leaf_idx, idx]
        cand += vbr[:, None, :]
        jr = np.argmax(cand, axis=2)
        out = np.take_along_axis(cand, jr[:, :, None], axis=2)[:, :, 0]
        for li, (g, tmax, _) in enumerate(per_leaf):
            if tmax + 1 < nb_max:
                out[li, tmax + 1 :] = -np.inf
        dp[:, kmax_glob:] = out
        results_win.append(jr.astype(np.int32))

    out_final = dp[:, kmax_glob:]
    results = []
    for li, (g, tmax, stages_desc) in enumerate(per_leaf):
        feas = np.flatnonzero(out_final[li, : tmax + 1] > -np.inf)
        dp_keys = (feas * g).astype(np.float64) * 1e-6
        dp_vals = out_final[li, feas].copy()
        stages = _IntStages(
            g=g,
            win=[results_win[s][li] for s in range(len(stages_desc))],
            kb_desc=[kb for kb, _, _ in stages_desc],
            keys_desc=[ks for _, _, ks in stages_desc],
            nstages=len(stages_desc),
        )
        results.append((dp_keys, dp_vals, stages))
    return results


def _class_picks(
    table: OptionTable,
    curve: _AggCurve,
    curve_key: tuple,
    spend: float,
    pick_cache: MutableMapping | None,
) -> tuple[list, np.ndarray, np.ndarray]:
    """One class's canonical pick column at ``spend``: name-sorted members
    get the option multiset in ascending-cost order.  Returns (pick tuples,
    costs, values) aligned with the class's sorted members — memoized by
    (curve content key, quantized spend) so unchanged classes skip the
    binary-split unwind entirely on warm rounds."""
    pkey = (curve_key, _qkey(spend))
    hit = pick_cache.get(pkey) if pick_cache is not None else None
    if hit is None:
        js: list[int] = []
        curve.unwind(spend, js)
        js.sort()
        pt = _pick_tuples(table)
        hit = ([pt[j] for j in js], table.costs[js], table.values[js])
        if pick_cache is not None:
            pick_cache[pkey] = hit
    return hit


def _assemble_plan(
    plan: _LeafPlan,
    curve_keys: Sequence[tuple],
    curves_: Sequence[_AggCurve],
    spends: Sequence[float],
    pick_cache: MutableMapping | None,
) -> tuple[dict, float, float]:
    """Canonical assembly of one plan's solution: picks dict over the
    name-sorted members plus (total_value, spent) accumulated in that same
    order — bit-for-bit the ungrouped ``solve_sparse`` form (sequential
    float64 adds via cumsum == the scalar left fold)."""
    if not plan.names_sorted:
        return {}, 0.0, 0.0
    tuples_parts: list[list] = []
    costs_parts: list[np.ndarray] = []
    vals_parts: list[np.ndarray] = []
    for (table, _, _), ckey, curve, spend in zip(
        plan.classes, curve_keys, curves_, spends
    ):
        tups, costs, vals = _class_picks(table, curve, ckey, spend, pick_cache)
        tuples_parts.append(tups)
        costs_parts.append(costs)
        vals_parts.append(vals)
    flat_tuples = [t for part in tuples_parts for t in part]
    order = plan.order
    picks = dict(zip(plan.names_sorted, (flat_tuples[i] for i in order)))
    costs = np.concatenate(costs_parts)[order]
    vals = np.concatenate(vals_parts)[order]
    total = float(np.cumsum(vals)[-1])
    spent = float(np.cumsum(costs)[-1])
    return picks, total, spent


def solve_sparse_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    curve_cache: MutableMapping | None = None,
    pick_cache: MutableMapping | None = None,
    plan_cache: MutableMapping | None = None,
    chain_cache: MutableMapping | None = None,
) -> MCKPSolution:
    """Group-collapsed Algorithm 1: one DP super-stage per behaviour class.

    Equivalent to — and bit-for-bit equal with — ``solve_sparse`` on the
    name-sorted ungrouped expansion: groups digesting equally merge first
    (their members are interchangeable), each merged group contributes its
    m-fold aggregate curve as a single DP stage, and the backtracked
    per-group spends unwind into option multisets assigned to name-sorted
    members in ascending-cost order (the sparse solver's canonical form).

    All three caches are optional warm state (mutable mappings, e.g. a
    controller's LRU dicts): ``curve_cache`` memoizes aggregate curves by
    (digest, m, quantized budget), ``pick_cache`` memoizes unwound pick
    multisets by (curve key, quantized spend), and ``plan_cache`` memoizes
    merged-class layouts by group-token tuple — together they make a
    steady-state re-solve cost O(changed classes), not O(cluster).
    """
    plan = _leaf_plan(groups, plan_cache)
    curves_, curve_keys = _class_curves(
        plan.classes, budget, curve_cache, chain_cache
    )
    dp_keys, dp_vals, stages = _superstage_dp(
        [(c.keys, c.vals) for c in curves_], budget
    )
    u = float(dp_keys[int(np.argmax(dp_vals))])
    spends = _backtrack_superstages(stages, u)
    picks, total, spent = _assemble_plan(
        plan, curve_keys, curves_, spends, pick_cache
    )
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Hierarchical (two-level) solve over a power-domain tree (DESIGN.md §12)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DomainGroups:
    """One power domain's slice of an allocation round.

    ``cap`` is the domain's *extra-power headroom* in watts — its physical
    cap net of the draw already committed under it (baselines of member
    receivers, natural draw of member donors; the engine does that
    accounting).  A leaf carries the behaviour-class ``groups`` of its
    member receivers (possibly empty); an internal domain carries
    ``children``.
    """

    name: str
    cap: float
    groups: tuple[GroupedOptions, ...] = ()
    children: tuple["DomainGroups", ...] = ()

    def __post_init__(self):
        if self.groups and self.children:
            raise ValueError(
                f"domain {self.name!r}: groups and children are exclusive"
            )


class HierState:
    """Persistent warm state for (incremental) hierarchical sparse solving.

    Every cache is *content-keyed* — digests + multiplicities + quantized
    budgets for curves/frontiers, content tokens for the aggregation-tree
    combines, group-identity tokens for plans and leaf solutions — so a
    warm re-solve is **bit-for-bit** the from-scratch solve: a cache entry
    is only ever reused for inputs under which it would be recomputed
    identically.  A steady-state round therefore costs O(what changed):

     * an unchanged leaf reuses its frontier DP and its assembled solution;
     * a changed leaf re-runs its class super-stages and re-aggregates
       through the balanced frontier **aggregation tree**, recombining only
       the O(log n_leaves) tree nodes on its root path;
     * unchanged classes inside a dirty leaf still reuse their aggregate
       curves and unwound pick multisets.

    All caches are LRU-bounded so long scenarios with drifting budgets or
    digests cannot grow warm state without bound.
    """

    def __init__(
        self,
        curve_cache: MutableMapping | None = None,
        frontier_cache: MutableMapping | None = None,
        *,
        chain_cache: MutableMapping | None = None,
        pick_cache: MutableMapping | None = None,
        plan_cache: MutableMapping | None = None,
        max_curves: int = 1024,
        max_frontiers: int = 512,
        max_picks: int = 8192,
        max_leaf_solutions: int = 128,
        max_plans: int = 256,
    ):
        self.curve_cache: MutableMapping = (
            LRUCache(max_curves) if curve_cache is None else curve_cache
        )
        #: (digest, budget) -> doubling chain, shielded from (d, m) churn
        self.chain_cache: MutableMapping = (
            LRUCache(512) if chain_cache is None else chain_cache
        )
        self.frontier_cache: MutableMapping = (
            LRUCache(max_frontiers) if frontier_cache is None else frontier_cache
        )
        #: (left token, right token, quantized cap) -> combined frontier
        self.comb_cache: MutableMapping = LRUCache(max_frontiers)
        self.pick_cache: MutableMapping = (
            LRUCache(max_picks) if pick_cache is None else pick_cache
        )
        #: (leaf token, plan key, spends) -> (picks, total, spent)
        self.leaf_sol_cache: MutableMapping = LRUCache(max_leaf_solutions)
        self.plan_cache: MutableMapping = (
            LRUCache(max_plans) if plan_cache is None else plan_cache
        )
        self._tokens: dict = {}
        self._next_token = itertools.count(1)

    def token(self, content) -> int:
        """Intern hashable content to a small process-unique int.

        Tokens are never reused (the counter outlives table resets), so a
        stale cache entry keyed by an old token can never collide with new
        content — it just ages out of its LRU."""
        t = self._tokens.get(content)
        if t is None:
            if len(self._tokens) > (1 << 20):
                self._tokens.clear()
            t = next(self._next_token)
            self._tokens[content] = t
        return t

    def cache_sizes(self) -> dict[str, int]:
        return {
            "curves": len(self.curve_cache),
            "frontiers": len(self.frontier_cache),
            "combines": len(self.comb_cache),
            "picks": len(self.pick_cache),
            "leaf_solutions": len(self.leaf_sol_cache),
            "plans": len(self.plan_cache),
        }

    def clear(self) -> None:
        for c in (
            self.curve_cache,
            self.chain_cache,
            self.frontier_cache,
            self.comb_cache,
            self.pick_cache,
            self.leaf_sol_cache,
            self.plan_cache,
        ):
            c.clear()
        self._tokens.clear()


class _CombNode:
    """One node of the balanced frontier aggregation tree.

    Wrapper nodes (``leaf`` set) adapt a child domain's frontier; internal
    nodes hold a (max,+)-combined frontier with per-state (left, right)
    spend splits for backtracking.  The tree shape is a deterministic
    function of the child count (adjacent pairs, odd tail carried up), so
    content-addressed memoization of each combine makes replacing one
    dirty child cost O(log n_children) convolutions.
    """

    __slots__ = ("keys", "vals", "back_left", "back_right", "left", "right", "leaf")

    def __init__(self, keys, vals, back_left=None, back_right=None,
                 left=None, right=None, leaf=None):
        self.keys: np.ndarray = keys
        self.vals: np.ndarray = vals
        self.back_left = back_left
        self.back_right = back_right
        self.left: _CombNode | None = left
        self.right: _CombNode | None = right
        self.leaf: "_SparseFrontier | None" = leaf


class _SparseFrontier:
    """A domain's value-vs-spend frontier with backtracking state.

    ``keys``/``vals`` are the capped frontier (ascending quantized spends,
    best value at each).  Leaves keep their plan/curves/stages for
    unwinding; internal domains keep their children plus the aggregation
    tree (``comb``) that combined them.  ``token`` is the content token
    the parent's combine cache keys on.
    """

    __slots__ = (
        "dom", "keys", "vals", "stages", "plan", "curves", "curve_keys",
        "token", "comb", "children",
    )

    def __init__(self, dom, keys, vals, *, stages=None, plan=None,
                 curves=None, curve_keys=None, token=None, comb=None,
                 children=None):
        self.dom: DomainGroups = dom
        self.keys: np.ndarray = keys
        self.vals: np.ndarray = vals
        self.stages: list | None = stages
        self.plan: _LeafPlan | None = plan
        self.curves = curves
        self.curve_keys = curve_keys
        self.token: int | None = token
        self.comb: _CombNode | None = comb
        self.children: list["_SparseFrontier"] | None = children


def _combine_frontiers(
    subs: Sequence[_SparseFrontier], eff: float, state: HierState
) -> tuple[_CombNode, int]:
    """Fold child frontiers through the balanced aggregation tree under
    cap ``eff``.  Returns the root node and its content token."""
    nodes = [
        _CombNode(keys=f.keys, vals=f.vals, leaf=f) for f in subs
    ]
    tokens = [f.token for f in subs]
    effk = _qkey(eff)
    while len(nodes) > 1:
        nxt: list[_CombNode] = []
        ntok: list[int] = []
        for i in range(0, len(nodes) - 1, 2):
            key = (tokens[i], tokens[i + 1], effk)
            hit = state.comb_cache.get(key)
            if hit is None:
                hit = _maxplus_pair(
                    nodes[i].keys, nodes[i].vals,
                    nodes[i + 1].keys, nodes[i + 1].vals, eff,
                )
                state.comb_cache[key] = hit
            nxt.append(
                _CombNode(
                    keys=hit[0], vals=hit[1], back_left=hit[2],
                    back_right=hit[3], left=nodes[i], right=nodes[i + 1],
                )
            )
            ntok.append(state.token(("comb",) + key))
        if len(nodes) % 2:
            nxt.append(nodes[-1])
            ntok.append(tokens[-1])
        nodes, tokens = nxt, ntok
    return nodes[0], tokens[0]


def _comb_spends(
    node: _CombNode, u: float, out: list[tuple[_SparseFrontier, float]]
) -> None:
    """Split a chosen spend ``u`` down the aggregation tree into per-child
    (frontier, spend) pairs in original child order."""
    if node.leaf is not None:
        out.append((node.leaf, u))
        return
    i = int(np.searchsorted(node.keys, u))
    _comb_spends(node.left, float(node.back_left[i]), out)
    _comb_spends(node.right, float(node.back_right[i]), out)


def _domain_eff(dom: DomainGroups, budget: float) -> float:
    """Effective spend cap of a domain under its parent's budget — the one
    clamping rule shared by the frontier builders and the batched-leaf
    pre-walks (divergence here would silently misalign their grids)."""
    eff = min(float(dom.cap), float(budget))
    return eff if eff > 0.0 else 0.0


def _prime_leaf_frontiers(
    root: DomainGroups, budget: float, state: HierState
) -> None:
    """Batched single-dispatch solve of every *dirty* leaf DP.

    Walks the domain tree computing each leaf's effective cap, collects
    the leaves whose frontier isn't cached, and solves them all through
    :func:`_superstage_dp_batch` — priming the frontier cache so the
    subsequent recursive build is all hits.  A steady-state round with k
    dirty leaves pays one batched dispatch instead of k per-leaf stage
    loops.  No-op (falling back to the per-leaf path) on non-lattice
    instances.
    """
    jobs: list[tuple[_LeafPlan, float, tuple]] = []
    seen: set = set()

    def walk(dom: DomainGroups, b: float) -> None:
        eff = _domain_eff(dom, b)
        if dom.children:
            for c in dom.children:
                walk(c, eff)
            return
        if not dom.groups:
            return
        plan = _leaf_plan(dom.groups, state.plan_cache)
        key = (plan.layout, _qkey(eff))
        if key in seen or state.frontier_cache.get(key) is not None:
            return
        seen.add(key)
        jobs.append((plan, eff, key))

    walk(root, float(budget))
    if len(jobs) < 2:
        return
    prepared = []
    for plan, eff, key in jobs:
        curves_, curve_keys = _class_curves(
            plan.classes, eff, state.curve_cache, state.chain_cache
        )
        prepared.append((plan, eff, key, curves_, curve_keys))
    batch = _superstage_dp_batch(
        [
            ([(c.keys, c.vals) for c in curves_], eff)
            for _, eff, _, curves_, _ in prepared
        ]
    )
    if batch is None:
        return
    for (plan, eff, key, curves_, curve_keys), (dp_keys, dp_vals, stages) in zip(
        prepared, batch
    ):
        state.frontier_cache[key] = (curves_, curve_keys, dp_keys, dp_vals, stages)


def _sparse_frontier(
    dom: DomainGroups, budget: float, state: HierState
) -> _SparseFrontier:
    """Capped frontier of one domain: its best-value-per-spend staircase,
    restricted to spends <= min(domain cap, parent budget).

    A leaf's frontier is the class super-stage DP of its groups — the same
    arrays ``solve_sparse_grouped`` ends on, so a single root domain with
    cap >= budget reproduces the flat grouped solve bit-for-bit.  An
    internal domain folds its children's frontiers through the balanced
    aggregation tree under its own cap (the "upper-level DP").  Leaf DPs
    memoize by (per-class digest+multiplicity layout, quantized budget);
    tree combines by the child content tokens — both in ``state``.
    """
    eff = _domain_eff(dom, budget)
    if dom.children:
        subs = [_sparse_frontier(c, eff, state) for c in dom.children]
        comb, token = _combine_frontiers(subs, eff, state)
        return _SparseFrontier(
            dom, comb.keys, comb.vals, token=token, comb=comb, children=subs
        )
    plan = _leaf_plan(dom.groups, state.plan_cache)
    key = (plan.layout, _qkey(eff))
    hit = state.frontier_cache.get(key)
    if hit is None:
        curves_, curve_keys = _class_curves(
            plan.classes, eff, state.curve_cache, state.chain_cache
        )
        dp_keys, dp_vals, stages = _superstage_dp(
            [(c.keys, c.vals) for c in curves_], eff
        )
        hit = (curves_, curve_keys, dp_keys, dp_vals, stages)
        state.frontier_cache[key] = hit  # type: ignore[index]
    curves_, curve_keys, dp_keys, dp_vals, stages = hit
    return _SparseFrontier(
        dom, dp_keys, dp_vals, stages=stages, plan=plan, curves=curves_,
        curve_keys=curve_keys, token=state.token(("leaf", key)),
    )


def _backtrack_frontier(
    f: _SparseFrontier,
    u: float,
    state: HierState,
    picks: dict[str, tuple[float, float, tuple[float, float]]],
    domain_spent: dict[str, float],
    leaf_totals: list[tuple[float, float]],
) -> None:
    """Walk a chosen spend ``u`` down the frontier tree to receiver picks.

    Leaf solutions (picks + canonically-accumulated totals) memoize by
    (leaf content token, membership plan key, per-class spends): an
    unchanged leaf whose budget share didn't move contributes its cached
    dict without re-unwinding a single class.
    """
    domain_spent[f.dom.name] = u
    if f.children is not None:
        pairs: list[tuple[_SparseFrontier, float]] = []
        _comb_spends(f.comb, u, pairs)
        for sub, s in pairs:
            _backtrack_frontier(sub, s, state, picks, domain_spent, leaf_totals)
        return
    spends = _backtrack_superstages(f.stages, u)
    skey = None
    if f.plan.key is not None:
        skey = (f.token, f.plan.key, tuple(spends))
        hit = state.leaf_sol_cache.get(skey)
        if hit is not None:
            picks.update(hit[0])
            leaf_totals.append((hit[1], hit[2]))
            return
    lp, lt, ls = _assemble_plan(
        f.plan, f.curve_keys, f.curves, spends, state.pick_cache
    )
    if skey is not None:
        state.leaf_sol_cache[skey] = (lp, lt, ls)
    picks.update(lp)
    leaf_totals.append((lt, ls))


def solve_hierarchical(
    root: DomainGroups,
    budget: float,
    *,
    solver: str = "sparse",
    unit: float = 1.0,
    curve_cache: MutableMapping | None = None,
    frontier_cache: MutableMapping | None = None,
    state: HierState | None = None,
) -> MCKPSolution:
    """Topology-aware MCKP over an arbitrary-depth power-domain tree.

    Per-domain group-collapsed aggregate tables become capped value-vs-spend
    frontiers; the upper-level DP folds sibling frontiers through a
    balanced aggregation tree *recursively at every internal domain* to
    split each parent's budget subject to every domain's local cap (site
    → row → PDU → ... → leaf), then backtracks down to the per-receiver
    picks.  Every domain's spend is <= its cap by construction, and with a
    single root domain whose cap >= the cluster budget the result is
    **bit-for-bit** ``solve_sparse_grouped`` (``solver='sparse'``) /
    ``solve_dense_jax_grouped`` (``solver='jax'`` / ``'pallas'``) —
    certified by tests/test_hier_alloc.py.

    Passing a persistent :class:`HierState` makes warm re-solves
    incremental (O(what changed) — see the class docstring) while staying
    bit-for-bit equal to a from-scratch call; ``curve_cache`` /
    ``frontier_cache`` remain accepted as standalone warm mappings.

    Returns a solution whose ``domain_spent`` maps each domain name to the
    watts spent inside it.
    """
    if solver == "sparse":
        st = state if state is not None else HierState(curve_cache, frontier_cache)
        _prime_leaf_frontiers(root, float(budget), st)
        f = _sparse_frontier(root, float(budget), st)
        u = float(f.keys[int(np.argmax(f.vals))])
        picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
        domain_spent: dict[str, float] = {}
        leaf_totals: list[tuple[float, float]] = []
        _backtrack_frontier(f, u, st, picks, domain_spent, leaf_totals)
        total = 0.0
        spent = 0.0
        for lt, ls in leaf_totals:
            total += lt
            spent += ls
        return MCKPSolution(
            total_value=total, spent=spent, picks=picks,
            domain_spent=domain_spent,
        )
    if solver in ("jax", "pallas"):
        return _solve_hier_dense(root, float(budget), unit=unit, backend=solver)
    raise ValueError(f"unknown hierarchical solver {solver!r}")


# ---------------------------------------------------------------------------
# Receding-horizon (MPC) spend planning over cached frontiers (DESIGN.md §15)
# ---------------------------------------------------------------------------


def grouped_frontier(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    curve_cache: MutableMapping | None = None,
    plan_cache: MutableMapping | None = None,
    chain_cache: MutableMapping | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """The flat cluster's value-vs-spend frontier under ``budget``: the
    final ``(dp_keys, dp_vals)`` arrays of the grouped super-stage DP —
    exactly the states ``solve_sparse_grouped`` ends on, built from the
    same warm class-curve caches (so a planning call right before the
    round's solve costs one super-stage scan, not a re-aggregation)."""
    plan = _leaf_plan(groups, plan_cache)
    curves_, _ = _class_curves(plan.classes, budget, curve_cache, chain_cache)
    dp_keys, dp_vals, _ = _superstage_dp(
        [(c.keys, c.vals) for c in curves_], budget
    )
    return dp_keys, dp_vals


def hierarchical_frontier(
    root: DomainGroups, budget: float, state: HierState | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """The root domain's capped value-vs-spend frontier under ``budget``
    (PR-5 frontier aggregation tree, warm through ``state``) — the
    hierarchical analogue of :func:`grouped_frontier`."""
    st = state if state is not None else HierState()
    _prime_leaf_frontiers(root, float(budget), st)
    f = _sparse_frontier(root, float(budget), st)
    return f.keys, f.vals


def frontier_records(
    keys: np.ndarray, vals: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Monotone record points of a frontier: the (spend, value) states
    where the running-max value strictly increases.

    Every record point is an *achievable* DP state (never an
    interpolation), and the smallest-spend argmax under any cap ``c`` is
    the last record point with spend <= ``c`` — the same state
    ``np.argmax`` (first max) picks in the myopic solvers, so planning on
    records commits only spends the real solve would also choose.
    """
    if len(keys) == 0:
        return keys, vals
    run = np.maximum.accumulate(vals)
    rec = np.ones(len(vals), dtype=bool)
    rec[1:] = vals[1:] > run[:-1]
    return keys[rec], vals[rec]


def plan_horizon(
    keys: np.ndarray,
    vals: np.ndarray,
    caps: Sequence[float],
    weights: Sequence[float] | None = None,
    *,
    eco_factor: float = 1.0,
    levels: int = 64,
    grid: int = 2048,
) -> list[float] | None:
    """Receding-horizon spend plan over one value-vs-spend frontier.

    Given the cluster frontier ``(keys, vals)`` (spends ascending, best
    value per spend) and an H-round cap forecast, choose per-round spends
    ``s_i`` maximizing ``sum_i value(s_i)`` subject to

     * ``s_i <= caps[i]`` — the instantaneous budget is *never* exceeded
       (the committed round-0 spend is a cap on that round's solve);
     * ``sum_i weights[i] * s_i <= eco_factor * sum_i weights[i] * umax_i``
       — the horizon's weighted spend (CO2 grams, dollars) may use at
       most an ``eco_factor`` fraction of what the myopic cap-riding
       controller would emit (``umax_i`` = the myopic best spend under
       ``caps[i]``).

    The temporal coupling is entirely in the weighted allowance: with
    ``eco_factor >= 1`` the per-round maxima are jointly feasible, the DP
    returns them, and the plan never restricts anything — so the function
    returns **None** ("don't touch the budget") and the caller takes the
    *literally unchanged* myopic code path, which is what certifies H=1
    and eco-off parity bit-for-bit.  With ``eco_factor < 1`` the DP banks
    spend away from dirty/expensive rounds (high weight) and rounds of
    diminishing marginal value, and toward clean rounds and upcoming
    deratings.

    Implementation: per-round candidates are the frontier's record points
    under that round's cap, subsampled to <= ``levels`` spends (the cap
    state and zero state always kept); the DP runs on an integer
    allowance lattice of ``grid`` cells with *ceil* cost rounding — so a
    returned plan's true weighted spend is <= the allowance, never over
    (conservative by construction).  Cost is O(H * levels * grid) numpy
    ops, independent of cluster size — the frontier did the heavy
    lifting.  Returns the planned spends (round order) or None when the
    plan would not restrict round 0.
    """
    H = len(caps)
    if H <= 1 or eco_factor >= 1.0 or len(keys) == 0:
        return None
    rk, rv = frontier_records(np.asarray(keys), np.asarray(vals))
    if len(rk) == 0 or rk[-1] <= 0.0:
        return None
    w = (
        np.ones(H, dtype=np.float64)
        if weights is None
        else np.clip(np.asarray(weights, dtype=np.float64), 0.0, None)
    )
    # per-round candidate spends/values + the myopic optimum under each cap
    cand_k: list[np.ndarray] = []
    cand_v: list[np.ndarray] = []
    umax = np.empty(H, dtype=np.float64)
    for i in range(H):
        hi = int(np.searchsorted(rk, float(caps[i]) + 1e-9))
        if hi == 0:
            # no positive-spend state fits: the only choice is state 0
            hi = 1
        umax[i] = rk[hi - 1]
        if hi > levels:
            idx = np.unique(
                np.round(np.linspace(0, hi - 1, levels)).astype(np.int64)
            )
        else:
            idx = np.arange(hi)
        cand_k.append(rk[idx])
        cand_v.append(rv[idx])
    allowance = float(eco_factor) * float(np.dot(w, umax))
    if allowance <= 0.0:
        plan = [float(k[0]) for k in cand_k]
        return None if plan[0] >= umax[0] - 1e-9 else plan
    q = allowance / float(grid)
    # integer ceil costs: sum(cost_cells) <= grid  =>  weighted spend <=
    # allowance exactly (each candidate's cells over-cover its true cost)
    costs = [
        np.ceil(w[i] * cand_k[i] / q - 1e-9).astype(np.int64)
        for i in range(H)
    ]
    neg = -np.inf
    dp = np.zeros(grid + 1, dtype=np.float64)
    wins: list[np.ndarray] = []
    t_axis = np.arange(grid + 1)
    for i in range(H):
        c, v = costs[i], cand_v[i]
        feas = c <= grid
        if not feas.any():
            return None
        c, v = c[feas], v[feas]
        # cand[j, t] = dp[t - c_j] + v_j where feasible
        shifted = np.full((len(c), grid + 1), neg)
        for j in range(len(c)):
            cj = int(c[j])
            shifted[j, cj:] = dp[: grid + 1 - cj] + v[j]
        win = np.argmax(shifted, axis=0)
        dp = shifted[win, t_axis]
        # record the candidate index in the unfiltered array for backtrack
        wins.append((np.flatnonzero(feas)[win], np.asarray(c)[win]))
    if not np.isfinite(dp[grid]):
        return None
    plan = [0.0] * H
    t = grid
    for i in range(H - 1, -1, -1):
        jfull, cwin = wins[i]
        j = int(jfull[t])
        plan[i] = float(cand_k[i][j])
        t -= int(cwin[t])
    return None if plan[0] >= umax[0] - 1e-9 else plan


# ---------------------------------------------------------------------------
# Fused device-resident sparse solve (DESIGN.md §14)
# ---------------------------------------------------------------------------

#: fused-path grid bound: fall back to host when the padded global spend
#: grid would exceed this many states (churn storms with tiny gcd pitches)
_FUSED_MAX_NB = 4096

#: per-stage option-count bound for the padded [S, L, K] device banks
_FUSED_MAX_OPTS = 1024


def _pow2_at_least(n: int, floor: int) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


class FusedState:
    """Device-resident warm state for the fused steady-state round.

    Holds the padded ``[S, L, K]`` option banks (spend offsets on the
    shared integer micro-watt lattice + float64 values) as *resident jax
    device arrays*, the host-side per-row content signatures that drive
    delta patching, and the reversed per-stage key arrays the host
    assembly maps device backpointers through.  Banks use
    **capacity-slack layouts** (DESIGN.md §17): padded dims are quantized
    tiers (pow2 options/grids, identity-row stage padding) that only ever
    grow, so membership/structure churn inside the slack is pure row
    content.  The churn-boundary contract (DESIGN.md §14/§17):

     * same layout + same row signatures  -> zero upload, straight to the
       jitted pipeline;
     * same layout, k rows changed        -> one donated scatter of the k
       rebuilt rows (O(churn) upload) — this now includes class
       add/remove/split/merge, pitch changes and leaf-name permutations
       that used to be shape changes;
     * layout changed (leaf set, pad tier growth, topology edit) ->
       **device-side compaction**: a jitted gather repacks every clean
       row into the new geometry and only dirty rows upload; the round
       still runs fused (O(churn), same round — no host fallback).

    Only the cold start (no resident banks) builds banks on the host and
    uploads them whole (``stats['rebuilds']``).  ``last_key``/
    ``last_solution`` short-circuit the host assembly when the device
    decision vector is unchanged round-over-round.
    """

    def __init__(self):
        self.shape: tuple | None = None  # capacity-slack layout signature
        self.names: tuple | None = None  # per-leaf names (compaction map)
        self.row_sigs: list | None = None  # [L][S] per-row content sigs
        self.kb_dev = None  # [S, L, K] int32 device bank (global lattice)
        self.vb_dev = None  # [S, L, K] float64 device bank
        self.keys_desc: list | None = None  # [L][S] host reversed key arrays
        self.g: int = 0  # global micro-watt lattice pitch
        self.last_key: tuple | None = None
        self.last_solution: MCKPSolution | None = None
        #: (curve key tuple) -> (leaf gcd pitch, per-class micro ints)
        self._leaf_ints: dict = {}
        #: row sig -> (kb_glob desc, vals desc, keys desc)
        self._row_cache: dict = {}
        #: last round's wall-clock split: prep/patch/compact/dispatch/
        #: backtrack/assembly seconds (tools/profile_round.py --churn)
        self.last_segments: dict = {}
        self.stats: dict = {
            "rounds": 0,
            "fallbacks": 0,
            "rebuilds": 0,
            "compactions": 0,
            "row_uploads": 0,
            "short_circuits": 0,
            "slack_utilization": 0.0,
            "device_s": 0.0,
            "fallback_reason": "",
        }

    def clear(self) -> None:
        self.shape = None
        self.names = None
        self.row_sigs = None
        self.kb_dev = None
        self.vb_dev = None
        self.keys_desc = None
        self.g = 0
        self.last_key = None
        self.last_solution = None
        self._leaf_ints.clear()
        self._row_cache.clear()
        self.last_segments = {}


@functools.cache
def _fused_patch_fn():
    """Donated row scatter: patch changed (stage, leaf) rows of a resident
    bank in place (the donation reuses the device buffer, so steady-state
    churn uploads only the dirty rows, never the whole bank)."""
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def patch(bank, s_idx, l_idx, rows):
        return bank.at[s_idx, l_idx].set(rows)

    return patch


@functools.cache
def _fused_shards() -> int:
    """Device count the fused leaf scan shards over.

    Defaults to every visible device (1 on a single-device host — the
    transparent unsharded path); ``REPRO_FUSED_SHARDS`` overrides, so a
    multi-device process can also compile the single-device pipeline and
    certify the sharded one bitwise against it.
    """
    import os

    import jax

    env = os.environ.get("REPRO_FUSED_SHARDS")
    n = int(env) if env else jax.device_count()
    return max(1, min(n, jax.device_count()))


@functools.cache
def _tree_ops(
    tree_sig: tuple | int, first_out: int
) -> tuple[tuple, dict, dict, tuple]:
    """Lower a nested domain signature to its static combine-op list.

    ``tree_sig``: leaf = spec row index; internal domain =
    ``("d", dom_idx, (child_sigs...))`` with ``dom_idx`` post-order.
    Rows ``0..L-1`` are the DFS leaves; each pairwise combine allocates
    the next row id from ``first_out``.  Per domain the ops replay
    ``_combine_frontiers``' balanced order exactly (adjacent pairs, odd
    tail carried up; a single-child domain emits no op — its cap already
    flows through the child's cascaded eff).  Returns ``(ops, depth,
    leaves_under, dom_rows)``: ops as ``(left_row, right_row, out_row,
    dom_idx)`` in topological order, per-row combine depth and leaf
    count, and each internal domain's result row.
    """
    ops: list[tuple[int, int, int, int]] = []
    depth: dict[int, int] = {}
    leaves_under: dict[int, int] = {}
    nxt = [first_out]
    dom_rows: dict[int, int] = {}

    def build(sig):
        if isinstance(sig, int):
            depth.setdefault(sig, 0)
            leaves_under.setdefault(sig, 1)
            return sig
        _tag, dom_idx, children = sig
        rows = [build(c) for c in children]
        while len(rows) > 1:
            merged = []
            for i in range(0, len(rows) - 1, 2):
                left, right = rows[i], rows[i + 1]
                out = nxt[0]
                nxt[0] += 1
                depth[out] = 1 + max(depth[left], depth[right])
                leaves_under[out] = leaves_under[left] + leaves_under[right]
                ops.append((left, right, out, dom_idx))
                merged.append(out)
            if len(rows) % 2:
                merged.append(rows[-1])
            rows = merged
        dom_rows[dom_idx] = rows[0]
        return rows[0]

    build(tree_sig)
    # renumber output rows into wave (depth) order: the pipeline buffer
    # appends each wave's outputs contiguously, so a row's id must equal
    # its append position — creation order interleaves domains and would
    # not (stable sort keeps within-depth creation order)
    order = sorted(range(len(ops)), key=lambda i: depth[ops[i][2]])
    remap = {
        ops[i][2]: first_out + pos for pos, i in enumerate(order)
    }
    ops_w = tuple(
        (
            remap.get(ops[i][0], ops[i][0]),
            remap.get(ops[i][1], ops[i][1]),
            remap[ops[i][2]],
            ops[i][3],
        )
        for i in order
    )
    return (
        ops_w,
        {remap.get(r, r): d for r, d in depth.items()},
        {remap.get(r, r): v for r, v in leaves_under.items()},
        tuple(
            remap.get(dom_rows[i], dom_rows[i]) for i in range(len(dom_rows))
        ),
    )


def _tree_waves(
    ops: tuple, depth: dict, leaves_under: dict, nb: int, nbt: int
) -> tuple:
    """Group combine ops into depth waves for batched kernel launches.

    Ops at the same combine depth are independent (inputs come from
    strictly shallower rows), so each wave is one row-batched (max,+)
    dispatch.  Per wave, the enumerated right-offset count is the static
    support bound of its right inputs: ``min(nbt, max_right_leaves *
    (nb - 1) + 1)`` — offsets beyond a subtree's reachable spend are
    provably ``-inf`` and dropping them is bitwise-neutral.
    """
    by_depth: dict[int, list] = {}
    for op in ops:
        by_depth.setdefault(depth[op[2]], []).append(op)
    return tuple(
        (
            min(nbt, max(leaves_under[op[1]] for op in wave) * (nb - 1) + 1),
            tuple(wave),
        )
        for _d, wave in sorted(by_depth.items())
    )


@functools.cache
def _fused_pipeline_fn(
    tree: tuple | None, L: int, Lp: int, S: int, K: int, NB: int, NBT: int,
    block_b: int, shards: int, interpret: bool,
):
    """Build the jitted fused round for one static shape.

    One XLA program: batched leaf super-stage DPs (Pallas sparse-option
    (max,+) stages with backpointer outputs), the depth-wave frontier
    aggregation schedule of an arbitrary-depth domain tree (the same
    kernel with dense descending offsets, masked at each owning domain's
    cap cut), the root argmax, and the index-based backtrack — device
    gathers through the recorded backpointer tables instead of a host
    Python unwind.  Mirrors ``_superstage_dp_batch`` +
    ``_combine_frontiers`` + ``_backtrack_superstages`` op for op
    (float64, first-max argmax, per-stage feasibility masks, per-pair
    cap pruning), so its decisions are bit-for-bit the sparse host
    path's at any tree depth.

    ``tree`` is the static ``(waves, dom_rows)`` schedule from
    ``_tree_ops``/``_tree_waves`` (None for flat/leaf-root rounds);
    ``Lp >= L`` is the leaf row count padded to a multiple of
    ``shards`` — with ``shards > 1`` the leaf scan runs under
    ``shard_map`` over the leaf axis (rows are independent, so the
    sharded scan is bitwise the single-device one; DESIGN.md §16), and
    the aggregation waves tree-reduce the gathered per-device frontier
    partials.

    Two lattice grids keep the work proportional to the *support*: leaf
    DPs and backtracking run on the per-leaf grid ``NB`` (max leaf spend
    + 1), the aggregation waves on ``NBT >= NB`` (cap-cut/support-sum
    bound), and each wave enumerates only ``K_level`` right-spend
    offsets — the static support bound of its right inputs.  Dropped
    grid tails and offsets are provably ``-inf`` (beyond every reachable
    spend sum), so values, first-max winners and backpointers of every
    reachable state are bitwise unchanged versus the single-grid form.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import mckp_dp as _mk

    waves, dom_rows = tree if tree is not None else ((), ())
    root_row = dom_rows[-1] if dom_rows else 0

    def leaf_scan(kb, vb, tmax_leaf):
        t_idx = jnp.arange(NB)
        neg = jnp.asarray(-jnp.inf, vb.dtype)
        dp0 = jnp.full((kb.shape[1], NB), neg).at[:, 0].set(0.0)

        def stage(dp, skv):
            kb_s, vb_s = skv
            out, arg = _mk.maxplus_stage_pallas_batched(
                dp, kb_s, vb_s, block_b=block_b, interpret=interpret
            )
            # per-leaf feasibility mask after every stage == the host
            # batch's out[li, tmax+1:] = -inf
            out = jnp.where(t_idx[None, :] > tmax_leaf[:, None], neg, out)
            return out, arg

        return jax.lax.scan(stage, dp0, (kb, vb))

    if shards > 1:
        from jax.sharding import PartitionSpec as P

        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:  # newer jax: promoted out of experimental
            from jax import shard_map  # type: ignore[attr-defined]

        from repro.kernels import ops as _kops

        leaf_fn = shard_map(
            leaf_scan,
            mesh=_kops.leaf_shard_mesh(shards),
            in_specs=(
                P(None, "leaves", None),
                P(None, "leaves", None),
                P("leaves"),
            ),
            out_specs=(P("leaves", None), P(None, "leaves", None)),
            check_rep=False,  # pallas_call carries no replication rule
        )
    else:
        leaf_fn = leaf_scan

    @jax.jit
    def run(kb, vb, tmax_leaf, tcuts):
        rows_i = jnp.arange(L)
        neg = jnp.asarray(-jnp.inf, vb.dtype)
        dp, wins = leaf_fn(kb, vb, tmax_leaf)  # dp: [Lp, NB]; wins: [S, Lp, NB]

        # frontier aggregation: depth waves of pairwise combines, each the
        # same sparse-option kernel with the dense descending offset row
        # (b-spend descending == the dict DP's smallest-a-spend tie-break),
        # masked at the owning domain's cap cut — the device image of
        # _combine_frontiers applying _maxplus_pair(..., eff) at every pair
        t_idx_tree = jnp.arange(NBT)
        tree_block = min(NBT, 256)
        buf = (
            jnp.concatenate([dp, jnp.full((Lp, NBT - NB), neg)], axis=1)
            if NBT > NB
            else dp
        )
        wins_tree = []
        for k_level, wave in waves:
            left = buf[jnp.asarray([op[0] for op in wave])]
            right = buf[jnp.asarray([op[1] for op in wave])]
            comb_desc = jnp.arange(k_level - 1, -1, -1, dtype=jnp.int32)
            ckb = jnp.broadcast_to(comb_desc[None, :], (len(wave), k_level))
            cvb = right[:, k_level - 1 :: -1]
            out, arg = _mk.maxplus_stage_pallas_batched(
                left, ckb, cvb, block_b=tree_block, interpret=interpret
            )
            tc = tcuts[jnp.asarray([op[3] for op in wave])]
            out = jnp.where(t_idx_tree[None, :] > tc[:, None], neg, out)
            wins_tree.append(arg)
            buf = jnp.concatenate([buf, out], axis=0)

        root_vec = buf[root_row]
        t_root = jnp.argmax(root_vec).astype(jnp.int32)  # first max
        root_val = root_vec[t_root]

        # tree backtrack: split t down the static schedule via gathers,
        # in reverse wave order (an op's output t is known before its
        # inputs are needed — the schedule is topological)
        t_of = {root_row: t_root}
        for (k_level, wave), win in zip(reversed(waves), reversed(wins_tree)):
            for i in range(len(wave) - 1, -1, -1):
                l_row, r_row, o_row, _d = wave[i]
                t_out = t_of[o_row]
                j = win[i, t_out]
                t_r = (k_level - 1 - j).astype(jnp.int32)
                t_of[r_row] = t_r
                t_of[l_row] = (t_out - t_r).astype(jnp.int32)
        t_leaf = jnp.stack([t_of[i] for i in range(L)]).astype(jnp.int32)
        t_dom = (
            jnp.stack([t_of[r] for r in dom_rows]).astype(jnp.int32)
            if dom_rows
            else jnp.zeros((0,), jnp.int32)
        )

        # leaf backtrack: walk the backpointer tables stage-by-stage, the
        # device-gather analogue of _IntStages.backtrack
        def bstep(t, skw):
            kb_s, win_s = skw
            j = win_s[rows_i, t]
            return (t - kb_s[rows_i, j]).astype(jnp.int32), j.astype(jnp.int32)

        _, js_rev = jax.lax.scan(bstep, t_leaf, (kb[::-1], wins[::-1]))
        js = js_rev[::-1].swapaxes(0, 1)  # [L, S]
        return t_root, t_leaf, js, root_val, t_dom

    return run


def _fused_leaf_rows(
    spec: tuple, fstate: FusedState
) -> tuple[int, int, list] | None:
    """Per-leaf lattice prep, mirroring ``_superstage_dp_batch``'s per-job
    block: micro-int class keys, the leaf gcd pitch, and the per-stage
    descending (offsets, values, keys) rows.  None routes to host."""
    name, eff, plan, curves_, curve_keys = spec
    lkey = tuple(curve_keys)
    ent = fstate._leaf_ints.get(lkey)
    if ent is None:
        ints = []
        g_l = 0
        for c in curves_:
            ia = _micro_int(c.keys)
            if ia is None or not len(ia):
                return None
            ints.append(ia)
            g_l = int(np.gcd(g_l, np.gcd.reduce(ia)))
        all_zero = g_l == 0  # every class key is 0.0: the leaf can only spend 0
        if g_l <= 0:
            g_l = 1
        if len(fstate._leaf_ints) > 1024:
            fstate._leaf_ints.clear()
        ent = (g_l, ints, all_zero)
        fstate._leaf_ints[lkey] = ent
    g_l, ints, all_zero = ent
    if all_zero:
        tmax_host = 0  # zero-spend leaf: one state, any lattice pitch fits
    else:
        bound = np.floor((eff + 1e-9) * 1e6 / g_l)
        if not np.isfinite(bound) or bound < 0:
            return None
        tmax_host = int(bound)
    rows = []
    for s, (ia, curve, ckey) in enumerate(zip(ints, curves_, curve_keys)):
        sig = (ckey, g_l, tmax_host)
        row = fstate._row_cache.get(sig)
        if row is None:
            keep = np.flatnonzero(ia // g_l <= tmax_host)
            if not len(keep):
                return None
            kb = (ia[keep] // g_l)[::-1].copy()  # leaf-lattice units
            row = (
                kb,
                curve.vals[keep][::-1].copy(),
                curve.keys[keep][::-1].copy(),
                sig,
            )
            if len(fstate._row_cache) > 4096:
                fstate._row_cache.clear()
            fstate._row_cache[sig] = row
        rows.append(row)
    return g_l, tmax_host, rows, all_zero


def _fused_run(
    specs: list[tuple],
    eff_root: float,
    kind: str,
    tree_sig: tuple | int | None,
    doms: tuple,
    *,
    pick_cache: MutableMapping | None,
    fstate: FusedState,
    st: "HierState | None" = None,
) -> MCKPSolution | None:
    """One fused device round over prepared leaf specs.

    ``specs``: per-leaf (name, eff, plan, curves, curve_keys) in DFS
    order.  ``kind``: 'flat' (grouped solve, no domain accounting),
    'leaf_root' (hierarchical root that is itself a leaf) or 'tree'
    (arbitrary-depth domain tree: ``tree_sig`` is the nested signature
    over spec indices and ``doms`` the post-order (name, eff) list of
    internal domains, root last).

    Structure churn never routes to the host (DESIGN.md §17): content
    changes (class add/remove/split/merge, pitch moves, headroom drift)
    patch rows in place under the unchanged capacity-slack layout, and
    layout changes (leaf set, pad-tier growth, topology edits) repack the
    resident banks by device-side compaction — either way the fused
    pipeline produces this round's allocation.  Returns None only for
    off-lattice keys, oversized grids, empty rounds or an infeasible
    root; ``fstate.stats['fallback_reason']`` records which.
    """
    import time

    import jax
    import jax.experimental
    import jax.numpy as jnp

    stats = fstate.stats
    seg = fstate.last_segments = {
        "prep_s": 0.0, "patch_s": 0.0, "compact_s": 0.0,
        "dispatch_s": 0.0, "backtrack_s": 0.0, "assembly_s": 0.0,
    }
    t_seg = time.perf_counter()
    L = len(specs)
    if L == 0:
        stats["fallbacks"] += 1
        stats["fallback_reason"] = "empty"
        return None

    prepped = []
    for spec in specs:
        pr = _fused_leaf_rows(spec, fstate)
        if pr is None:
            stats["fallbacks"] += 1
            stats["fallback_reason"] = "off_lattice"
            return None
        prepped.append(pr)

    g = 0
    for (g_l, _, rows, all_zero) in prepped:
        if rows and not all_zero:
            # zero-spend leaves contribute nothing: their only state (0)
            # sits on every lattice, so they must not shrink the pitch
            g = int(np.gcd(g, g_l))
    if g <= 0:
        g = 1

    shards = _fused_shards()
    Lp = -(-L // shards) * shards  # pad rows are identity leaves

    s_max = 1
    k_max = 1
    nb_needed = 1
    tmax_dev = np.zeros(Lp, dtype=np.int32)
    for li, (g_l, tmax_host, rows, all_zero) in enumerate(prepped):
        if rows:
            mult = 1 if all_zero else g_l // g
            td = tmax_host * mult
            if td + 1 > _FUSED_MAX_NB:
                stats["fallbacks"] += 1
                stats["fallback_reason"] = "grid_overflow"
                return None
            tmax_dev[li] = td
            nb_needed = max(nb_needed, td + 1)
            s_max = max(s_max, len(rows))
            for kb, _, _, _ in rows:
                k_max = max(k_max, len(kb))

    use_tree = kind == "tree"
    tcuts = np.zeros(len(doms), dtype=np.int32)
    nbt_needed = nb_needed
    ops: tuple = ()
    depths: dict = {}
    leaves_under: dict = {}
    dom_rows: tuple = ()
    if use_tree:
        # the exact _maxplus_pair prune per internal domain: keep combined
        # states whose reconstructed float64 key is <= eff + 1e-9
        cut_by_eff: dict[float, int] = {}
        for i, (_dn, eff_d) in enumerate(doms):
            c = cut_by_eff.get(eff_d)
            if c is None:
                ub = int((eff_d + 1e-9) * 1e6 // g) + 1
                if ub + 1 > 4 * _FUSED_MAX_NB:
                    stats["fallbacks"] += 1
                    stats["fallback_reason"] = "grid_overflow"
                    return None
                ks = (
                    np.arange(ub + 2, dtype=np.int64) * g
                ).astype(np.float64) * 1e-6
                c = int(np.flatnonzero(ks <= eff_d + 1e-9).max())
                cut_by_eff[eff_d] = c
            tcuts[i] = c
        ops, depths, leaves_under, dom_rows = _tree_ops(tree_sig, Lp)
        # the tree grid only needs the reachable spend-sum support: every
        # state beyond min(cap cut, sum of input supports) is -inf
        support = {li: int(tmax_dev[li]) for li in range(L)}
        for l_row, r_row, o_row, d in ops:
            support[o_row] = min(
                support[l_row] + support[r_row], int(tcuts[d])
            )
        nbt_needed = max(nb_needed, max(support.values()) + 1)

    if k_max > _FUSED_MAX_OPTS:
        stats["fallbacks"] += 1
        stats["fallback_reason"] = "grid_overflow"
        return None
    nb_pad = _pow2_at_least(nb_needed, 16)
    nbt_pad = _pow2_at_least(nbt_needed, 16) if use_tree else nb_pad
    if max(nb_pad, nbt_pad) > _FUSED_MAX_NB:
        stats["fallbacks"] += 1
        stats["fallback_reason"] = "grid_overflow"
        return None
    s_pad = max(1, -(-s_max // 8) * 8)
    k_pad = _pow2_at_least(max(k_max, 1), 4)

    names = tuple(name for name, *_ in specs)
    dom_names = tuple(dn for dn, _ in doms)
    # sticky pads: padding up is always exact (identity stages, -inf
    # option tails, masked grid tops), so never *shrink* the resident
    # tiers while the solver kind matches — churn across a pow2 boundary
    # must not flap between compactions and recompiles, and keeping tiers
    # across leaf-count changes means compaction never truncates content
    if fstate.shape is not None and fstate.shape[0] == kind:
        _pk, _pL, ps, pkk, pnb, pnbt = fstate.shape[:6]
        s_pad = max(s_pad, ps)
        k_pad = max(k_pad, pkk)
        nb_pad = max(nb_pad, pnb)
        nbt_pad = max(nbt_pad, pnbt) if use_tree else nb_pad
    nbt_pad = max(nbt_pad, nb_pad)
    # capacity-slack layout signature (DESIGN.md §17): only what the
    # jitted pipeline is specialized on — kind, leaf count, padded tiers
    # and the static tree schedule.  Everything else (global pitch g,
    # leaf names, class digests/layouts, option rows) is *content*: the
    # per-row signatures below move it through the delta-patch or
    # compaction path under an unchanged layout, with no re-jit and no
    # host round.  Row signatures fold in the leaf->global lattice
    # multiplier, so a pitch change re-uploads exactly the rows whose
    # device image (kb * mult) it moved.
    layout = (kind, L, s_pad, k_pad, nb_pad, nbt_pad, tree_sig)
    stats["slack_utilization"] = max(
        s_max / s_pad,
        k_max / k_pad,
        nb_needed / nb_pad,
        (nbt_needed / nbt_pad) if use_tree else 0.0,
    )

    bank_shape = (s_pad, Lp, k_pad)
    rebuild = fstate.shape is None
    compact = not rebuild and (
        fstate.shape != layout or tuple(fstate.kb_dev.shape) != bank_shape
    )
    if compact and (
        fstate.shape[0] != kind
        or len(set(names)) != len(names)
        or len(set(fstate.names or ())) != len(fstate.names or ())
    ):
        # unmappable resident state (different solver kind, ambiguous
        # leaf identities): cold host rebuild — still a fused round
        rebuild, compact = True, False

    with jax.experimental.enable_x64():

        def upload_rows(entries):
            # entries: (s, li, kb_glob | None, vb | None); None = identity.
            # The scatter batch pads to a pow2 tier by *repeating the
            # first entry* (duplicate index, identical row: the set is
            # value-deterministic) — the jitted scatter then sees a few
            # quantized shapes instead of recompiling per churn count.
            patch = _fused_patch_fn()
            m = len(entries)
            mp = _pow2_at_least(m, 8)
            s_np = np.empty(mp, dtype=np.int32)
            l_np = np.empty(mp, dtype=np.int32)
            kb_rows = np.zeros((mp, k_pad), dtype=np.int32)
            vb_rows = np.full((mp, k_pad), -np.inf)
            for i, (s, li, kbg, vb) in enumerate(entries):
                s_np[i] = s
                l_np[i] = li
                if kbg is None:
                    vb_rows[i, 0] = 0.0
                else:
                    kb_rows[i, : len(kbg)] = kbg
                    vb_rows[i, : len(vb)] = vb
            s_np[m:] = s_np[0]
            l_np[m:] = l_np[0]
            kb_rows[m:] = kb_rows[0]
            vb_rows[m:] = vb_rows[0]
            si, lj = jnp.asarray(s_np), jnp.asarray(l_np)
            fstate.kb_dev = patch(fstate.kb_dev, si, lj, jnp.asarray(kb_rows))
            fstate.vb_dev = patch(fstate.vb_dev, si, lj, jnp.asarray(vb_rows))
            stats["row_uploads"] += m
            fstate.last_key = None

        seg["prep_s"] = time.perf_counter() - t_seg
        t_seg = time.perf_counter()
        if rebuild:
            # cold start (or unmappable state): host-built banks, one
            # full upload — the only non-O(churn) sync point left
            kb_np = np.zeros((s_pad, Lp, k_pad), dtype=np.int32)
            vb_np = np.full((s_pad, Lp, k_pad), -np.inf)
            vb_np[:, :, 0] = 0.0  # identity padding stages/rows: spend 0, +0.0
            row_sigs: list[list] = [[None] * s_pad for _ in range(L)]
            keys_desc: list[list] = [[None] * s_pad for _ in range(L)]
            for li, (g_l, tmax_host, rows, all_zero) in enumerate(prepped):
                mult = 1 if all_zero else g_l // g
                for s, (kb, vb, keys, sig) in enumerate(rows):
                    n = len(kb)
                    kb_np[s, li, :n] = kb * mult
                    vb_np[s, li, :n] = vb
                    vb_np[s, li, n:] = -np.inf
                    row_sigs[li][s] = (sig, mult)
                    keys_desc[li][s] = keys
            fstate.kb_dev = jnp.asarray(kb_np)
            fstate.vb_dev = jnp.asarray(vb_np)
            fstate.row_sigs = row_sigs
            fstate.keys_desc = keys_desc
            fstate.shape = layout
            fstate.names = names
            fstate.g = g
            fstate.last_key = None
            fstate.last_solution = None
            stats["rebuilds"] += 1
            seg["patch_s"] += time.perf_counter() - t_seg
        elif compact:
            # device-side compaction (DESIGN.md §17): the layout moved
            # (leaf set / pad tier / topology), so repack every row whose
            # content signature survived via one jitted gather out of the
            # old banks — clean subtrees keep their rows bit-for-bit with
            # zero upload — and scatter only the dirty rows after
            from repro.kernels import ops as _kops

            old_pos = {nm: i for i, nm in enumerate(fstate.names or ())}
            o_s_pad = int(fstate.kb_dev.shape[0])
            src_s = np.full((s_pad, Lp), -1, dtype=np.int32)
            src_l = np.full((s_pad, Lp), -1, dtype=np.int32)
            row_sigs = [[None] * s_pad for _ in range(L)]
            keys_desc = [[None] * s_pad for _ in range(L)]
            dirty: list[tuple] = []
            for li, (g_l, tmax_host, rows, all_zero) in enumerate(prepped):
                mult = 1 if all_zero else g_l // g
                oli = old_pos.get(names[li])
                for s in range(s_pad):
                    if s < len(rows):
                        kb, vb, keys, sig = rows[s]
                        esig = (sig, mult)
                    else:
                        kb = vb = keys = None
                        esig = None
                    row_sigs[li][s] = esig
                    keys_desc[li][s] = keys
                    if esig is None:
                        continue  # identity rows come from the init
                    if (
                        oli is not None
                        and s < o_s_pad
                        and fstate.row_sigs[oli][s] == esig
                    ):
                        src_s[s, li] = s
                        src_l[s, li] = oli
                    else:
                        dirty.append((s, li, kb * mult, vb))
            fstate.kb_dev, fstate.vb_dev = _kops.bank_compact(
                fstate.kb_dev, fstate.vb_dev,
                jnp.asarray(src_s), jnp.asarray(src_l), k_pad=k_pad,
            )
            fstate.row_sigs = row_sigs
            fstate.keys_desc = keys_desc
            fstate.shape = layout
            fstate.names = names
            fstate.g = g
            fstate.last_key = None
            fstate.last_solution = None
            stats["compactions"] += 1
            seg["compact_s"] += time.perf_counter() - t_seg
            t_seg = time.perf_counter()
            if dirty:
                upload_rows(dirty)
            seg["patch_s"] += time.perf_counter() - t_seg
        else:
            # delta patch: upload only the rows whose content signature
            # moved (class churn / pitch moves / headroom drift), via
            # donated scatter
            entries: list[tuple] = []
            for li, (g_l, tmax_host, rows, all_zero) in enumerate(prepped):
                mult = 1 if all_zero else g_l // g
                for s in range(s_pad):
                    if s < len(rows):
                        kb, vb, keys, sig = rows[s]
                        esig = (sig, mult)
                    else:
                        kb = vb = keys = None
                        esig = None
                    if fstate.row_sigs[li][s] == esig:
                        continue
                    entries.append(
                        (s, li, None if kb is None else kb * mult, vb)
                    )
                    fstate.row_sigs[li][s] = esig
                    fstate.keys_desc[li][s] = keys
            if entries:
                upload_rows(entries)
            fstate.names = names
            fstate.g = g
            seg["patch_s"] += time.perf_counter() - t_seg

        tree_static = None
        if use_tree:
            waves = _tree_waves(ops, depths, leaves_under, nb_pad, nbt_pad)
            tree_static = (waves, dom_rows)
        run = _fused_pipeline_fn(
            tree_static, L, Lp, s_pad, k_pad, nb_pad, nbt_pad,
            min(nb_pad, 256), shards, _interpret(),
        )
        t0 = time.perf_counter()
        out = jax.block_until_ready(
            run(
                fstate.kb_dev,
                fstate.vb_dev,
                jnp.asarray(tmax_dev),
                jnp.asarray(tcuts),
            )
        )
        stats["device_s"] += time.perf_counter() - t0
        seg["dispatch_s"] += time.perf_counter() - t0
        stats["rounds"] += 1

    t_seg = time.perf_counter()
    if not np.isfinite(float(out[3])):
        # no feasible root state: keep the host path authoritative
        stats["fallbacks"] += 1
        stats["fallback_reason"] = "no_feasible_root"
        return None
    stats["fallback_reason"] = ""
    t_root = int(out[0])
    t_leaf = np.asarray(out[1])
    js = np.asarray(out[2])

    leaf_meta = []
    for name, eff, plan, curves_, curve_keys in specs:
        tok = (
            st.token(("leaf", (plan.layout, _qkey(eff))))
            if st is not None
            else None
        )
        leaf_meta.append((tok, plan.key))

    # layout no longer pins pitch / leaf names / class layouts (they are
    # patchable content now), so the short-circuit key carries them
    # explicitly alongside the row signatures
    dec_key = (
        layout,
        g,
        names,
        dom_names,
        tuple(tuple(rs) for rs in fstate.row_sigs),
        tuple(leaf_meta),
        t_root,
        t_leaf.tobytes(),
        js.tobytes(),
    )
    seg["backtrack_s"] += time.perf_counter() - t_seg
    t_seg = time.perf_counter()
    if dec_key == fstate.last_key and fstate.last_solution is not None:
        # unchanged device decision vector: the previous solution is the
        # bit-identical answer — skip the host assembly entirely
        fstate.stats["short_circuits"] += 1
        return fstate.last_solution

    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    domain_spent: dict[str, float] | None = (
        {} if kind in ("tree", "leaf_root") else None
    )
    if use_tree:
        # per-internal-domain spends off the device backtrack: the
        # float64(t * g) * 1e-6 reconstruction is the host frontier-key
        # round-trip, so the values are bitwise _backtrack_frontier's
        t_dom = np.asarray(out[4])
        for i, (dname, _de) in enumerate(doms):
            domain_spent[dname] = float(
                np.float64(int(t_dom[i]) * g) * 1e-6
            )
    leaf_totals: list[tuple[float, float]] = []
    for li, ((name, eff, plan, curves_, curve_keys), (tok, _pk)) in enumerate(
        zip(specs, leaf_meta)
    ):
        u = float(np.float64(int(t_leaf[li]) * g) * 1e-6)
        if domain_spent is not None:
            domain_spent[name] = u
        n_stages = len(plan.classes)
        spends = [
            float(fstate.keys_desc[li][s][int(js[li, s])])
            for s in range(n_stages)
        ]
        skey = None
        if st is not None and plan.key is not None:
            skey = (tok, plan.key, tuple(spends))
            hit = st.leaf_sol_cache.get(skey)
            if hit is not None:
                picks.update(hit[0])
                leaf_totals.append((hit[1], hit[2]))
                continue
        lp, lt, ls = _assemble_plan(
            plan, curve_keys, curves_, spends, pick_cache
        )
        if skey is not None:
            st.leaf_sol_cache[skey] = (lp, lt, ls)
        picks.update(lp)
        leaf_totals.append((lt, ls))

    total = 0.0
    spent = 0.0
    for lt, ls in leaf_totals:
        total += lt
        spent += ls
    sol = MCKPSolution(
        total_value=total, spent=spent, picks=picks, domain_spent=domain_spent
    )
    fstate.last_key = dec_key
    fstate.last_solution = sol
    seg["assembly_s"] += time.perf_counter() - t_seg
    return sol


@functools.cache
def _interpret() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def solve_grouped_fused(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    fstate: FusedState,
    curve_cache: MutableMapping | None = None,
    pick_cache: MutableMapping | None = None,
    plan_cache: MutableMapping | None = None,
    chain_cache: MutableMapping | None = None,
) -> MCKPSolution | None:
    """Fused device-resident form of :func:`solve_sparse_grouped`.

    Returns the bit-for-bit identical solution, or None to fall back to
    the host path (off-lattice keys, oversized grids, empty rounds,
    infeasible roots).  Group/class churn is *not* a fallback: it
    patches or compacts the resident banks and solves fused in the same
    call (DESIGN.md §17).
    """
    plan = _leaf_plan(groups, plan_cache)
    curves_, curve_keys = _class_curves(
        plan.classes, budget, curve_cache, chain_cache
    )
    eff = float(budget)
    specs = [(None, eff, plan, curves_, curve_keys)]
    sol = _fused_run(
        specs, eff, "flat", None, (), pick_cache=pick_cache, fstate=fstate
    )
    return sol


def solve_hierarchical_fused(
    root: DomainGroups,
    budget: float,
    *,
    state: HierState,
    fstate: FusedState,
) -> MCKPSolution | None:
    """Fused device-resident form of the N-level sparse
    :func:`solve_hierarchical`.

    Walks the arbitrary-depth domain tree on the host exactly like
    ``_sparse_frontier`` (same cascaded effective caps, plans and class
    curves — shared caches), lowering it to a static combine schedule
    plus a dynamic per-domain cap-cut vector, then runs the whole
    decision pipeline on device (DESIGN.md §16).  Returns None to fall
    back to the host path: off-lattice keys, oversized grids, empty
    rounds or an infeasible root — ``fstate.stats['fallback_reason']``
    says which.  Structure changes (new class layouts, membership churn,
    topology edits) are served fused in the same round by row patching
    or device-side compaction of the resident banks (DESIGN.md §17).
    """
    eff_root = _domain_eff(root, float(budget))
    if not root.children:
        plan = _leaf_plan(root.groups, state.plan_cache)
        curves_, curve_keys = _class_curves(
            plan.classes, eff_root, state.curve_cache, state.chain_cache
        )
        specs = [(root.name, eff_root, plan, curves_, curve_keys)]
        return _fused_run(
            specs,
            eff_root,
            "leaf_root",
            None,
            (),
            pick_cache=state.pick_cache,
            fstate=fstate,
            st=state,
        )

    specs = []
    doms: list[tuple[str, float]] = []

    def walk(dom: DomainGroups, b: float):
        eff = _domain_eff(dom, b)
        if dom.children:
            child_sigs = tuple(walk(c, eff) for c in dom.children)
            doms.append((dom.name, eff))
            return ("d", len(doms) - 1, child_sigs)
        plan = _leaf_plan(dom.groups, state.plan_cache)
        curves_, curve_keys = _class_curves(
            plan.classes, eff, state.curve_cache, state.chain_cache
        )
        specs.append((dom.name, eff, plan, curves_, curve_keys))
        return len(specs) - 1

    tree_sig = walk(root, float(budget))
    return _fused_run(
        specs,
        eff_root,
        "tree",
        tree_sig,
        tuple(doms),
        pick_cache=state.pick_cache,
        fstate=fstate,
        st=state,
    )


# ---------------------------------------------------------------------------
# Dense-grid DP (numpy)
# ---------------------------------------------------------------------------


def _stage_maxplus(
    dp: np.ndarray,
    costs_u: np.ndarray,
    values: np.ndarray,
    chunk: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """One (max,+) stage restricted to option costs.

    dp' [b] = max_j dp[b - cost_j] + value_j   (invalid b-cost_j masked)
    Returns (dp', argmax_j) with first-max tie-breaking.  ``chunk`` bounds
    the [k, chunk] candidate tile for stages with many costs (the full
    (max,+) convolution of the hierarchical dense path, where costs_u is
    the whole budget grid); columns are independent, so chunking is
    bitwise-neutral.
    """
    nb = dp.shape[0]
    if chunk is None:
        chunk = nb
    out = np.empty(nb, dtype=np.float64)
    arg = np.empty(nb, dtype=np.int32)
    for b0 in range(0, nb, chunk):
        b = np.arange(b0, min(b0 + chunk, nb))
        # cand[j, b] = dp[b - c_j] + v_j
        idx = b[None, :] - costs_u[:, None]  # [k, chunk]
        valid = idx >= 0
        cand = (
            np.where(valid, dp[np.clip(idx, 0, nb - 1)], -np.inf)
            + values[:, None]
        )
        a = np.argmax(cand, axis=0)
        out[b] = cand[a, np.arange(len(b))]
        arg[b] = a
    return out, arg


def solve_dense(
    options: Sequence[OptionTable], budget: float, unit: float = 1.0
) -> MCKPSolution:
    """Vectorized dense DP at ``unit``-watt budget granularity."""
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    dp = np.zeros(nb, dtype=np.float64)
    args: list[np.ndarray] = []
    costs_per_app: list[np.ndarray] = []
    kept_per_app: list[np.ndarray] = []
    for opt in options:
        cu = np.ceil(opt.costs / unit - 1e-9).astype(np.int64)
        keep = cu < nb
        cu, vals = cu[keep], opt.values[keep]
        dp, arg = _stage_maxplus(dp, cu, vals)
        args.append(arg)
        costs_per_app.append(cu)
        kept_per_app.append(np.nonzero(keep)[0])

    b = int(np.argmax(dp))
    total = float(dp[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        j_local = int(args[i][b])
        j = int(kept_per_app[i][j_local])
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= int(costs_per_app[i][j_local])
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def _grouped_dense_layout(
    groups: Sequence[GroupedOptions], budget: float, unit: float
):
    """Digest-merged stage layout shared by the grouped dense solvers.

    Returns ``(names, stage_gids, tables, f_groups, ch_groups)``: the
    name-sorted receiver order, each receiver's behaviour-class id, and the
    per-class tables / dense curves — densified once per class instead of
    once per receiver.
    """
    classes = _merge_classes(groups)
    pairs = sorted(
        (name, cid)
        for cid, (_, members, _) in enumerate(classes)
        for name in members
    )
    names = [p[0] for p in pairs]
    stage_gids = np.array([p[1] for p in pairs], dtype=np.int32)
    tables = [c[0] for c in classes]
    fs, chs = [], []
    for table in tables:
        f, ch = dense_curve(table, budget, unit)
        fs.append(f)
        chs.append(ch)
    return names, stage_gids, tables, np.stack(fs), np.stack(chs)


def solve_dense_grouped(
    groups: Sequence[GroupedOptions], budget: float, unit: float = 1.0
) -> MCKPSolution:
    """Grouped numpy dense DP: per-class cost/value prep, one stage per
    receiver — bitwise identical to ``solve_dense`` on the name-sorted
    ungrouped expansion (same stage convolutions in the same order)."""
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    names, stage_gids, tables, _, _ = _grouped_dense_layout(
        groups, budget, unit
    )
    cu_of, vals_of, kept_of = [], [], []
    for table in tables:
        cu = np.ceil(table.costs / unit - 1e-9).astype(np.int64)
        keep = cu < nb
        cu_of.append(cu[keep])
        vals_of.append(table.values[keep])
        kept_of.append(np.nonzero(keep)[0])

    dp = np.zeros(nb, dtype=np.float64)
    args: list[np.ndarray] = []
    for gid in stage_gids:
        dp, arg = _stage_maxplus(dp, cu_of[gid], vals_of[gid])
        args.append(arg)

    b = int(np.argmax(dp))
    total = float(dp[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(names) - 1, -1, -1):
        gid = stage_gids[i]
        table = tables[gid]
        j_local = int(args[i][b])
        j = int(kept_of[gid][j_local])
        picks[names[i]] = (
            float(table.costs[j]),
            float(table.values[j]),
            (float(table.caps[j, 0]), float(table.caps[j, 1])),
        )
        b -= int(cu_of[gid][j_local])
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Dense-grid DP (JAX, scan over receivers)
# ---------------------------------------------------------------------------


def _jax_dp(f_mat, backend: str = "jax"):
    """jit-compiled forward DP over dense curves.

    f_mat: [N, NB] monotone curves (F_i). Returns (dp_final [NB],
    argk [N, NB]) where argk[i, b] is the spend chosen for receiver i when b
    units are available to receivers 0..i.

    The inner maximization DP'[b] = max_k DP[b-k] + F[k] is a full
    (max,+)-convolution; ``backend='pallas'`` routes it through the Pallas
    TPU kernel (repro.kernels.mckp_dp), 'jax' uses a pure-jnp masked gather.
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv = kops.maxplus_conv
    else:
        from repro.kernels import ref as kref

        conv = kref.maxplus_conv

    def stage(dp, f_row):
        out, arg = conv(dp, f_row)
        return out, arg

    @jax.jit
    def run(f_mat):
        dp0 = jnp.zeros(f_mat.shape[1], dtype=f_mat.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mat)
        return dp_final, args

    return run(f_mat)


def solve_dense_jax(
    options: Sequence[OptionTable],
    budget: float,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Dense DP via jit'd lax.scan (+ optional Pallas (max,+) kernel)."""
    import numpy as np

    f_mat, choices = dense_curves_matrix(list(options), budget, unit)
    dp_final, args = _jax_dp(f_mat, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    b = int(np.argmax(dp_final))
    total = float(dp_final[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        k = int(args[i, b])  # units granted to receiver i
        j = int(choices[i][k])  # option index realizing F_i(k)
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= k
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def _jax_dp_gather(f_groups, stage_gids, backend: str = "jax"):
    """Repeated-stage forward DP: scan over group ids, gathering each
    stage's curve from the [G, NB] class matrix.  Same convolutions in the
    same order as ``_jax_dp`` on the row-expanded matrix — bitwise equal —
    without materializing [N, NB] curves."""
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.maxplus_scan(f_groups, stage_gids)

    from repro.kernels import ref as kref

    @jax.jit
    def run(f_groups, gids):
        def stage(dp, gid):
            out, arg = kref.maxplus_conv(dp, f_groups[gid])
            return out, arg

        dp0 = jnp.zeros(f_groups.shape[1], dtype=f_groups.dtype)
        return jax.lax.scan(stage, dp0, gids)

    return run(f_groups, jnp.asarray(stage_gids))


def _gather_backtrack(
    layout,
    args: np.ndarray,
    b: int,
    picks: dict[str, tuple[float, float, tuple[float, float]]],
) -> float:
    """Walk a gather scan's argmaxes from ``b`` granted units down to
    per-receiver picks (reverse stage order, the dense solvers' shared
    backtrack); returns the watts actually spent."""
    names, stage_gids, tables, _, ch_groups = layout
    spent = 0.0
    for i in range(len(names) - 1, -1, -1):
        gid = stage_gids[i]
        table = tables[gid]
        k = int(args[i, b])  # units granted to receiver i
        j = int(ch_groups[gid][k])  # option index realizing F(k)
        picks[names[i]] = (
            float(table.costs[j]),
            float(table.values[j]),
            (float(table.caps[j, 0]), float(table.caps[j, 1])),
        )
        spent += float(table.costs[j])
        b -= k
    return spent


def solve_dense_jax_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Grouped dense DP via the repeated-stage gather scan.

    Bitwise identical to ``solve_dense_jax`` on the name-sorted ungrouped
    expansion; curves are densified once per behaviour class and the scan
    gathers its stage row by class id (jax or Pallas (max,+) kernel)."""
    layout = _grouped_dense_layout(groups, budget, unit)
    _, stage_gids, _, f_groups, _ = layout
    dp_final, args = _jax_dp_gather(f_groups, stage_gids, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    b = int(np.argmax(dp_final))
    total = float(dp_final[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    spent = _gather_backtrack(layout, args, b, picks)
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Hierarchical dense-grid solve (domain frontiers on the unit budget grid)
# ---------------------------------------------------------------------------


class _DenseFrontier:
    """Dense analogue of :class:`_SparseFrontier`: ``f[k]`` is the domain's
    best value at spend ``k`` units (length min(cap, budget)//unit + 1 — the
    cap restriction is the truncation).  Leaves keep their grouped dense
    layout for backtracking; internal domains keep per-child conv argmaxes.
    """

    __slots__ = ("dom", "f", "args", "layout", "children")

    def __init__(self, dom, f, args, layout=None, children=None):
        self.dom: DomainGroups = dom
        self.f: np.ndarray = f
        self.args = args
        self.layout = layout
        self.children: list["_DenseFrontier"] | None = children


def _conv_full(dp: np.ndarray, f: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Full (max,+) convolution: out[b] = max_k dp[b-k] + f[k].

    ``f`` may be shorter than ``dp`` (a capped child frontier).  One
    :func:`_stage_maxplus` stage whose "options" are every grid spend,
    b-chunked so the candidate tile stays bounded."""
    return _stage_maxplus(dp, np.arange(len(f)), f, chunk=512)


#: padded-element ceiling for the single-dispatch batched leaf solve
#: (L x N x NB argmax tables); beyond it leaves solve one by one
_BATCH_LEAF_MAX_ELEMS = 150_000_000


@functools.cache
def _ref_scan_batched_fn():
    """Jitted jax-reference batched leaf scan, built once per process —
    re-jitting per call would retrace the whole scan every round."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref as kref

    @jax.jit
    def run(f_banks, gids):
        rows_idx = jnp.arange(f_banks.shape[0])

        def stage(dp, gid_col):
            rows = f_banks[rows_idx, gid_col]
            out, arg = jax.vmap(kref.maxplus_conv)(dp, rows)
            return out, arg

        dp0 = jnp.zeros(
            (f_banks.shape[0], f_banks.shape[2]), dtype=f_banks.dtype
        )
        dp_final, args = jax.lax.scan(stage, dp0, gids.T)
        return dp_final, args.swapaxes(0, 1)

    return run


def _batch_dense_leaves(
    root: DomainGroups, budget: float, unit: float, backend: str
) -> dict[int, tuple]:
    """Single-dispatch batched solve of every non-empty leaf's gather scan.

    Collects each leaf's (groups, eff) pair, densifies every leaf's class
    curves on the *widest* leaf grid, pads class banks with the identity
    curve and stage sequences with the identity class id, and runs one
    ``ops.maxplus_scan_batched`` (or the jax reference equivalent) for all
    leaves.  Per-leaf slices are bitwise what the per-leaf scan returns:
    grid positions past a leaf's own budget never influence positions
    inside it, and identity stages are exact (+0.0) no-ops.  Returns
    {id(dom): (layout, dp_final, args)}; empty when batching is
    inapplicable (single leaf, or padded size beyond the ceiling).
    """
    leaves: list[tuple[DomainGroups, float]] = []

    def walk(dom: DomainGroups, b: float) -> None:
        eff = _domain_eff(dom, b)
        if dom.children:
            for c in dom.children:
                walk(c, eff)
        elif dom.groups:
            leaves.append((dom, eff))

    walk(root, float(budget))
    if len(leaves) < 2:
        return {}
    nbs = [int(np.floor(eff / unit + 1e-9)) + 1 for _, eff in leaves]
    nb_max = max(nbs)
    layouts = [
        _grouped_dense_layout(dom.groups, (nb_max - 1) * unit, unit)
        for dom, _ in leaves
    ]
    g_max = max(lay[3].shape[0] for lay in layouts)
    n_max = max(len(lay[1]) for lay in layouts)
    if len(leaves) * n_max * nb_max > _BATCH_LEAF_MAX_ELEMS:
        return {}
    identity = np.full(nb_max, -np.inf)
    identity[0] = 0.0
    f_banks = np.empty((len(leaves), g_max + 1, nb_max), dtype=np.float64)
    gids_pad = np.empty((len(leaves), n_max), dtype=np.int32)
    for li, lay in enumerate(layouts):
        _, stage_gids, _, f_groups, _ = lay
        g_l, n_l = f_groups.shape[0], len(stage_gids)
        f_banks[li, :g_l] = f_groups
        f_banks[li, g_l:] = identity
        gids_pad[li, :n_l] = stage_gids
        gids_pad[li, n_l:] = g_l  # identity stage: dp + 0.0
    if backend == "pallas":
        from repro.kernels import ops as kops

        dp_all, args_all = kops.maxplus_scan_batched(f_banks, gids_pad)
    else:
        dp_all, args_all = _ref_scan_batched_fn()(f_banks, gids_pad)
    dp_all = np.asarray(dp_all)
    args_all = np.asarray(args_all)
    out: dict[int, tuple] = {}
    for li, ((dom, _), lay, nb) in enumerate(zip(leaves, layouts, nbs)):
        n_l = len(lay[1])
        out[id(dom)] = (lay, dp_all[li, :nb], args_all[li, :n_l, :nb])
    return out


def _dense_frontier(
    dom: DomainGroups,
    budget: float,
    unit: float,
    backend: str,
    batched: dict[int, tuple] | None = None,
) -> _DenseFrontier:
    """Capped dense frontier of one domain on the ``unit``-watt grid.

    A leaf runs the repeated-stage gather scan of its groups (the same
    convolutions as ``solve_dense_jax_grouped``, so a single root with
    cap >= budget is bitwise identical to the flat solve) — or picks up
    its slice of the single-dispatch batched solve when one ran; an
    internal domain convolves its children's truncated frontiers in numpy.
    """
    eff = _domain_eff(dom, budget)
    nb = int(np.floor(eff / unit + 1e-9)) + 1
    if dom.children:
        subs = [
            _dense_frontier(c, eff, unit, backend, batched)
            for c in dom.children
        ]
        dp = np.zeros(nb, dtype=np.float64)
        args: list[np.ndarray] = []
        for sub in subs:
            dp, arg = _conv_full(dp, sub.f)
            args.append(arg)
        return _DenseFrontier(dom, dp, args, children=subs)
    if not dom.groups:
        # no receivers under this leaf: zero spend or nothing
        f = np.full(nb, -np.inf)
        f[0] = 0.0
        return _DenseFrontier(dom, f, None, layout=None)
    hit = batched.get(id(dom)) if batched else None
    if hit is not None:
        layout, dp_final, args_arr = hit
        return _DenseFrontier(dom, dp_final, args_arr, layout=layout)
    layout = _grouped_dense_layout(dom.groups, eff, unit)
    _, stage_gids, _, f_groups, _ = layout
    dp_final, args = _jax_dp_gather(f_groups, stage_gids, backend=backend)
    return _DenseFrontier(
        dom, np.asarray(dp_final), np.asarray(args), layout=layout
    )


def _backtrack_dense(
    fr: _DenseFrontier,
    b: int,
    picks: dict[str, tuple[float, float, tuple[float, float]]],
    domain_spent: dict[str, float],
) -> float:
    """Walk ``b`` granted units down the frontier tree into picks; returns
    the watts actually spent inside this domain."""
    spent = 0.0
    if fr.children is not None:
        for i in range(len(fr.children) - 1, -1, -1):
            k = int(fr.args[i][b])
            spent += _backtrack_dense(fr.children[i], k, picks, domain_spent)
            b -= k
    elif fr.layout is not None:
        spent = _gather_backtrack(fr.layout, fr.args, b, picks)
    domain_spent[fr.dom.name] = spent
    return spent


def _solve_hier_dense(
    root: DomainGroups,
    budget: float,
    *,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Dense-grid hierarchical solve (see :func:`solve_hierarchical`)."""
    batched = _batch_dense_leaves(root, budget, unit, backend)
    fr = _dense_frontier(root, budget, unit, backend, batched)
    b = int(np.argmax(fr.f))
    total = float(fr.f[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    domain_spent: dict[str, float] = {}
    _backtrack_dense(fr, b, picks, domain_spent)
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(
        total_value=total, spent=spent, picks=picks, domain_spent=domain_spent
    )


def _jax_dp_batch(f_mats, backend: str = "jax"):
    """Batched forward DP over R independent rounds.

    f_mats: [R, N, NB].  Returns (dp_final [R, NB], args [R, N, NB]): one
    scan over the N receiver stages where each stage is the *batched*
    (max,+) convolution over all R rounds at once (vmap over the Pallas
    kernel for ``backend='pallas'``).
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv_b = kops.maxplus_conv_batched
    else:
        from repro.kernels import ref as kref

        def conv_b(dp, f):
            return jax.vmap(kref.maxplus_conv)(dp, f)

    def stage(dp, f_rows):  # dp, f_rows: [R, NB]
        out, arg = conv_b(dp, f_rows)
        return out, arg

    @jax.jit
    def run(f_mats):
        r, _, nb = f_mats.shape
        dp0 = jnp.zeros((r, nb), dtype=f_mats.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mats.swapaxes(0, 1))
        return dp_final, args.swapaxes(0, 1)

    return run(f_mats)


def solve_dense_jax_batch(
    rounds: Sequence[Sequence[OptionTable]],
    budgets: Sequence[float],
    unit: float = 1.0,
    backend: str = "jax",
) -> list[MCKPSolution]:
    """Solve R independent dense-DP rounds with one vmapped scan.

    Each round is an (option tables, budget) pair — e.g. the rounds of a
    scenario trace, or one receiver set under a budget sweep.  Curves are
    densified on the widest budget grid; rounds with fewer receivers are
    padded with identity stages (F = [0, -inf, ...], which picks zero
    spend), and each round's argmax is restricted to its own budget range,
    so every solution equals its standalone ``solve_dense_jax`` call.
    """
    if len(rounds) != len(budgets):
        raise ValueError("rounds and budgets must have equal length")
    nbs = [int(np.floor(b / unit + 1e-9)) + 1 for b in budgets]
    nb = max(nbs)
    n_max = max(len(r) for r in rounds)
    f_all = np.empty((len(rounds), n_max, nb), dtype=np.float64)
    ch_all = np.zeros((len(rounds), n_max, nb), dtype=np.int32)
    pad_row = np.full(nb, -np.inf)
    pad_row[0] = 0.0
    for r, opts in enumerate(rounds):
        f, ch = dense_curves_matrix(list(opts), (nb - 1) * unit, unit)
        f_all[r, : len(opts)] = f
        ch_all[r, : len(opts)] = ch
        f_all[r, len(opts) :] = pad_row

    dp_final, args = _jax_dp_batch(f_all, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    sols: list[MCKPSolution] = []
    for r, opts in enumerate(rounds):
        b = int(np.argmax(dp_final[r, : nbs[r]]))
        total = float(dp_final[r, b])
        picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
        for i in range(n_max - 1, -1, -1):
            k = int(args[r, i, b])
            if i < len(opts):
                opt = opts[i]
                j = int(ch_all[r, i][k])
                picks[opt.name] = (
                    float(opt.costs[j]),
                    float(opt.values[j]),
                    (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
                )
            b -= k
        spent = sum(c for c, _, _ in picks.values())
        sols.append(MCKPSolution(total_value=total, spent=spent, picks=picks))
    return sols


# ---------------------------------------------------------------------------
# Exhaustive brute force (Oracle ground truth for small cases)
# ---------------------------------------------------------------------------


def brute_force(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Exhaustive DFS over the cross product of option sets.

    Exponential — used for the §6.3 Oracle on <= ~10 apps with pruned
    option sets, and to certify the DP solvers in tests.  A simple
    optimistic bound (sum of per-app max remaining values) prunes branches.
    """
    n = len(options)
    # optimistic suffix bound
    suffix_max = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        suffix_max[i] = suffix_max[i + 1] + float(np.max(options[i].values))

    best = {"total": -1.0, "choice": [0] * n}
    choice = [0] * n

    def dfs(i: int, used: float, value: float) -> None:
        if value + suffix_max[i] <= best["total"]:
            return
        if i == n:
            if value > best["total"]:
                best["total"] = value
                best["choice"] = list(choice)
            return
        opt = options[i]
        for j in range(opt.k - 1, -1, -1):
            e = float(opt.costs[j])
            if used + e > budget + 1e-9:
                continue
            choice[i] = j
            dfs(i + 1, used + e, value + float(opt.values[j]))
        choice[i] = 0

    dfs(0, 0.0, 0.0)
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i, opt in enumerate(options):
        j = best["choice"][i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=best["total"], spent=spent, picks=picks)
