"""Multiple-choice-knapsack solvers for reclaimed-power distribution (§3.2.2).

Three equivalent solvers (equivalence-tested against each other and against
exhaustive brute force):

 * ``solve_sparse``   — faithful Algorithm 1: dict-keyed sparse DP over the
                        distinct per-app extra-power levels, O(B * Σ K_i).
 * ``solve_dense``    — vectorized numpy DP over dense F_i(b) curves; each
                        stage is a (max,+)-convolution restricted to the K_i
                        option costs, O(B * Σ K_i) with numpy inner loops.
 * ``solve_dense_jax``— the same dense DP as a jit-compiled ``lax.scan``
                        (one stage per receiver), used by the Pallas kernel
                        path (repro.kernels.mckp_dp) and by the scaling
                        benchmarks.

All solvers return allocations in *watts spent per receiver* plus the cap
pair realizing it, and they all respect the monotone-upgrade model: a
receiver may always take the zero-cost baseline option.

**Group-collapsed solving** (DESIGN.md §11): real clusters replicate a small
number of behaviour classes across thousands of nodes, so receivers sharing
one option table collapse into a :class:`GroupedOptions` with multiplicity
``m``:

 * ``solve_sparse_grouped``    — bounded MCKP: each group's m-fold aggregate
                                 curve is built by binary-split (max,+)
                                 self-convolution (O(log m) convolutions),
                                 then one sparse DP runs over the ~G group
                                 super-stages instead of the N receivers.
                                 Bit-for-bit equal to ``solve_sparse`` on
                                 the name-sorted ungrouped expansion.
 * ``solve_dense_jax_grouped`` — repeated-stage scan: the lax.scan walks a
                                 per-receiver group-id sequence and gathers
                                 its stage curve from a [G, NB] matrix, so
                                 curves are densified once per group.
                                 Bitwise identical to ``solve_dense_jax``
                                 (same convolutions, same order).
 * ``solve_dense_grouped``     — the numpy analogue of the gather scan.

**Hierarchical solving** (DESIGN.md §12): facilities cascade caps down a
site → rack/PDU tree, so :func:`solve_hierarchical` turns each domain's
group-collapsed aggregates into a *capped value-vs-spend frontier* and an
upper-level DP convolves sibling frontiers to split every parent budget
subject to each domain's local cap.  A single root domain with cap >= the
cluster budget reproduces the flat grouped solve bit-for-bit.

Determinism contract: receivers with *byte-identical* option tables are
interchangeable, so every optimum is degenerate under permutations of their
picks.  ``solve_sparse`` canonicalizes — identical-table stages exchange
their chosen options so costs ascend in stage order, and ``total_value`` /
``spent`` are re-accumulated in stage order — which is exactly the form the
group-collapsed solver reproduces.  (Parity assumes option costs are well
above the 1e-6 W state-merge tolerance; true for watt-granular cap grids.)
"""

from __future__ import annotations

import dataclasses
import math
from typing import MutableMapping, Sequence

import numpy as np

from repro.core.curves import OptionTable, dense_curve, dense_curves_matrix


@dataclasses.dataclass
class MCKPSolution:
    """Solution of one distribution round."""

    total_value: float  # Σ_i I_i  (N * average improvement)
    spent: float  # watts used out of the budget
    #: per-receiver picks: name -> (cost_watts, value, (c, g))
    picks: dict[str, tuple[float, float, tuple[float, float]]]
    #: hierarchical solves only: domain name -> watts spent inside it
    domain_spent: dict[str, float] | None = None

    def average_improvement(self) -> float:
        n = len(self.picks)
        return self.total_value / n if n else 0.0


# ---------------------------------------------------------------------------
# Faithful Algorithm 1 (sparse dict DP)
# ---------------------------------------------------------------------------


def _qkey(u: float) -> float:
    """State key: costs within 1e-6 W merge into one DP state.

    Defined as floor(u * 1e6 + 0.5) * 1e-6 so the scalar form and the
    vectorized :func:`_qkey_np` are bitwise identical (same float64 ops) —
    the grouped solver's array DP and the ungrouped dict DP must agree on
    every state key.  For grid-exact watt costs the key equals the sum
    itself, so per-step rounding order cannot diverge between the two.
    """
    return math.floor(u * 1e6 + 0.5) * 1e-6


def _qkey_np(u: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_qkey` (bitwise-identical float64 pipeline)."""
    return np.floor(u * 1e6 + 0.5) * 1e-6


def table_digest(opt: OptionTable) -> tuple:
    """Content identity of an option table (costs, values, caps bytes).

    Receivers whose tables digest equally are *interchangeable* in any MCKP
    — permuting their picks preserves value and feasibility.  This is the
    group key of the collapsed solvers, and the equivalence class within
    which ``solve_sparse`` canonicalizes its assignment.  Note a
    multiplicatively-slowed straggler digests equally to its healthy peers:
    relative improvements are invariant under constant slowdown.
    """
    return (opt.costs.tobytes(), opt.values.tobytes(), opt.caps.tobytes())


def _canonical_solution(
    options: Sequence[OptionTable], js: list[int]
) -> MCKPSolution:
    """Assemble a solution from per-stage option choices in canonical form.

    Identical-table stages (same :func:`table_digest`) exchange their
    chosen options so option indices ascend in stage order, and
    ``total_value`` / ``spent`` are accumulated stage by stage — the one
    deterministic representative of the optimum's permutation class, and
    exactly what :func:`solve_sparse_grouped` reconstructs.
    """
    by_digest: dict[tuple, list[int]] = {}
    for i, opt in enumerate(options):
        by_digest.setdefault(table_digest(opt), []).append(i)
    for idxs in by_digest.values():
        if len(idxs) > 1:
            for i, j in zip(idxs, sorted(js[i] for i in idxs)):
                js[i] = j
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    total = 0.0
    spent = 0.0
    for i, opt in enumerate(options):
        j = js[i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        total += float(opt.values[j])
        spent += float(opt.costs[j])
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def solve_sparse(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Paper Algorithm 1 with parent-pointer backtracking.

    States are keyed by *used power* (floats straight from the option
    tables — no budget discretization), exactly like the pseudo-code's
    ``DP`` dict.  Costs within 1e-6 W are merged to keep the state count
    equal to the number of distinct achievable sums.  The returned solution
    is canonicalized (see :func:`_canonical_solution`) so interchangeable
    receivers always get their picks in ascending-cost stage order.
    """
    qkey = _qkey
    # DP: used -> (score, parent_used, option_index)
    dp: dict[float, tuple[float, float, int]] = {0.0: (0.0, -1.0, -1)}
    stages: list[dict[float, tuple[float, float, int]]] = []
    for opt in options:
        ndp: dict[float, tuple[float, float, int]] = {}
        for u, (score, _, _) in dp.items():
            for j in range(opt.k):
                e = float(opt.costs[j])
                if u + e > budget + 1e-9:
                    continue
                key = qkey(u + e)
                s = score + float(opt.values[j])
                cur = ndp.get(key)
                if cur is None or s > cur[0]:
                    ndp[key] = (s, u, j)
        stages.append(ndp)
        dp = ndp

    # best end state, then walk parents backwards
    best_u = max(dp, key=lambda u: dp[u][0])
    js: list[int] = [0] * len(options)
    u = best_u
    for i in range(len(options) - 1, -1, -1):
        _, parent, j = stages[i][qkey(u)]
        js[i] = j
        u = parent
    return _canonical_solution(options, js)


# ---------------------------------------------------------------------------
# Group-collapsed sparse DP (bounded MCKP via binary-split multiplicity)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupedOptions:
    """One behaviour class: a shared option table with its member receivers.

    All members share the table (same surface identity, baseline and
    slowdown class), so the group acts as a bounded multiple-choice item
    with multiplicity ``m = len(members)``.
    """

    table: OptionTable
    members: tuple[str, ...]

    @property
    def m(self) -> int:
        return len(self.members)


def expand_groups(groups: Sequence[GroupedOptions]) -> list[OptionTable]:
    """Ungrouped, name-sorted expansion (the parity reference ordering)."""
    out = [
        dataclasses.replace(g.table, name=name)
        for g in groups
        for name in g.members
    ]
    out.sort(key=lambda o: o.name)
    return out


def collapse_receivers(
    names: Sequence[str],
    surfaces: Sequence,
    baselines: Sequence[tuple[float, float]],
    build_table,
) -> list[GroupedOptions]:
    """Collapse aligned receiver columns into behaviour-class groups.

    Receivers sharing (surface identity, baseline) form one class;
    ``build_table(surface, baseline)`` is called once per class (a warm
    cache lookup on the controller path, a fresh ``curves.build_options``
    on the pure-policy path).
    """
    classes: dict[tuple, list] = {}
    for name, surf, base in zip(names, surfaces, baselines):
        key = (id(surf), base[0], base[1])
        slot = classes.get(key)
        if slot is None:
            classes[key] = [surf, (float(base[0]), float(base[1])), [name]]
        else:
            slot[2].append(name)
    return [
        GroupedOptions(
            table=build_table(surf, base), members=tuple(sorted(members))
        )
        for surf, base, members in classes.values()
    ]


def solve_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    solver: str = "sparse",
    unit: float = 1.0,
    curve_cache: MutableMapping | None = None,
) -> MCKPSolution:
    """Solver dispatch for the group-collapsed paths (see ``solve_*_grouped``)."""
    if solver == "sparse":
        return solve_sparse_grouped(groups, budget, curve_cache=curve_cache)
    if solver == "dense":
        return solve_dense_grouped(groups, budget, unit=unit)
    if solver in ("jax", "pallas"):
        return solve_dense_jax_grouped(groups, budget, unit=unit, backend=solver)
    raise ValueError(f"unknown solver {solver!r}")


def _dedupe_first_max(
    keys: np.ndarray, vals: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per distinct key keep the max value — first occurrence on ties.

    Mirrors the dict DP's ``cur is None or s > cur[0]`` update over the
    candidates in array order.  Returns (sorted unique keys, selector into
    the input arrays).
    """
    order = np.lexsort((np.arange(len(keys)), -vals, keys))
    k_sorted = keys[order]
    first = np.ones(len(order), dtype=bool)
    first[1:] = k_sorted[1:] != k_sorted[:-1]
    sel = order[first]
    return keys[sel], sel


class _AggCurve:
    """Sparse aggregate curve of ``t`` copies of one option table.

    Columns over the curve's states (ascending spend key): ``keys`` are
    quantized spends, ``vals`` the best achievable value at each.  For a
    leaf curve (t == 1) ``back`` holds option indices; for a combined curve
    ``back_left`` / ``back_right`` hold the (left, right) spend split, so
    :meth:`unwind` can walk the binary-split tree back down to the multiset
    of single-receiver picks.  All convolutions are vectorized outer
    (max,+) products deduped by :func:`_dedupe_first_max` — the same
    candidate order and tie-breaking as the scalar dict DP.
    """

    __slots__ = ("keys", "vals", "back", "back_left", "back_right", "left", "right")

    def __init__(self, keys, vals, back=None, back_left=None, back_right=None,
                 left=None, right=None):
        self.keys: np.ndarray = keys
        self.vals: np.ndarray = vals
        self.back = back
        self.back_left = back_left
        self.back_right = back_right
        self.left: _AggCurve | None = left
        self.right: _AggCurve | None = right

    @staticmethod
    def leaf(table: OptionTable, budget: float) -> "_AggCurve":
        feas = np.flatnonzero(table.costs <= budget + 1e-9)
        keys = _qkey_np(table.costs[feas])
        _, sel = _dedupe_first_max(keys, table.values[feas])
        return _AggCurve(
            keys=keys[sel], vals=table.values[feas][sel], back=feas[sel]
        )

    @staticmethod
    def combine(a: "_AggCurve", b: "_AggCurve", budget: float) -> "_AggCurve":
        raw = (a.keys[:, None] + b.keys[None, :]).ravel()
        vals = (a.vals[:, None] + b.vals[None, :]).ravel()
        feas = np.flatnonzero(raw <= budget + 1e-9)
        keys, sel = _dedupe_first_max(_qkey_np(raw[feas]), vals[feas])
        sel = feas[sel]
        nb = len(b.keys)
        return _AggCurve(
            keys=keys,
            vals=vals[sel],
            back_left=a.keys[sel // nb],
            back_right=b.keys[sel % nb],
            left=a,
            right=b,
        )

    def _at(self, spend: float) -> int:
        i = int(np.searchsorted(self.keys, spend))
        if i >= len(self.keys) or self.keys[i] != spend:
            raise KeyError(f"aggregate curve has no state at {spend!r}")
        return i

    def unwind(self, spend: float, out: list[int]) -> None:
        """Collect the option-index multiset realizing ``spend``."""
        i = self._at(spend)
        if self.left is None:
            out.append(int(self.back[i]))
        else:
            self.left.unwind(float(self.back_left[i]), out)
            self.right.unwind(float(self.back_right[i]), out)


def aggregate_curve(table: OptionTable, m: int, budget: float) -> _AggCurve:
    """m-fold (max,+) self-convolution of a table's sparse staircase.

    Binary split: O(log m) pairwise convolutions build the doubling chain
    P_1, P_2, P_4, ... and the set bits of ``m`` combine into the final
    curve.  State count stays bounded by the distinct achievable sums
    <= budget, so each convolution is one small vectorized outer product.
    """
    base = _AggCurve.leaf(table, budget)
    acc: _AggCurve | None = None
    power = base
    bit = m
    while bit:
        if bit & 1:
            acc = power if acc is None else _AggCurve.combine(acc, power, budget)
        bit >>= 1
        if bit:
            power = _AggCurve.combine(power, power, budget)
    assert acc is not None
    return acc


def _merge_classes(groups: Sequence[GroupedOptions]) -> list[list]:
    """Merge interchangeable groups (equal table content) into classes.

    Returns ``[table, members, digest]`` triples sorted by min member name —
    the deterministic class order every grouped/hierarchical solver shares.
    """
    merged: dict[tuple, list] = {}
    for g in groups:
        d = table_digest(g.table)
        slot = merged.get(d)
        if slot is None:
            merged[d] = [g.table, list(g.members), d]
        else:
            slot[1].extend(g.members)
    return sorted(merged.values(), key=lambda s: min(s[1]))


def _class_curves(
    classes: Sequence[list],
    budget: float,
    curve_cache: MutableMapping | None,
) -> list[_AggCurve]:
    """m-fold aggregate curve per class, memoized by (digest, m, budget)."""
    curves_: list[_AggCurve] = []
    for table, members, d in classes:
        key = (d, len(members), _qkey(budget))
        curve = curve_cache.get(key) if curve_cache is not None else None
        if curve is None:
            curve = aggregate_curve(table, len(members), budget)
            if curve_cache is not None:
                curve_cache[key] = curve  # type: ignore[index]
        curves_.append(curve)
    return curves_


def _superstage_dp(
    stage_curves: Sequence[tuple[np.ndarray, np.ndarray]], budget: float
) -> tuple[np.ndarray, np.ndarray, list]:
    """Sparse DP over (keys, vals) super-stages under ``budget``.

    Each stage is one vectorized outer (max,+) product over
    [states x stage spends].  Stages may be class aggregate curves (grouped
    solve) or whole domain frontiers (hierarchical solve).  Returns the
    final ``(dp_keys, dp_vals, stages)`` where each backtracking stage is a
    (keys, parent spend, stage spend) triple.
    """
    dp_keys = np.zeros(1, dtype=np.float64)
    dp_vals = np.zeros(1, dtype=np.float64)
    stages: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for c_keys, c_vals in stage_curves:
        raw = (dp_keys[:, None] + c_keys[None, :]).ravel()
        scores = (dp_vals[:, None] + c_vals[None, :]).ravel()
        feas = np.flatnonzero(raw <= budget + 1e-9)
        keys, sel = _dedupe_first_max(_qkey_np(raw[feas]), scores[feas])
        sel = feas[sel]
        # keys come back ascending from the stable lexsort dedupe, so the
        # stage arrays are searchsorted-ready as-is
        nc = len(c_keys)
        stages.append((keys, dp_keys[sel // nc], c_keys[sel % nc]))
        dp_keys = keys
        dp_vals = scores[sel]
    return dp_keys, dp_vals, stages


def _backtrack_superstages(stages: Sequence[tuple], u: float) -> list[float]:
    """Walk the super-stage DP backwards from end state ``u``: the per-stage
    spends realizing it (stage order)."""
    spends: list[float] = [0.0] * len(stages)
    for i in range(len(stages) - 1, -1, -1):
        keys, parents, spends_stage = stages[i]
        pos = int(np.searchsorted(keys, u))
        spends[i] = float(spends_stage[pos])
        u = float(parents[pos])
    return spends


def _unwind_classes(
    classes: Sequence[list],
    curves_: Sequence[_AggCurve],
    spends: Sequence[float],
    choice_of: dict[str, tuple[OptionTable, int]],
) -> None:
    """Unwind each class spend to its option multiset; ascending picks over
    name-sorted members == solve_sparse's canonical assignment."""
    for (table, members, _), curve, spend in zip(classes, curves_, spends):
        js: list[int] = []
        curve.unwind(spend, js)
        for name, j in zip(sorted(members), sorted(js)):
            choice_of[name] = (table, j)


def _assemble_choices(
    choice_of: dict[str, tuple[OptionTable, int]],
) -> MCKPSolution:
    """Canonical stage-order accumulation (bit-for-bit the ungrouped form)."""
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    total = 0.0
    spent = 0.0
    for name in sorted(choice_of):
        table, j = choice_of[name]
        picks[name] = (
            float(table.costs[j]),
            float(table.values[j]),
            (float(table.caps[j, 0]), float(table.caps[j, 1])),
        )
        total += float(table.values[j])
        spent += float(table.costs[j])
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def solve_sparse_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    *,
    curve_cache: MutableMapping | None = None,
) -> MCKPSolution:
    """Group-collapsed Algorithm 1: one DP super-stage per behaviour class.

    Equivalent to — and bit-for-bit equal with — ``solve_sparse`` on the
    name-sorted ungrouped expansion: groups digesting equally merge first
    (their members are interchangeable), each merged group contributes its
    m-fold aggregate curve as a single DP stage, and the backtracked
    per-group spends unwind into option multisets assigned to name-sorted
    members in ascending-cost order (the sparse solver's canonical form).

    ``curve_cache`` (a mutable mapping, e.g. a controller's warm dict)
    memoizes aggregate curves keyed by (digest, m, quantized budget).
    """
    classes = _merge_classes(groups)
    curves_ = _class_curves(classes, budget, curve_cache)
    dp_keys, dp_vals, stages = _superstage_dp(
        [(c.keys, c.vals) for c in curves_], budget
    )
    u = float(dp_keys[int(np.argmax(dp_vals))])
    spends = _backtrack_superstages(stages, u)
    choice_of: dict[str, tuple[OptionTable, int]] = {}
    _unwind_classes(classes, curves_, spends, choice_of)
    return _assemble_choices(choice_of)


# ---------------------------------------------------------------------------
# Hierarchical (two-level) solve over a power-domain tree (DESIGN.md §12)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DomainGroups:
    """One power domain's slice of an allocation round.

    ``cap`` is the domain's *extra-power headroom* in watts — its physical
    cap net of the draw already committed under it (baselines of member
    receivers, natural draw of member donors; the engine does that
    accounting).  A leaf carries the behaviour-class ``groups`` of its
    member receivers (possibly empty); an internal domain carries
    ``children``.
    """

    name: str
    cap: float
    groups: tuple[GroupedOptions, ...] = ()
    children: tuple["DomainGroups", ...] = ()

    def __post_init__(self):
        if self.groups and self.children:
            raise ValueError(
                f"domain {self.name!r}: groups and children are exclusive"
            )


class _SparseFrontier:
    """A domain's value-vs-spend frontier with backtracking state.

    ``keys``/``vals`` are the capped frontier (ascending quantized spends,
    best value at each — exactly a super-stage DP's final state).  Leaves
    keep their classes/curves for unwinding; internal domains keep child
    frontiers.  ``stages`` backtracks the domain's own DP.
    """

    __slots__ = ("dom", "keys", "vals", "stages", "classes", "curves", "children")

    def __init__(self, dom, keys, vals, stages, classes=None, curves=None,
                 children=None):
        self.dom: DomainGroups = dom
        self.keys: np.ndarray = keys
        self.vals: np.ndarray = vals
        self.stages: list = stages
        self.classes = classes
        self.curves = curves
        self.children: list["_SparseFrontier"] | None = children


def _sparse_frontier(
    dom: DomainGroups,
    budget: float,
    curve_cache: MutableMapping | None,
    frontier_cache: MutableMapping | None,
) -> _SparseFrontier:
    """Capped frontier of one domain: its best-value-per-spend staircase,
    restricted to spends <= min(domain cap, parent budget).

    A leaf's frontier is the class super-stage DP of its groups — the same
    arrays ``solve_sparse_grouped`` ends on, so a single root domain with
    cap >= budget reproduces the flat grouped solve bit-for-bit.  An
    internal domain convolves its children's frontiers under its own cap
    (the "upper-level DP").  ``frontier_cache`` memoizes leaf DPs by
    (per-class digest+multiplicity layout, quantized budget) — the
    hierarchical analogue of the aggregate-curve cache.
    """
    eff = min(float(dom.cap), float(budget))
    if eff < 0.0:
        eff = 0.0
    if dom.children:
        subs = [
            _sparse_frontier(c, eff, curve_cache, frontier_cache)
            for c in dom.children
        ]
        dp_keys, dp_vals, stages = _superstage_dp(
            [(f.keys, f.vals) for f in subs], eff
        )
        return _SparseFrontier(dom, dp_keys, dp_vals, stages, children=subs)
    classes = _merge_classes(dom.groups)
    key = (
        tuple((d, len(members)) for _, members, d in classes),
        _qkey(eff),
    )
    hit = frontier_cache.get(key) if frontier_cache is not None else None
    if hit is None:
        curves_ = _class_curves(classes, eff, curve_cache)
        dp_keys, dp_vals, stages = _superstage_dp(
            [(c.keys, c.vals) for c in curves_], eff
        )
        hit = (curves_, dp_keys, dp_vals, stages)
        if frontier_cache is not None:
            frontier_cache[key] = hit  # type: ignore[index]
    curves_, dp_keys, dp_vals, stages = hit
    return _SparseFrontier(
        dom, dp_keys, dp_vals, stages, classes=classes, curves=curves_
    )


def _backtrack_frontier(
    f: _SparseFrontier,
    u: float,
    choice_of: dict[str, tuple[OptionTable, int]],
    domain_spent: dict[str, float],
) -> None:
    """Walk a chosen spend ``u`` down the frontier tree to receiver picks."""
    domain_spent[f.dom.name] = u
    spends = _backtrack_superstages(f.stages, u)
    if f.children is not None:
        for child, s in zip(f.children, spends):
            _backtrack_frontier(child, s, choice_of, domain_spent)
    else:
        _unwind_classes(f.classes, f.curves, spends, choice_of)


def solve_hierarchical(
    root: DomainGroups,
    budget: float,
    *,
    solver: str = "sparse",
    unit: float = 1.0,
    curve_cache: MutableMapping | None = None,
    frontier_cache: MutableMapping | None = None,
) -> MCKPSolution:
    """Two-level topology-aware MCKP over a power-domain tree.

    Per-domain group-collapsed aggregate tables become capped value-vs-spend
    frontiers; an upper-level DP convolves sibling frontiers to split each
    parent's budget subject to every domain's local cap, then backtracks
    down to the per-receiver picks.  Every domain's spend is <= its cap by
    construction, and with a single root domain whose cap >= the cluster
    budget the result is **bit-for-bit** ``solve_sparse_grouped``
    (``solver='sparse'``) / ``solve_dense_jax_grouped`` (``solver='jax'`` /
    ``'pallas'``) — certified by tests/test_hier_alloc.py.

    Returns a solution whose ``domain_spent`` maps each domain name to the
    watts spent inside it.
    """
    if solver == "sparse":
        f = _sparse_frontier(root, float(budget), curve_cache, frontier_cache)
        u = float(f.keys[int(np.argmax(f.vals))])
        choice_of: dict[str, tuple[OptionTable, int]] = {}
        domain_spent: dict[str, float] = {}
        _backtrack_frontier(f, u, choice_of, domain_spent)
        sol = _assemble_choices(choice_of)
        sol.domain_spent = domain_spent
        return sol
    if solver in ("jax", "pallas"):
        return _solve_hier_dense(root, float(budget), unit=unit, backend=solver)
    raise ValueError(f"unknown hierarchical solver {solver!r}")


# ---------------------------------------------------------------------------
# Dense-grid DP (numpy)
# ---------------------------------------------------------------------------


def _stage_maxplus(
    dp: np.ndarray,
    costs_u: np.ndarray,
    values: np.ndarray,
    chunk: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """One (max,+) stage restricted to option costs.

    dp' [b] = max_j dp[b - cost_j] + value_j   (invalid b-cost_j masked)
    Returns (dp', argmax_j) with first-max tie-breaking.  ``chunk`` bounds
    the [k, chunk] candidate tile for stages with many costs (the full
    (max,+) convolution of the hierarchical dense path, where costs_u is
    the whole budget grid); columns are independent, so chunking is
    bitwise-neutral.
    """
    nb = dp.shape[0]
    if chunk is None:
        chunk = nb
    out = np.empty(nb, dtype=np.float64)
    arg = np.empty(nb, dtype=np.int32)
    for b0 in range(0, nb, chunk):
        b = np.arange(b0, min(b0 + chunk, nb))
        # cand[j, b] = dp[b - c_j] + v_j
        idx = b[None, :] - costs_u[:, None]  # [k, chunk]
        valid = idx >= 0
        cand = (
            np.where(valid, dp[np.clip(idx, 0, nb - 1)], -np.inf)
            + values[:, None]
        )
        a = np.argmax(cand, axis=0)
        out[b] = cand[a, np.arange(len(b))]
        arg[b] = a
    return out, arg


def solve_dense(
    options: Sequence[OptionTable], budget: float, unit: float = 1.0
) -> MCKPSolution:
    """Vectorized dense DP at ``unit``-watt budget granularity."""
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    dp = np.zeros(nb, dtype=np.float64)
    args: list[np.ndarray] = []
    costs_per_app: list[np.ndarray] = []
    kept_per_app: list[np.ndarray] = []
    for opt in options:
        cu = np.ceil(opt.costs / unit - 1e-9).astype(np.int64)
        keep = cu < nb
        cu, vals = cu[keep], opt.values[keep]
        dp, arg = _stage_maxplus(dp, cu, vals)
        args.append(arg)
        costs_per_app.append(cu)
        kept_per_app.append(np.nonzero(keep)[0])

    b = int(np.argmax(dp))
    total = float(dp[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        j_local = int(args[i][b])
        j = int(kept_per_app[i][j_local])
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= int(costs_per_app[i][j_local])
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def _grouped_dense_layout(
    groups: Sequence[GroupedOptions], budget: float, unit: float
):
    """Digest-merged stage layout shared by the grouped dense solvers.

    Returns ``(names, stage_gids, tables, f_groups, ch_groups)``: the
    name-sorted receiver order, each receiver's behaviour-class id, and the
    per-class tables / dense curves — densified once per class instead of
    once per receiver.
    """
    classes = _merge_classes(groups)
    pairs = sorted(
        (name, cid)
        for cid, (_, members, _) in enumerate(classes)
        for name in members
    )
    names = [p[0] for p in pairs]
    stage_gids = np.array([p[1] for p in pairs], dtype=np.int32)
    tables = [c[0] for c in classes]
    fs, chs = [], []
    for table in tables:
        f, ch = dense_curve(table, budget, unit)
        fs.append(f)
        chs.append(ch)
    return names, stage_gids, tables, np.stack(fs), np.stack(chs)


def solve_dense_grouped(
    groups: Sequence[GroupedOptions], budget: float, unit: float = 1.0
) -> MCKPSolution:
    """Grouped numpy dense DP: per-class cost/value prep, one stage per
    receiver — bitwise identical to ``solve_dense`` on the name-sorted
    ungrouped expansion (same stage convolutions in the same order)."""
    nb = int(np.floor(budget / unit + 1e-9)) + 1
    names, stage_gids, tables, _, _ = _grouped_dense_layout(
        groups, budget, unit
    )
    cu_of, vals_of, kept_of = [], [], []
    for table in tables:
        cu = np.ceil(table.costs / unit - 1e-9).astype(np.int64)
        keep = cu < nb
        cu_of.append(cu[keep])
        vals_of.append(table.values[keep])
        kept_of.append(np.nonzero(keep)[0])

    dp = np.zeros(nb, dtype=np.float64)
    args: list[np.ndarray] = []
    for gid in stage_gids:
        dp, arg = _stage_maxplus(dp, cu_of[gid], vals_of[gid])
        args.append(arg)

    b = int(np.argmax(dp))
    total = float(dp[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(names) - 1, -1, -1):
        gid = stage_gids[i]
        table = tables[gid]
        j_local = int(args[i][b])
        j = int(kept_of[gid][j_local])
        picks[names[i]] = (
            float(table.costs[j]),
            float(table.values[j]),
            (float(table.caps[j, 0]), float(table.caps[j, 1])),
        )
        b -= int(cu_of[gid][j_local])
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Dense-grid DP (JAX, scan over receivers)
# ---------------------------------------------------------------------------


def _jax_dp(f_mat, backend: str = "jax"):
    """jit-compiled forward DP over dense curves.

    f_mat: [N, NB] monotone curves (F_i). Returns (dp_final [NB],
    argk [N, NB]) where argk[i, b] is the spend chosen for receiver i when b
    units are available to receivers 0..i.

    The inner maximization DP'[b] = max_k DP[b-k] + F[k] is a full
    (max,+)-convolution; ``backend='pallas'`` routes it through the Pallas
    TPU kernel (repro.kernels.mckp_dp), 'jax' uses a pure-jnp masked gather.
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv = kops.maxplus_conv
    else:
        from repro.kernels import ref as kref

        conv = kref.maxplus_conv

    def stage(dp, f_row):
        out, arg = conv(dp, f_row)
        return out, arg

    @jax.jit
    def run(f_mat):
        dp0 = jnp.zeros(f_mat.shape[1], dtype=f_mat.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mat)
        return dp_final, args

    return run(f_mat)


def solve_dense_jax(
    options: Sequence[OptionTable],
    budget: float,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Dense DP via jit'd lax.scan (+ optional Pallas (max,+) kernel)."""
    import numpy as np

    f_mat, choices = dense_curves_matrix(list(options), budget, unit)
    dp_final, args = _jax_dp(f_mat, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    b = int(np.argmax(dp_final))
    total = float(dp_final[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i in range(len(options) - 1, -1, -1):
        opt = options[i]
        k = int(args[i, b])  # units granted to receiver i
        j = int(choices[i][k])  # option index realizing F_i(k)
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
        b -= k
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


def _jax_dp_gather(f_groups, stage_gids, backend: str = "jax"):
    """Repeated-stage forward DP: scan over group ids, gathering each
    stage's curve from the [G, NB] class matrix.  Same convolutions in the
    same order as ``_jax_dp`` on the row-expanded matrix — bitwise equal —
    without materializing [N, NB] curves."""
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        return kops.maxplus_scan(f_groups, stage_gids)

    from repro.kernels import ref as kref

    @jax.jit
    def run(f_groups, gids):
        def stage(dp, gid):
            out, arg = kref.maxplus_conv(dp, f_groups[gid])
            return out, arg

        dp0 = jnp.zeros(f_groups.shape[1], dtype=f_groups.dtype)
        return jax.lax.scan(stage, dp0, gids)

    return run(f_groups, jnp.asarray(stage_gids))


def _gather_backtrack(
    layout,
    args: np.ndarray,
    b: int,
    picks: dict[str, tuple[float, float, tuple[float, float]]],
) -> float:
    """Walk a gather scan's argmaxes from ``b`` granted units down to
    per-receiver picks (reverse stage order, the dense solvers' shared
    backtrack); returns the watts actually spent."""
    names, stage_gids, tables, _, ch_groups = layout
    spent = 0.0
    for i in range(len(names) - 1, -1, -1):
        gid = stage_gids[i]
        table = tables[gid]
        k = int(args[i, b])  # units granted to receiver i
        j = int(ch_groups[gid][k])  # option index realizing F(k)
        picks[names[i]] = (
            float(table.costs[j]),
            float(table.values[j]),
            (float(table.caps[j, 0]), float(table.caps[j, 1])),
        )
        spent += float(table.costs[j])
        b -= k
    return spent


def solve_dense_jax_grouped(
    groups: Sequence[GroupedOptions],
    budget: float,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Grouped dense DP via the repeated-stage gather scan.

    Bitwise identical to ``solve_dense_jax`` on the name-sorted ungrouped
    expansion; curves are densified once per behaviour class and the scan
    gathers its stage row by class id (jax or Pallas (max,+) kernel)."""
    layout = _grouped_dense_layout(groups, budget, unit)
    _, stage_gids, _, f_groups, _ = layout
    dp_final, args = _jax_dp_gather(f_groups, stage_gids, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    b = int(np.argmax(dp_final))
    total = float(dp_final[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    spent = _gather_backtrack(layout, args, b, picks)
    return MCKPSolution(total_value=total, spent=spent, picks=picks)


# ---------------------------------------------------------------------------
# Hierarchical dense-grid solve (domain frontiers on the unit budget grid)
# ---------------------------------------------------------------------------


class _DenseFrontier:
    """Dense analogue of :class:`_SparseFrontier`: ``f[k]`` is the domain's
    best value at spend ``k`` units (length min(cap, budget)//unit + 1 — the
    cap restriction is the truncation).  Leaves keep their grouped dense
    layout for backtracking; internal domains keep per-child conv argmaxes.
    """

    __slots__ = ("dom", "f", "args", "layout", "children")

    def __init__(self, dom, f, args, layout=None, children=None):
        self.dom: DomainGroups = dom
        self.f: np.ndarray = f
        self.args = args
        self.layout = layout
        self.children: list["_DenseFrontier"] | None = children


def _conv_full(dp: np.ndarray, f: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Full (max,+) convolution: out[b] = max_k dp[b-k] + f[k].

    ``f`` may be shorter than ``dp`` (a capped child frontier).  One
    :func:`_stage_maxplus` stage whose "options" are every grid spend,
    b-chunked so the candidate tile stays bounded."""
    return _stage_maxplus(dp, np.arange(len(f)), f, chunk=512)


def _dense_frontier(
    dom: DomainGroups, budget: float, unit: float, backend: str
) -> _DenseFrontier:
    """Capped dense frontier of one domain on the ``unit``-watt grid.

    A leaf runs the repeated-stage gather scan of its groups (the same
    convolutions as ``solve_dense_jax_grouped``, so a single root with
    cap >= budget is bitwise identical to the flat solve); an internal
    domain convolves its children's truncated frontiers in numpy.
    """
    eff = min(float(dom.cap), float(budget))
    if eff < 0.0:
        eff = 0.0
    nb = int(np.floor(eff / unit + 1e-9)) + 1
    if dom.children:
        subs = [_dense_frontier(c, eff, unit, backend) for c in dom.children]
        dp = np.zeros(nb, dtype=np.float64)
        args: list[np.ndarray] = []
        for sub in subs:
            dp, arg = _conv_full(dp, sub.f)
            args.append(arg)
        return _DenseFrontier(dom, dp, args, children=subs)
    if not dom.groups:
        # no receivers under this leaf: zero spend or nothing
        f = np.full(nb, -np.inf)
        f[0] = 0.0
        return _DenseFrontier(dom, f, None, layout=None)
    layout = _grouped_dense_layout(dom.groups, eff, unit)
    _, stage_gids, _, f_groups, _ = layout
    dp_final, args = _jax_dp_gather(f_groups, stage_gids, backend=backend)
    return _DenseFrontier(
        dom, np.asarray(dp_final), np.asarray(args), layout=layout
    )


def _backtrack_dense(
    fr: _DenseFrontier,
    b: int,
    picks: dict[str, tuple[float, float, tuple[float, float]]],
    domain_spent: dict[str, float],
) -> float:
    """Walk ``b`` granted units down the frontier tree into picks; returns
    the watts actually spent inside this domain."""
    spent = 0.0
    if fr.children is not None:
        for i in range(len(fr.children) - 1, -1, -1):
            k = int(fr.args[i][b])
            spent += _backtrack_dense(fr.children[i], k, picks, domain_spent)
            b -= k
    elif fr.layout is not None:
        spent = _gather_backtrack(fr.layout, fr.args, b, picks)
    domain_spent[fr.dom.name] = spent
    return spent


def _solve_hier_dense(
    root: DomainGroups,
    budget: float,
    *,
    unit: float = 1.0,
    backend: str = "jax",
) -> MCKPSolution:
    """Dense-grid hierarchical solve (see :func:`solve_hierarchical`)."""
    fr = _dense_frontier(root, budget, unit, backend)
    b = int(np.argmax(fr.f))
    total = float(fr.f[b])
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    domain_spent: dict[str, float] = {}
    _backtrack_dense(fr, b, picks, domain_spent)
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(
        total_value=total, spent=spent, picks=picks, domain_spent=domain_spent
    )


def _jax_dp_batch(f_mats, backend: str = "jax"):
    """Batched forward DP over R independent rounds.

    f_mats: [R, N, NB].  Returns (dp_final [R, NB], args [R, N, NB]): one
    scan over the N receiver stages where each stage is the *batched*
    (max,+) convolution over all R rounds at once (vmap over the Pallas
    kernel for ``backend='pallas'``).
    """
    import jax
    import jax.numpy as jnp

    if backend == "pallas":
        from repro.kernels import ops as kops

        conv_b = kops.maxplus_conv_batched
    else:
        from repro.kernels import ref as kref

        def conv_b(dp, f):
            return jax.vmap(kref.maxplus_conv)(dp, f)

    def stage(dp, f_rows):  # dp, f_rows: [R, NB]
        out, arg = conv_b(dp, f_rows)
        return out, arg

    @jax.jit
    def run(f_mats):
        r, _, nb = f_mats.shape
        dp0 = jnp.zeros((r, nb), dtype=f_mats.dtype)
        dp_final, args = jax.lax.scan(stage, dp0, f_mats.swapaxes(0, 1))
        return dp_final, args.swapaxes(0, 1)

    return run(f_mats)


def solve_dense_jax_batch(
    rounds: Sequence[Sequence[OptionTable]],
    budgets: Sequence[float],
    unit: float = 1.0,
    backend: str = "jax",
) -> list[MCKPSolution]:
    """Solve R independent dense-DP rounds with one vmapped scan.

    Each round is an (option tables, budget) pair — e.g. the rounds of a
    scenario trace, or one receiver set under a budget sweep.  Curves are
    densified on the widest budget grid; rounds with fewer receivers are
    padded with identity stages (F = [0, -inf, ...], which picks zero
    spend), and each round's argmax is restricted to its own budget range,
    so every solution equals its standalone ``solve_dense_jax`` call.
    """
    if len(rounds) != len(budgets):
        raise ValueError("rounds and budgets must have equal length")
    nbs = [int(np.floor(b / unit + 1e-9)) + 1 for b in budgets]
    nb = max(nbs)
    n_max = max(len(r) for r in rounds)
    f_all = np.empty((len(rounds), n_max, nb), dtype=np.float64)
    ch_all = np.zeros((len(rounds), n_max, nb), dtype=np.int32)
    pad_row = np.full(nb, -np.inf)
    pad_row[0] = 0.0
    for r, opts in enumerate(rounds):
        f, ch = dense_curves_matrix(list(opts), (nb - 1) * unit, unit)
        f_all[r, : len(opts)] = f
        ch_all[r, : len(opts)] = ch
        f_all[r, len(opts) :] = pad_row

    dp_final, args = _jax_dp_batch(f_all, backend=backend)
    dp_final = np.asarray(dp_final)
    args = np.asarray(args)

    sols: list[MCKPSolution] = []
    for r, opts in enumerate(rounds):
        b = int(np.argmax(dp_final[r, : nbs[r]]))
        total = float(dp_final[r, b])
        picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
        for i in range(n_max - 1, -1, -1):
            k = int(args[r, i, b])
            if i < len(opts):
                opt = opts[i]
                j = int(ch_all[r, i][k])
                picks[opt.name] = (
                    float(opt.costs[j]),
                    float(opt.values[j]),
                    (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
                )
            b -= k
        spent = sum(c for c, _, _ in picks.values())
        sols.append(MCKPSolution(total_value=total, spent=spent, picks=picks))
    return sols


# ---------------------------------------------------------------------------
# Exhaustive brute force (Oracle ground truth for small cases)
# ---------------------------------------------------------------------------


def brute_force(options: Sequence[OptionTable], budget: float) -> MCKPSolution:
    """Exhaustive DFS over the cross product of option sets.

    Exponential — used for the §6.3 Oracle on <= ~10 apps with pruned
    option sets, and to certify the DP solvers in tests.  A simple
    optimistic bound (sum of per-app max remaining values) prunes branches.
    """
    n = len(options)
    # optimistic suffix bound
    suffix_max = np.zeros(n + 1)
    for i in range(n - 1, -1, -1):
        suffix_max[i] = suffix_max[i + 1] + float(np.max(options[i].values))

    best = {"total": -1.0, "choice": [0] * n}
    choice = [0] * n

    def dfs(i: int, used: float, value: float) -> None:
        if value + suffix_max[i] <= best["total"]:
            return
        if i == n:
            if value > best["total"]:
                best["total"] = value
                best["choice"] = list(choice)
            return
        opt = options[i]
        for j in range(opt.k - 1, -1, -1):
            e = float(opt.costs[j])
            if used + e > budget + 1e-9:
                continue
            choice[i] = j
            dfs(i + 1, used + e, value + float(opt.values[j]))
        choice[i] = 0

    dfs(0, 0.0, 0.0)
    picks: dict[str, tuple[float, float, tuple[float, float]]] = {}
    for i, opt in enumerate(options):
        j = best["choice"][i]
        picks[opt.name] = (
            float(opt.costs[j]),
            float(opt.values[j]),
            (float(opt.caps[j, 0]), float(opt.caps[j, 1])),
        )
    spent = sum(c for c, _, _ in picks.values())
    return MCKPSolution(total_value=best["total"], spent=spent, picks=picks)
