"""Evaluation metrics (paper §5.3, §6)."""

from __future__ import annotations

import numpy as np

#: two-sided 98% normal quantile (paper reports 98% confidence intervals)
Z_98 = 2.3263478740408408


def jain_index(x: np.ndarray) -> float:
    """Jain's fairness index (Eq. 3): (Σx)² / (n Σx²), in [1/n, 1].

    Degenerate all-zero improvement vectors return 1.0 (perfectly even).
    """
    x = np.asarray(x, dtype=np.float64)
    n = x.size
    if n == 0:
        return 1.0
    denom = n * float(np.sum(x * x))
    if denom == 0.0:
        return 1.0
    return float(np.sum(x)) ** 2 / denom


def mean_ci98(samples: np.ndarray) -> tuple[float, float, float]:
    """(mean, lo, hi) with a 98% normal-approximation CI over repeats."""
    s = np.asarray(samples, dtype=np.float64)
    m = float(np.mean(s))
    if s.size < 2:
        return m, m, m
    half = Z_98 * float(np.std(s, ddof=1)) / np.sqrt(s.size)
    return m, m - half, m + half


def prediction_accuracy(p_true: np.ndarray, p_pred: np.ndarray) -> np.ndarray:
    """Per-cell accuracy Acc = 1 - |p̂ - p| / p (paper §6.1)."""
    p_true = np.asarray(p_true, dtype=np.float64)
    p_pred = np.asarray(p_pred, dtype=np.float64)
    return 1.0 - np.abs(p_pred - p_true) / np.maximum(np.abs(p_true), 1e-12)


def gap_cdf(gaps_pp: np.ndarray, points: np.ndarray | None = None):
    """CDF of oracle gaps in percentage points (Fig. 10).

    Returns (sorted_gaps, cdf_values) plus summary dict with the paper's
    reported statistics: median, mean, p90, frac within 1/2/3 pp.
    """
    g = np.sort(np.asarray(gaps_pp, dtype=np.float64))
    cdf = np.arange(1, g.size + 1) / g.size
    summary = {
        "median": float(np.median(g)),
        "mean": float(np.mean(g)),
        "p90": float(np.quantile(g, 0.90)),
        "frac_within_1pp": float(np.mean(g <= 1.0)),
        "frac_within_2pp": float(np.mean(g <= 2.0)),
        "frac_within_3pp": float(np.mean(g <= 3.0)),
    }
    return g, cdf, summary


def violin_quantiles(x: np.ndarray) -> dict[str, float]:
    """Distribution summary standing in for the Fig. 9 violins."""
    x = np.asarray(x, dtype=np.float64)
    qs = np.quantile(x, [0.05, 0.25, 0.5, 0.75, 0.95]) if x.size else np.zeros(5)
    return {
        "p05": float(qs[0]),
        "p25": float(qs[1]),
        "median": float(qs[2]),
        "p75": float(qs[3]),
        "p95": float(qs[4]),
        "mean": float(np.mean(x)) if x.size else 0.0,
    }
