"""Neural-collaborative-filtering performance predictor (paper §3.1, [39]).

Performance prediction as matrix completion: rows = applications, columns =
(cpu_cap, gpu_cap) grid cells.  A NeuMF-style model (GMF branch: elementwise
product of embeddings; MLP branch: concatenated embeddings + numeric cap
features) predicts the *log runtime ratio* of an (app, config) cell relative
to the max-cap reference config.  Predicting ratios is exactly what the
allocator needs: improvements I(c,g) are scale-free.

Two phases, matching the paper's workflow (Fig. 3):

 * ``fit``           — offline training on historical apps (dense or sparse
                       observations), Adam + MSE.
 * ``infer_app``     — online phase for an *unseen* app: freeze config
                       embeddings + MLP, fit only the new app's two
                       embedding vectors on K profiled samples.
 * ``update_app``    — incremental variant of the online phase: re-fit an
                       app's embeddings from its *accumulated* observation
                       buffer (replacing any previous embedding row).  The
                       seeded from-scratch re-fit makes the result a pure
                       function of (name, observations, shared params), so
                       incrementally updated predictors agree bit-for-bit
                       with a fresh ``infer_app`` on the same observations.
 * ``update_apps``   — batched online phase: one stacked embedding fit for
                       every app whose telemetry changed this round (the
                       per-app losses are independent and AdamW is
                       elementwise, so the stacked trajectory matches the
                       sequential per-app fits up to float reduction order).
 * ``predict_table`` — densify the predicted surface over the full grid
                       (handed to the allocator as a TabulatedSurface).

The telemetry-driven wrapper that feeds ``update_apps`` from live cluster
measurements lives in :mod:`repro.cluster.predictor` (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.surfaces import PowerSurface, TabulatedSurface
from repro.core.types import SystemSpec
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class NCFConfig:
    embed_dim: int = 16
    mlp_hidden: tuple[int, ...] = (64, 32)
    lr: float = 3e-3
    train_steps: int = 3000
    online_lr: float = 5e-2
    online_steps: int = 400
    batch_size: int = 512
    seed: int = 0


def _config_features(system: SystemSpec) -> np.ndarray:
    """Per-grid-cell numeric features in [0,1]: (c_norm, g_norm)."""
    grid = system.grid
    pairs = grid.pairs()
    c = (pairs[:, 0] - grid.cpu_min) / max(grid.cpu_max - grid.cpu_min, 1e-9)
    g = (pairs[:, 1] - grid.gpu_min) / max(grid.gpu_max - grid.gpu_min, 1e-9)
    return np.stack([c, g], axis=-1).astype(np.float32)


def _init_params(rng: jax.Array, n_apps: int, n_cfgs: int, cfg: NCFConfig):
    d = cfg.embed_dim
    keys = jax.random.split(rng, 8)
    scale = 0.1
    feat_dim = 2
    mlp_in = 2 * d + feat_dim
    layers = []
    dims = (mlp_in,) + cfg.mlp_hidden
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        k = jax.random.fold_in(keys[4], i)
        layers.append(
            {
                "w": jax.random.normal(k, (din, dout)) * jnp.sqrt(2.0 / din),
                "b": jnp.zeros((dout,)),
            }
        )
    head_in = d + cfg.mlp_hidden[-1]
    return {
        "app_gmf": scale * jax.random.normal(keys[0], (n_apps, d)),
        "app_mlp": scale * jax.random.normal(keys[1], (n_apps, d)),
        "cfg_gmf": scale * jax.random.normal(keys[2], (n_cfgs, d)),
        "cfg_mlp": scale * jax.random.normal(keys[3], (n_cfgs, d)),
        "mlp": layers,
        "head_w": jax.random.normal(keys[5], (head_in, 1)) * jnp.sqrt(1.0 / head_in),
        "head_b": jnp.zeros((1,)),
    }


def _forward(params, app_ids, cfg_ids, cfg_feats):
    ag = params["app_gmf"][app_ids]
    am = params["app_mlp"][app_ids]
    cg = params["cfg_gmf"][cfg_ids]
    cm = params["cfg_mlp"][cfg_ids]
    gmf = ag * cg
    h = jnp.concatenate([am, cm, cfg_feats], axis=-1)
    for layer in params["mlp"]:
        h = jax.nn.silu(h @ layer["w"] + layer["b"])
    z = jnp.concatenate([gmf, h], axis=-1)
    return (z @ params["head_w"] + params["head_b"])[..., 0]


@dataclasses.dataclass
class NCFPredictor:
    """Trained predictor bound to one system's cap grid."""

    system: SystemSpec
    cfg: NCFConfig
    params: dict
    app_index: dict[str, int]
    cfg_feats: np.ndarray  # [C, 2]

    # -- construction -------------------------------------------------------

    @staticmethod
    def fit(
        system: SystemSpec,
        observations: Mapping[str, Mapping[tuple[float, float], float]],
        cfg: NCFConfig = NCFConfig(),
    ) -> "NCFPredictor":
        """Train on historical apps.

        ``observations[app][(c, g)] = measured runtime`` — any subset of the
        grid per app; targets are log-ratios vs that app's max-cap cell
        (which must be observed or is approximated by the min runtime).
        """
        grid = system.grid
        pairs = grid.pairs()
        cell_of = {(round(c, 3), round(g, 3)): i for i, (c, g) in enumerate(pairs)}
        app_index = {name: i for i, name in enumerate(sorted(observations))}
        rows, cols, ys = [], [], []
        for name, obs in observations.items():
            ref = min(obs.values())  # fastest observed ~ max-cap runtime
            for (c, g), t in obs.items():
                key = (round(c, 3), round(g, 3))
                if key not in cell_of:
                    raise KeyError(f"({c},{g}) not on the {system.name} grid")
                rows.append(app_index[name])
                cols.append(cell_of[key])
                ys.append(np.log(t / ref))
        rows = jnp.asarray(np.array(rows, np.int32))
        cols = jnp.asarray(np.array(cols, np.int32))
        ys = jnp.asarray(np.array(ys, np.float32))
        feats = jnp.asarray(_config_features(system))

        rng = jax.random.PRNGKey(cfg.seed)
        params = _init_params(rng, len(app_index), len(pairs), cfg)
        optimizer = opt.adamw(cfg.lr)
        state = optimizer.init(params)

        @jax.jit
        def step(params, state, key):
            idx = jax.random.randint(key, (cfg.batch_size,), 0, rows.shape[0])

            def loss_fn(p):
                pred = _forward(p, rows[idx], cols[idx], feats[cols[idx]])
                return jnp.mean((pred - ys[idx]) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, state = optimizer.update(grads, state, params)
            return params, state, loss

        key = jax.random.PRNGKey(cfg.seed + 1)
        for i in range(cfg.train_steps):
            key, sub = jax.random.split(key)
            params, state, loss = step(params, state, sub)
        return NCFPredictor(
            system=system,
            cfg=cfg,
            params=jax.device_get(params),
            app_index=app_index,
            cfg_feats=np.asarray(feats),
        )

    # -- online phase for unseen apps ---------------------------------------

    def has_app(self, name: str) -> bool:
        return name in self.app_index

    def _sample_arrays(
        self, samples: Mapping[tuple[float, float], float]
    ) -> tuple[np.ndarray, np.ndarray]:
        """(grid-cell ids, log-runtime-ratio targets) for one app's samples.

        The reference is the fastest observed runtime (≈ the max-cap cell),
        exactly as in :meth:`fit`.
        """
        grid = self.system.grid
        pairs = grid.pairs()
        cell_of = {(round(c, 3), round(g, 3)): i for i, (c, g) in enumerate(pairs)}
        ref = min(samples.values())
        cols = np.array(
            [cell_of[(round(c, 3), round(g, 3))] for c, g in samples], np.int32
        )
        ys = np.array([np.log(t / ref) for t in samples.values()], np.float32)
        return cols, ys

    def _app_rng(self, name: str) -> jax.Array:
        return jax.random.PRNGKey(zlib.crc32(name.encode()) % (2**31))

    def _init_embedding(self, name: str) -> dict:
        d = self.cfg.embed_dim
        rng = self._app_rng(name)
        return {
            "gmf": 0.1 * jax.random.normal(rng, (1, d)),
            "mlp": 0.1 * jax.random.normal(jax.random.fold_in(rng, 1), (1, d)),
        }

    def _fit_embedding(
        self, name: str, cols: np.ndarray, ys: np.ndarray
    ) -> dict:
        """Online phase core: fit one app's embedding pair, shared params
        frozen.  Deterministic given (name, observations, shared params)."""
        cols = jnp.asarray(cols)
        ys = jnp.asarray(ys)
        feats = jnp.asarray(self.cfg_feats)
        frozen = jax.tree.map(
            jnp.asarray, {k: v for k, v in self.params.items() if "app" not in k}
        )
        emb = self._init_embedding(name)
        optimizer = opt.adamw(self.cfg.online_lr)
        state = optimizer.init(emb)

        @jax.jit
        def step(emb, state):
            def loss_fn(e):
                p = dict(frozen)
                p["app_gmf"], p["app_mlp"] = e["gmf"], e["mlp"]
                zeros = jnp.zeros_like(cols)
                pred = _forward(p, zeros, cols, feats[cols])
                return jnp.mean((pred - ys) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(emb)
            emb, state = optimizer.update(grads, state, emb)
            return emb, state, loss

        for _ in range(self.cfg.online_steps):
            emb, state, _ = step(emb, state)
        return {k: np.asarray(v) for k, v in emb.items()}

    def _with_embeddings(self, emb_by_app: Mapping[str, dict]) -> "NCFPredictor":
        """New predictor with the given (1, d) embedding pairs written in:
        known apps have their row replaced, new apps are appended in sorted
        name order."""
        gmf = np.array(self.params["app_gmf"])
        mlp = np.array(self.params["app_mlp"])
        new_index = dict(self.app_index)
        appended_g, appended_m = [], []
        for name in sorted(emb_by_app):
            e = emb_by_app[name]
            if name in new_index:
                gmf[new_index[name]] = e["gmf"][0]
                mlp[new_index[name]] = e["mlp"][0]
            else:
                new_index[name] = len(new_index)
                appended_g.append(e["gmf"])
                appended_m.append(e["mlp"])
        if appended_g:
            gmf = np.concatenate([gmf] + appended_g, axis=0)
            mlp = np.concatenate([mlp] + appended_m, axis=0)
        new_params = dict(self.params)
        new_params["app_gmf"] = gmf
        new_params["app_mlp"] = mlp
        return NCFPredictor(
            system=self.system,
            cfg=self.cfg,
            params=new_params,
            app_index=new_index,
            cfg_feats=self.cfg_feats,
        )

    def infer_app(
        self,
        name: str,
        samples: Mapping[tuple[float, float], float],
    ) -> "NCFPredictor":
        """Fit embeddings for an unseen app from K online-profiled samples.

        Freezes all shared parameters (config embeddings, MLP, head) and
        optimizes only the new app's GMF/MLP embedding vectors.  Returns a
        new predictor whose app table includes ``name``.
        """
        cols, ys = self._sample_arrays(samples)
        return self._with_embeddings({name: self._fit_embedding(name, cols, ys)})

    def update_app(
        self,
        name: str,
        samples: Mapping[tuple[float, float], float],
    ) -> "NCFPredictor":
        """Incremental online update: re-fit ``name``'s embeddings from its
        full accumulated observation set.

        Runs the same seeded fit as :meth:`infer_app`, so updating a stale
        predictor with the accumulated buffer yields *exactly* the predictor
        a from-scratch ``infer_app`` on those observations would — the
        contract tests/test_online_predictor.py certifies.  Unknown apps are
        added (``update_app`` ⊇ ``infer_app``)."""
        return self.infer_app(name, samples)

    def update_apps(
        self,
        samples_by_app: Mapping[str, Mapping[tuple[float, float], float]],
    ) -> "NCFPredictor":
        """Batched online phase: fit every listed app's embedding pair in a
        single stacked optimization (one jitted step for all apps).

        Per-app loss terms are independent (each involves only that app's
        embedding row) and AdamW is elementwise, so each row follows the
        same trajectory as a standalone :meth:`update_app` up to float
        reduction order.  Observation counts may differ per app; short apps
        are zero-padded and masked."""
        names = sorted(samples_by_app)
        if not names:
            return self
        if len(names) == 1:
            return self.update_app(names[0], samples_by_app[names[0]])
        arrays = [self._sample_arrays(samples_by_app[n]) for n in names]
        n_apps = len(names)
        k_max = max(len(c) for c, _ in arrays)
        cols = np.zeros((n_apps, k_max), np.int32)
        ys = np.zeros((n_apps, k_max), np.float32)
        mask = np.zeros((n_apps, k_max), np.float32)
        for i, (c, y) in enumerate(arrays):
            cols[i, : len(c)] = c
            ys[i, : len(y)] = y
            mask[i, : len(c)] = 1.0
        counts = jnp.asarray(mask.sum(axis=1))
        cols = jnp.asarray(cols)
        ys = jnp.asarray(ys)
        mask = jnp.asarray(mask)
        feats = jnp.asarray(self.cfg_feats)
        frozen = jax.tree.map(
            jnp.asarray, {k: v for k, v in self.params.items() if "app" not in k}
        )
        emb = {
            "gmf": jnp.concatenate(
                [self._init_embedding(n)["gmf"] for n in names], axis=0
            ),
            "mlp": jnp.concatenate(
                [self._init_embedding(n)["mlp"] for n in names], axis=0
            ),
        }
        optimizer = opt.adamw(self.cfg.online_lr)
        state = optimizer.init(emb)
        app_ids = jnp.broadcast_to(
            jnp.arange(n_apps, dtype=jnp.int32)[:, None], (n_apps, k_max)
        )

        @jax.jit
        def step(emb, state):
            def loss_fn(e):
                p = dict(frozen)
                p["app_gmf"], p["app_mlp"] = e["gmf"], e["mlp"]
                pred = _forward(p, app_ids, cols, feats[cols])
                per_app = jnp.sum(mask * (pred - ys) ** 2, axis=1) / counts
                # sum (not mean) over apps: each row's gradient equals its
                # standalone single-app gradient
                return jnp.sum(per_app)

            loss, grads = jax.value_and_grad(loss_fn)(emb)
            emb, state = optimizer.update(grads, state, emb)
            return emb, state, loss

        for _ in range(self.cfg.online_steps):
            emb, state, _ = step(emb, state)
        out = {
            name: {
                "gmf": np.asarray(emb["gmf"][i : i + 1]),
                "mlp": np.asarray(emb["mlp"][i : i + 1]),
            }
            for i, name in enumerate(names)
        }
        return self._with_embeddings(out)

    # -- prediction ----------------------------------------------------------

    def predict_log_ratios(self, name: str) -> np.ndarray:
        """Predicted log runtime ratio for every grid cell, shape [C]."""
        if name not in self.app_index:
            raise KeyError(f"{name} unknown; call infer_app first")
        aid = self.app_index[name]
        params = jax.tree.map(jnp.asarray, self.params)
        n = self.cfg_feats.shape[0]
        app_ids = jnp.full((n,), aid, jnp.int32)
        cfg_ids = jnp.arange(n, dtype=jnp.int32)
        out = _forward(params, app_ids, cfg_ids, jnp.asarray(self.cfg_feats))
        return np.asarray(out)

    def predict_surface(self, name: str) -> PowerSurface:
        """Predicted runtime surface (arbitrary scale) over the full grid."""
        grid = self.system.grid
        ratios = np.exp(self.predict_log_ratios(name))
        n_c, n_g = len(grid.cpu_levels), len(grid.gpu_levels)
        return TabulatedSurface(
            cpu_levels=grid.cpu_levels,
            gpu_levels=grid.gpu_levels,
            table=ratios.reshape(n_c, n_g),
        )
