"""Cluster-wide power-distribution policies (paper §5.1).

All policies share one signature and return a validated ``Allocation``:

    policy(receivers, baselines, budget, system, surfaces, ...) -> Allocation

``surfaces`` carries the runtime model the policy is allowed to see:
 * EcoShift receives *predicted* surfaces (NCF) — or true ones when the
   prediction stage is being ablated;
 * the Oracle receives *true* surfaces;
 * DPS / MixedAdaptive only use telemetry-level information (natural power
   draw), never the performance surfaces — faithful to the baselines they
   reproduce (fair-share [9] and demand-proportional [35]).
"""

from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core import curves, mckp
from repro.core.surfaces import PowerSurface
from repro.core.types import (
    Allocation,
    AppSpec,
    SystemSpec,
    as_receiver_order,
    validate_allocation,
)

PolicyFn = Callable[..., Allocation]


def allocation_from_solution(
    sol: mckp.MCKPSolution,
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    grid,
) -> Allocation:
    """Turn an MCKP solution's picks into a validated ``Allocation`` —
    the shared assembly step of every DP policy and controller."""
    alloc = Allocation(
        caps={name: pick[2] for name, pick in sol.picks.items()},
        spent=sol.spent,
        predicted_improvement=sol.average_improvement(),
    )
    validate_allocation(alloc, baselines, budget, grid)
    return alloc


def _headroom(baselines, name, system) -> tuple[float, float]:
    c0, g0 = baselines[name]
    grid = system.grid
    return grid.cpu_max - c0, grid.gpu_max - g0


# ---------------------------------------------------------------------------
# No-distribution baseline
# ---------------------------------------------------------------------------


def uniform(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface] | None = None,
) -> Allocation:
    """Keep the initial uniform caps (the paper's measurement baseline)."""
    caps = {a.name: baselines[a.name] for a in receivers}
    alloc = Allocation(caps=caps, spent=0.0, predicted_improvement=0.0)
    validate_allocation(alloc, baselines, budget, system.grid)
    return alloc


# ---------------------------------------------------------------------------
# DPS — fair-share redistribution [Ding & Hoffmann, SC'23]
# ---------------------------------------------------------------------------


def dps(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface] | None = None,
) -> Allocation:
    """Fair-share: equal watts per receiver, split evenly CPU/GPU.

    Water-filling handles grid-ceiling clamps: leftover watts from saturated
    receivers/components are re-shared equally among the rest until either
    the budget is gone or everyone is saturated.  (Table 2: two receivers,
    200 W -> each gets 100 W split 50/50 -> caps (+50, +50).)
    """
    order = as_receiver_order(receivers)
    extra = {a.name: [0.0, 0.0] for a in order}
    head = {a.name: list(_headroom(baselines, a.name, system)) for a in order}
    remaining = float(budget)
    for _ in range(64):
        active = [
            a.name for a in order if head[a.name][0] > 1e-9 or head[a.name][1] > 1e-9
        ]
        if not active or remaining <= 1e-9:
            break
        share = remaining / len(active)
        for name in active:
            hc, hg = head[name]
            want_c = want_g = share / 2.0
            # within a receiver, a saturated component's half spills over
            give_c = min(want_c, hc)
            give_g = min(want_g, hg)
            spill = (want_c - give_c) + (want_g - give_g)
            if spill > 0:
                extra_c = min(spill, hc - give_c)
                give_c += extra_c
                give_g += min(spill - extra_c, hg - give_g)
            extra[name][0] += give_c
            extra[name][1] += give_g
            head[name][0] -= give_c
            head[name][1] -= give_g
            remaining -= give_c + give_g
    caps = {}
    for a in order:
        c0, g0 = baselines[a.name]
        caps[a.name] = (c0 + extra[a.name][0], g0 + extra[a.name][1])
    alloc = Allocation(caps=caps, spent=budget - remaining)
    validate_allocation(alloc, baselines, budget, system.grid)
    return alloc


# ---------------------------------------------------------------------------
# MixedAdaptive — demand-proportional [Wilson et al., IPDPS'21]
# ---------------------------------------------------------------------------


def mixed_adaptive(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface],
) -> Allocation:
    """Allocate proportionally to per-component power *demand*.

    Demand is inferred from telemetry: a component pinned at its cap with
    natural draw above it demands (natural - cap) more watts.  The budget is
    split proportionally to demand, capped at each component's demand and
    grid headroom, with proportional water-filling of the remainder.
    """
    order = as_receiver_order(receivers)
    names = [a.name for a in order]
    demand = np.zeros((len(order), 2))
    head = np.zeros((len(order), 2))
    for i, a in enumerate(order):
        c0, g0 = baselines[a.name]
        nat_c, nat_g = surfaces[a.name].power_draw(1e9, 1e9)
        demand[i, 0] = max(0.0, float(nat_c) - c0)
        demand[i, 1] = max(0.0, float(nat_g) - g0)
        head[i] = _headroom(baselines, a.name, system)
    limit = np.minimum(demand, head)

    give = np.zeros_like(demand)
    remaining = float(budget)
    for _ in range(64):
        room = limit - give
        active = (demand > 1e-9) & (room > 1e-9)
        if remaining <= 1e-9 or not active.any():
            break
        w = np.where(active, demand, 0.0)
        w_sum = w.sum()
        if w_sum <= 0:
            break
        inc = np.minimum(remaining * w / w_sum, room)
        give += inc
        remaining -= float(inc.sum())

    caps = {}
    for i, name in enumerate(names):
        c0, g0 = baselines[name]
        caps[name] = (c0 + float(give[i, 0]), g0 + float(give[i, 1]))
    alloc = Allocation(caps=caps, spent=budget - remaining)
    validate_allocation(alloc, baselines, budget, system.grid)
    return alloc


# ---------------------------------------------------------------------------
# EcoShift — predicted-surface MCKP via DP (the paper's contribution)
# ---------------------------------------------------------------------------


def ecoshift(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface],
    *,
    solver: str = "sparse",
    unit: float = 1.0,
    grouped: bool = False,
) -> Allocation:
    """Build per-receiver option curves from the (predicted) surfaces and
    solve the multiple-choice knapsack with the DP of §3.2.2.

    ``grouped=True`` collapses receivers sharing (surface identity,
    baseline) into one behaviour class — one option table and one DP
    super-stage per class (DESIGN.md §11) — solving clusters of replicated
    app classes in ~G stages instead of N, with bit-for-bit (sparse) /
    bitwise (dense) parity against the ungrouped path.
    """
    order = as_receiver_order(receivers)
    if grouped:
        groups = mckp.collapse_receivers(
            [a.name for a in order],
            [surfaces[a.name] for a in order],
            [baselines[a.name] for a in order],
            lambda surf, base: curves.build_options(
                "class", surf, base, system.grid, budget
            ),
        )
        sol = mckp.solve_grouped(groups, budget, solver=solver, unit=unit)
        return allocation_from_solution(sol, baselines, budget, system.grid)
    options = [
        curves.build_options(
            a.name, surfaces[a.name], baselines[a.name], system.grid, budget
        )
        for a in order
    ]
    if solver == "sparse":
        sol = mckp.solve_sparse(options, budget)
    elif solver == "dense":
        sol = mckp.solve_dense(options, budget, unit=unit)
    elif solver in ("jax", "pallas"):
        sol = mckp.solve_dense_jax(options, budget, unit=unit, backend=solver)
    else:
        raise ValueError(f"unknown solver {solver!r}")
    return allocation_from_solution(sol, baselines, budget, system.grid)


# ---------------------------------------------------------------------------
# EcoShift-Hier — topology-aware two-level MCKP (DESIGN.md §12)
# ---------------------------------------------------------------------------


def domain_tree(topology, caps, groups_by_leaf) -> mckp.DomainGroups:
    """Mirror a :class:`~repro.core.topology.PowerTopology` into the solver's
    :class:`~repro.core.mckp.DomainGroups` tree.

    ``caps`` is the per-domain *extra-power headroom* indexed by preorder
    domain id; ``groups_by_leaf`` maps leaf domain id -> its receivers'
    ``GroupedOptions``.  Shared by the pure policy and the controller.
    """

    def build(d):
        i = topology.index[d.name]
        if d.is_leaf:
            return mckp.DomainGroups(
                name=d.name,
                cap=float(caps[i]),
                groups=tuple(groups_by_leaf.get(i, ())),
            )
        return mckp.DomainGroups(
            name=d.name,
            cap=float(caps[i]),
            children=tuple(build(c) for c in d.children),
        )

    return build(topology.root)


def ecoshift_hier(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface],
    *,
    topology,
    node_of: Mapping[str, int],
    domain_extra: Mapping[str, float] | None = None,
    solver: str = "sparse",
    unit: float = 1.0,
) -> Allocation:
    """Topology-aware EcoShift: per-domain capped frontiers + upper-level DP.

    ``topology`` is a :class:`~repro.core.topology.PowerTopology`;
    ``node_of`` maps each receiver instance name to its node id (the
    topology's leaf ranges own node ids, not instance names).
    ``domain_extra`` gives each domain's extra-power headroom in watts (by
    domain name); when omitted it defaults to the round-0 cap minus the
    baseline caps of the domain's *receivers* — the standalone
    approximation.  The cluster engine always passes the real headroom (cap
    minus all committed draw, donors and dead nodes included).

    With a single root domain whose cap covers the budget this is
    bit-for-bit the flat ``ecoshift(grouped=True)`` path.
    """
    order = as_receiver_order(receivers)
    leaf_ids = topology.leaf_of([node_of[a.name] for a in order])

    if domain_extra is not None:
        caps = np.array(
            [domain_extra[d.name] for d in topology.domains], dtype=np.float64
        )
    else:
        committed = np.zeros(len(topology), dtype=np.float64)
        for a, leaf in zip(order, leaf_ids):
            c0, g0 = baselines[a.name]
            committed[leaf] += c0 + g0
        caps = topology.cap_at(0) - topology.aggregate_leaves(committed)
        np.clip(caps, 0.0, None, out=caps)

    groups_by_leaf: dict[int, list[mckp.GroupedOptions]] = {}
    for leaf in np.unique(leaf_ids):
        ii = np.flatnonzero(leaf_ids == leaf)
        members = [order[i] for i in ii]
        groups_by_leaf[int(leaf)] = mckp.collapse_receivers(
            [a.name for a in members],
            [surfaces[a.name] for a in members],
            [baselines[a.name] for a in members],
            lambda surf, base: curves.build_options(
                "class", surf, base, system.grid, budget
            ),
        )
    root = domain_tree(topology, caps, groups_by_leaf)
    sol = mckp.solve_hierarchical(root, budget, solver=solver, unit=unit)
    return allocation_from_solution(sol, baselines, budget, system.grid)


# ---------------------------------------------------------------------------
# Oracle — exhaustive search on true surfaces (§5.1, §6.3)
# ---------------------------------------------------------------------------


def oracle(
    receivers: Sequence[AppSpec],
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    system: SystemSpec,
    surfaces: Mapping[str, PowerSurface],
    *,
    exhaustive: bool = True,
) -> Allocation:
    """Brute-force optimum over true surfaces.

    ``exhaustive=True`` runs the DFS brute force (tractable for <= ~10 apps
    after per-app pruning, like the paper's §6.3 study); ``False`` uses the
    exact sparse DP — provably identical on discrete option sets, certified
    by tests, and usable at any scale.
    """
    order = as_receiver_order(receivers)
    options = [
        curves.build_options(
            a.name, surfaces[a.name], baselines[a.name], system.grid, budget
        )
        for a in order
    ]
    sol = (
        mckp.brute_force(options, budget)
        if exhaustive
        else mckp.solve_sparse(options, budget)
    )
    return allocation_from_solution(sol, baselines, budget, system.grid)


POLICIES: dict[str, PolicyFn] = {
    "uniform": uniform,
    "dps": dps,
    "mixed_adaptive": mixed_adaptive,
    "ecoshift": ecoshift,
    "ecoshift_hier": ecoshift_hier,
    "oracle": oracle,
}


# ---------------------------------------------------------------------------
# Stateful controllers (repro.cluster.controller)
# ---------------------------------------------------------------------------

#: policy name -> Controller subclass; populated by repro.cluster.controller
#: via @register_controller so the registry lives beside POLICIES without a
#: core -> cluster import at module load.
CONTROLLERS: dict[str, type] = {}


def register_controller(name: str, *, pure: bool = True):
    """Class decorator: register a stateful controller for ``name``.

    ``pure=True`` (default) requires a pure policy function of the same
    name in ``POLICIES`` — guarding against typos.  ``pure=False``
    registers a *controller-only* policy with no stateless counterpart
    (e.g. ``ecoshift_online``, whose telemetry-driven prediction loop is
    inherently stateful)."""
    if pure and name not in POLICIES:
        raise KeyError(f"controller for unknown policy {name!r}")

    def deco(cls):
        CONTROLLERS[name] = cls
        return cls

    return deco


def get_controller(name: str, system, **kwargs):
    """Instantiate the stateful controller for ``name`` (see CONTROLLERS)."""
    if name not in CONTROLLERS:
        import repro.cluster.controller  # noqa: F401  (populates registry)
    if name not in CONTROLLERS:
        raise KeyError(f"no controller registered for policy {name!r}")
    return CONTROLLERS[name](system, **kwargs)
