"""Online profiling: sampling plans + emulated measurement (paper §3.1).

EcoShift profiles an unseen application at a handful of representative
(cpu, gpu) cap pairs for a short window.  The plan mixes the feasible-region
corners (pins the surface's dynamic range), the center, and low-discrepancy
interior points (captures curvature/diminishing returns).  Deterministic
given (app, system) so emulation runs are reproducible.
"""

from __future__ import annotations

import numpy as np

from repro.core.surfaces import PowerSurface, measured_runtime
from repro.core.types import SystemSpec


def sampling_plan(
    system: SystemSpec,
    n_samples: int = 8,
    *,
    seed: int = 0,
) -> list[tuple[float, float]]:
    """K representative cap pairs on the system grid."""
    grid = system.grid
    cl, gl = grid.cpu_levels, grid.gpu_levels
    plan: list[tuple[float, float]] = [
        (cl[0], gl[0]),
        (cl[-1], gl[-1]),
        (cl[0], gl[-1]),
        (cl[-1], gl[0]),
        (cl[len(cl) // 2], gl[len(gl) // 2]),
    ]
    rng = np.random.default_rng(seed)
    # Halton-style interior fill on grid points
    while len(plan) < n_samples:
        c = cl[int(rng.integers(1, len(cl) - 1))]
        g = gl[int(rng.integers(1, len(gl) - 1))]
        if (c, g) not in plan:
            plan.append((float(c), float(g)))
    return plan[:n_samples]


def profile_app(
    surface: PowerSurface,
    system: SystemSpec,
    *,
    n_samples: int = 8,
    rng: np.random.Generator | None = None,
    seed: int = 0,
) -> dict[tuple[float, float], float]:
    """Emulated online profiling: measure runtime at the planned cap pairs."""
    rng = rng if rng is not None else np.random.default_rng(seed)
    plan = sampling_plan(system, n_samples, seed=seed)
    return {
        (c, g): measured_runtime(
            surface, c, g, rng=rng, noise_sigma=system.noise_sigma
        )
        for (c, g) in plan
    }


def dense_profile(
    surface: PowerSurface,
    system: SystemSpec,
    *,
    rng: np.random.Generator | None = None,
    noise: bool = True,
) -> dict[tuple[float, float], float]:
    """Full-grid sweep (offline characterization for historical apps)."""
    rng = rng if rng is not None else np.random.default_rng(0)
    out = {}
    sigma = system.noise_sigma if noise else 0.0
    for c in system.grid.cpu_levels:
        for g in system.grid.gpu_levels:
            out[(float(c), float(g))] = measured_runtime(
                surface, float(c), float(g), rng=rng, noise_sigma=sigma
            )
    return out
