"""Ground-truth power->performance surfaces for the emulator.

The paper measures each application on real Xeon+A100/H100 nodes under a
(cpu_cap, gpu_cap) sweep (§2, Fig. 1-2).  We reproduce the *published
characteristics* of those surfaces analytically (DESIGN.md §9.2):

  T(c, g) = max(T_host(c), T_dev(g)) + rho * min(T_host(c), T_dev(g))
  T_host(c) = host_work / phi_h(c),   T_dev(g) = dev_work / phi_d(g)

where ``phi`` is a saturating DVFS speed curve ``1 - exp(-(p - p0)/tau)``.
This family exhibits exactly the behaviours the paper motivates with:

 * asymmetric CPU/GPU sensitivity (host- vs device-dominant work),
 * diminishing marginal returns in the cap (concave phi),
 * cross-component insensitivity (raising the non-dominant cap does little),
 * full insensitivity when the knee sits below the feasible grid.

The two Fig. 2 anchor applications are fit *exactly* (to float precision) to
the paper's numbers:

 * cfd        : +17.0% for CPU 300->400 W, +7.6% for 400->500 W (CPU-bound)
 * raytracing : +15.5% for GPU 200->300 W, +2.1% for 300->400 W (GPU-bound)

``fit_saturating_curve`` solves for (p0, tau) from those two ratios in closed
form up to a 1-D bisection; tests assert the anchors reproduce to <0.2%.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Mapping

import numpy as np

from repro.core.types import (
    AppSpec,
    CLASS_BOTH,
    CLASS_CPU,
    CLASS_GPU,
    CLASS_NONE,
    SystemSpec,
)

# ---------------------------------------------------------------------------
# Speed curves
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpeedCurve:
    """Saturating DVFS speed fraction: phi(p) = 1 - exp(-(p - p0)/tau).

    Clipped below at ``floor`` so surfaces stay finite for caps near/below
    the leakage point p0.  phi is monotonically non-decreasing in p.
    """

    p0: float
    tau: float
    floor: float = 0.05

    def __call__(self, p) -> np.ndarray:
        p = np.asarray(p, dtype=np.float64)
        val = 1.0 - np.exp(-(p - self.p0) / self.tau)
        return np.clip(val, self.floor, 1.0)

    @staticmethod
    def flat() -> "SpeedCurve":
        """A curve saturated everywhere inside any realistic grid."""
        return SpeedCurve(p0=-1e9, tau=1.0)


def fit_saturating_curve(
    p_lo: float,
    p_mid: float,
    p_hi: float,
    gain_lo_mid: float,
    gain_mid_hi: float,
) -> SpeedCurve:
    """Fit (p0, tau) so a component-dominated app shows the given gains.

    ``gain_lo_mid`` is the relative runtime reduction when the dominant cap
    moves p_lo -> p_mid (e.g. 0.17 for cfd CPU 300->400), and likewise for
    p_mid -> p_hi.  For a dominated app T ~ 1/phi, so the gains pin the
    ratios r1 = phi(mid)/phi(lo) and r2 = phi(hi)/phi(mid).  With
    u = exp(-(p_hi - p_mid)/tau) (assuming uniform spacing) both ratios are
    rational in (u, a) and we bisect on u.
    """
    if not np.isclose(p_mid - p_lo, p_hi - p_mid):
        raise ValueError("fit assumes uniformly spaced anchor powers")
    d = p_mid - p_lo
    r1 = 1.0 / (1.0 - gain_lo_mid)
    r2 = 1.0 / (1.0 - gain_mid_hi)

    def resid(u: float) -> float:
        # a = exp(-(p_lo - p0)/tau); two expressions for a must agree.
        a1 = (r1 - 1.0) / (r1 - u)
        a2 = (r2 - 1.0) / (u * (r2 - u))
        return a1 - a2

    lo, hi = 1e-6, 1.0 - 1e-6
    flo = resid(lo)
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        fmid = resid(mid)
        if np.sign(fmid) == np.sign(flo):
            lo, flo = mid, fmid
        else:
            hi = mid
    u = 0.5 * (lo + hi)
    tau = -d / np.log(u)
    a = (r1 - 1.0) / (r1 - u)
    p0 = p_lo + tau * np.log(a)
    return SpeedCurve(p0=float(p0), tau=float(tau))


# ---------------------------------------------------------------------------
# Surfaces
# ---------------------------------------------------------------------------


class PowerSurface:
    """Interface: continuous runtime + power-draw model over cap pairs."""

    def runtime(self, c, g) -> np.ndarray:  # pragma: no cover - interface
        raise NotImplementedError

    def power_draw(self, c, g) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError  # pragma: no cover - interface

    # Convenience -----------------------------------------------------------
    def improvement(self, base: tuple[float, float], c, g) -> np.ndarray:
        """Relative runtime reduction I(c,g) vs baseline caps (§3.2.1)."""
        t0 = self.runtime(base[0], base[1])
        return (t0 - self.runtime(c, g)) / t0


@dataclasses.dataclass(frozen=True)
class AnalyticSurface(PowerSurface):
    host_work: float
    dev_work: float
    phi_h: SpeedCurve
    phi_d: SpeedCurve
    #: non-overlapped coupling fraction in [0, ~0.4)
    rho: float = 0.1
    #: natural (uncapped) component draws, for donor detection
    natural_cpu: float = 1e9
    natural_gpu: float = 1e9

    def runtime(self, c, g) -> np.ndarray:
        th = self.host_work / self.phi_h(c)
        td = self.dev_work / self.phi_d(g)
        return np.maximum(th, td) + self.rho * np.minimum(th, td)

    def power_draw(self, c, g) -> tuple[np.ndarray, np.ndarray]:
        c = np.asarray(c, dtype=np.float64)
        g = np.asarray(g, dtype=np.float64)
        return np.minimum(c, self.natural_cpu), np.minimum(g, self.natural_gpu)


@dataclasses.dataclass(frozen=True)
class TabulatedSurface(PowerSurface):
    """Bilinear interpolation over a measured/predicted (c, g) table.

    Used for (a) NCF-predicted surfaces handed to the allocator and (b)
    roofline-derived surfaces of the assigned architectures (surfaces built
    from compiled-HLO cost analysis; see repro.roofline).
    """

    cpu_levels: np.ndarray
    gpu_levels: np.ndarray
    #: runtime[i, j] at (cpu_levels[i], gpu_levels[j])
    table: np.ndarray
    natural_cpu: float = 1e9
    natural_gpu: float = 1e9

    def runtime(self, c, g) -> np.ndarray:
        c = np.clip(np.asarray(c, np.float64), self.cpu_levels[0], self.cpu_levels[-1])
        g = np.clip(np.asarray(g, np.float64), self.gpu_levels[0], self.gpu_levels[-1])
        ci = np.clip(np.searchsorted(self.cpu_levels, c) - 1, 0, len(self.cpu_levels) - 2)
        gi = np.clip(np.searchsorted(self.gpu_levels, g) - 1, 0, len(self.gpu_levels) - 2)
        c0, c1 = self.cpu_levels[ci], self.cpu_levels[ci + 1]
        g0, g1 = self.gpu_levels[gi], self.gpu_levels[gi + 1]
        wc = np.where(c1 > c0, (c - c0) / np.where(c1 > c0, c1 - c0, 1.0), 0.0)
        wg = np.where(g1 > g0, (g - g0) / np.where(g1 > g0, g1 - g0, 1.0), 0.0)
        t00 = self.table[ci, gi]
        t01 = self.table[ci, gi + 1]
        t10 = self.table[ci + 1, gi]
        t11 = self.table[ci + 1, gi + 1]
        return (
            t00 * (1 - wc) * (1 - wg)
            + t01 * (1 - wc) * wg
            + t10 * wc * (1 - wg)
            + t11 * wc * wg
        )

    def power_draw(self, c, g) -> tuple[np.ndarray, np.ndarray]:
        c = np.asarray(c, dtype=np.float64)
        g = np.asarray(g, dtype=np.float64)
        return np.minimum(c, self.natural_cpu), np.minimum(g, self.natural_gpu)


def tabulate(surface: PowerSurface, system: SystemSpec) -> TabulatedSurface:
    """Sample a surface on a system's full cap grid."""
    cl, gl = system.grid.cpu_levels, system.grid.gpu_levels
    cc, gg = np.meshgrid(cl, gl, indexing="ij")
    nat_c, nat_g = surface.power_draw(1e9, 1e9)
    return TabulatedSurface(
        cpu_levels=cl,
        gpu_levels=gl,
        table=np.asarray(surface.runtime(cc, gg)),
        natural_cpu=float(nat_c),
        natural_gpu=float(nat_g),
    )


# ---------------------------------------------------------------------------
# Paper anchor surfaces (Fig. 2 / Table 2 calibration)
# ---------------------------------------------------------------------------


def _calibrate(
    build,
    anchors: tuple[float, float, float],
    targets: tuple[float, float],
    axis: str,
    fixed: float,
) -> AnalyticSurface:
    """Iteratively refit the dominant curve so *measured* surface gains hit
    the paper's anchors exactly (the cross-component coupling term slightly
    dilutes the pure-1/phi fit; a few multiplicative corrections converge)."""
    p_lo, p_mid, p_hi = anchors
    g1, g2 = targets
    adj1, adj2 = g1, g2
    surf = None
    for _ in range(8):
        curve = fit_saturating_curve(p_lo, p_mid, p_hi, adj1, adj2)
        surf = build(curve)

        def rt(p):
            return float(
                surf.runtime(p, fixed) if axis == "cpu" else surf.runtime(fixed, p)
            )

        t_lo, t_mid, t_hi = rt(p_lo), rt(p_mid), rt(p_hi)
        m1 = (t_lo - t_mid) / t_lo
        m2 = (t_mid - t_hi) / t_mid
        adj1 = float(np.clip(adj1 * g1 / max(m1, 1e-6), 1e-4, 0.9))
        adj2 = float(np.clip(adj2 * g2 / max(m2, 1e-6), 1e-4, 0.9))
    return surf


def cfd_surface() -> AnalyticSurface:
    """CPU-dominated: +17% for CPU 300->400 W, +7.6% for 400->500 W."""

    def build(phi_h: SpeedCurve) -> AnalyticSurface:
        # device work small enough that the host term dominates everywhere,
        # saturated-early device curve so extra GPU power is near-useless.
        return AnalyticSurface(
            host_work=1.0,
            dev_work=0.25,
            phi_h=phi_h,
            phi_d=SpeedCurve(p0=40.0, tau=35.0),
            rho=0.05,
            natural_cpu=520.0,
            natural_gpu=240.0,
        )

    return _calibrate(build, (300.0, 400.0, 500.0), (0.170, 0.076), "cpu", 200.0)


def raytracing_surface() -> AnalyticSurface:
    """GPU-dominated: +15.5% for GPU 200->300 W, +2.1% for 300->400 W."""

    def build(phi_d: SpeedCurve) -> AnalyticSurface:
        return AnalyticSurface(
            host_work=0.2,
            dev_work=1.0,
            phi_h=SpeedCurve(p0=60.0, tau=60.0),
            phi_d=phi_d,
            rho=0.05,
            natural_cpu=330.0,
            natural_gpu=520.0,
        )

    return _calibrate(build, (200.0, 300.0, 400.0), (0.155, 0.021), "gpu", 300.0)


# ---------------------------------------------------------------------------
# Workload suite (Table 1): 40 apps across 4 sensitivity classes
# ---------------------------------------------------------------------------

#: (suite, app, class) following Table 1 of the paper.
TABLE_1: tuple[tuple[str, str, str], ...] = (
    ("altis", "gemm", CLASS_CPU),
    ("altis", "gups", CLASS_NONE),
    ("altis", "maxflops", CLASS_CPU),
    ("altis", "bfs", CLASS_CPU),
    ("altis", "particlefilter_float", CLASS_GPU),
    ("altis", "cfd_double", CLASS_BOTH),
    ("altis", "particlefilter_naive", CLASS_CPU),
    ("altis", "raytracing", CLASS_GPU),
    ("altis", "fdtd2d", CLASS_GPU),
    ("altis", "nw", CLASS_BOTH),
    ("altis", "cfd", CLASS_CPU),
    ("altis", "lavamd", CLASS_CPU),
    ("altis", "sort", CLASS_CPU),
    ("hecbench", "kalman", CLASS_CPU),
    ("hecbench", "stencil3d", CLASS_CPU),
    ("hecbench", "extrema", CLASS_BOTH),
    ("hecbench", "knn", CLASS_CPU),
    ("hecbench", "dropout", CLASS_NONE),
    ("hecbench", "aobench", CLASS_NONE),
    ("hecbench", "zoom", CLASS_CPU),
    ("hecbench", "convolution3D", CLASS_BOTH),
    ("hecbench", "softmax", CLASS_CPU),
    ("hecbench", "chacha20", CLASS_NONE),
    ("hecbench", "zmddft", CLASS_GPU),
    ("hecbench", "residualLayerNorm", CLASS_BOTH),
    ("hecbench", "backgroundSubtract", CLASS_CPU),
    ("mlperf", "UNet", CLASS_BOTH),
    ("mlperf", "BERT", CLASS_GPU),
    ("mlperf", "ResNet50", CLASS_BOTH),
    ("ecp", "sw4lite", CLASS_CPU),
    ("ecp", "XSBench", CLASS_BOTH),
    ("ecp", "Laghos", CLASS_NONE),
    ("ecp", "miniGAN", CLASS_BOTH),
    ("hpc", "GROMACS", CLASS_CPU),
    ("hpc", "LAMMPS", CLASS_CPU),
    ("spec", "lbm", CLASS_GPU),
    ("spec", "cloverleaf", CLASS_CPU),
    ("spec", "tealeaf", CLASS_GPU),
    ("spec", "minisweep", CLASS_NONE),
    ("spec", "pot3d", CLASS_GPU),
)


def _stable_seed(*parts: str) -> int:
    h = hashlib.sha256("/".join(parts).encode()).digest()
    return int.from_bytes(h[:4], "little")


def _random_surface(rng: np.random.Generator, sclass: str, system: SystemSpec) -> AnalyticSurface:
    """Draw a class-consistent surface with randomized parameters.

    Knee placement is expressed relative to the system grid so the same class
    behaves consistently on System 1 (A100 ranges) and System 2 (H100 ranges).
    """
    grid = system.grid
    c_span = grid.cpu_max - grid.cpu_min
    g_span = grid.gpu_max - grid.gpu_min

    def sensitive(span: float, lo: float) -> SpeedCurve:
        # knee inside the grid: p0 below grid min, tau a fraction of span
        p0 = lo - rng.uniform(0.1, 0.6) * span
        tau = rng.uniform(0.30, 0.70) * span
        return SpeedCurve(p0=float(p0), tau=float(tau))

    def saturated(span: float, lo: float) -> SpeedCurve:
        # knee below the grid: nearly flat inside it
        p0 = lo - rng.uniform(2.0, 4.0) * span
        tau = rng.uniform(0.5, 1.0) * span
        return SpeedCurve(p0=float(p0), tau=float(tau))

    rho = float(rng.uniform(0.02, 0.15))
    if sclass == CLASS_CPU:
        hw, dw = 1.0, float(rng.uniform(0.15, 0.5))
        ph = sensitive(c_span, grid.cpu_min)
        pd = saturated(g_span, grid.gpu_min)
        nat = (grid.cpu_max * 1.1, rng.uniform(0.4, 0.8) * grid.gpu_max)
    elif sclass == CLASS_GPU:
        hw, dw = float(rng.uniform(0.15, 0.5)), 1.0
        ph = saturated(c_span, grid.cpu_min)
        pd = sensitive(g_span, grid.gpu_min)
        nat = (rng.uniform(0.4, 0.8) * grid.cpu_max, grid.gpu_max * 1.1)
    elif sclass == CLASS_BOTH:
        hw, dw = 1.0, float(rng.uniform(0.8, 1.2))
        ph = sensitive(c_span, grid.cpu_min)
        pd = sensitive(g_span, grid.gpu_min)
        rho = float(rng.uniform(0.1, 0.35))
        nat = (grid.cpu_max * 1.1, grid.gpu_max * 1.1)
    elif sclass == CLASS_NONE:
        hw, dw = 1.0, float(rng.uniform(0.5, 1.0))
        ph = saturated(c_span, grid.cpu_min)
        pd = saturated(g_span, grid.gpu_min)
        # draws well below even the initial caps -> pure donor
        nat = (
            rng.uniform(0.3, 0.7) * system.init_cpu,
            rng.uniform(0.3, 0.7) * system.init_gpu,
        )
    else:  # pragma: no cover - guarded by AppSpec
        raise ValueError(sclass)
    return AnalyticSurface(
        host_work=hw,
        dev_work=dw,
        phi_h=ph,
        phi_d=pd,
        rho=rho,
        natural_cpu=float(nat[0]),
        natural_gpu=float(nat[1]),
    )


def build_paper_suite(system: SystemSpec) -> tuple[list[AppSpec], dict[str, PowerSurface]]:
    """The 40-app Table-1 suite with class-consistent random surfaces.

    ``cfd`` and ``raytracing`` use the exact Fig.-2-calibrated surfaces on
    System 2 (the H100 system where the paper measured them); on other
    systems they are drawn like the rest of their class.
    """
    apps: list[AppSpec] = []
    surfaces: dict[str, PowerSurface] = {}
    for suite, app, sclass in TABLE_1:
        name = f"{suite}.{app}"
        spec = AppSpec(name=name, sclass=sclass, surface_id=name)
        rng = np.random.default_rng(_stable_seed(system.name, name))
        if app == "cfd" and system.name == "system2-h100":
            surf: PowerSurface = cfd_surface()
        elif app == "raytracing" and system.name == "system2-h100":
            surf = raytracing_surface()
        else:
            surf = _random_surface(rng, sclass, system)
        apps.append(spec)
        surfaces[name] = surf
    return apps, surfaces


def workload_group(
    apps: list[AppSpec], group: str
) -> list[AppSpec]:
    """Paper §5.2 groups: cpu / gpu / both / insensitive / mixed."""
    key = {
        "cpu": CLASS_CPU,
        "gpu": CLASS_GPU,
        "both": CLASS_BOTH,
        "insensitive": CLASS_NONE,
    }
    if group == "mixed":
        return list(apps)
    if group not in key:
        raise ValueError(f"unknown workload group {group!r}")
    return [a for a in apps if a.sclass == key[group]]


def measured_runtime(
    surface: PowerSurface,
    c: float,
    g: float,
    *,
    rng: np.random.Generator,
    noise_sigma: float,
) -> float:
    """One emulated 'execution': surface lookup + multiplicative noise."""
    t = float(surface.runtime(c, g))
    if noise_sigma > 0:
        t *= float(np.exp(rng.normal(0.0, noise_sigma)))
    return t


def surfaces_by_name(
    specs: list[AppSpec], surfaces: Mapping[str, PowerSurface]
) -> dict[str, PowerSurface]:
    return {s.name: surfaces[s.surface_id] for s in specs}
