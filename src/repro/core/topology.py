"""Hierarchical power domains: the facility's cap topology (DESIGN.md §12).

Real power-constrained facilities cascade limits down a tree — site → row →
rack/PDU → node — and a flat allocator can reclaim power into a rack that
physically cannot draw it.  A :class:`PowerTopology` makes that tree
first-class:

 * every :class:`PowerDomain` carries a **cap trace** (scalar, per-round
   sequence, or callable — the same trace forms as scenario budgets) giving
   its max total draw in watts per round;
 * **leaves own node-id ranges** (half-open ``[lo, hi)`` intervals); internal
   domains own the union of their children;
 * node → domain interning is one vectorized ``searchsorted`` over the
   sorted leaf range bounds, so a 10k-node cluster maps its whole id column
   in one pass.

Domains are indexed in deterministic DFS preorder (the root is id 0); the
``parent`` array lets per-leaf sums aggregate to every ancestor in one
reverse sweep.  The allocation math lives in ``repro.core.mckp``
(``solve_hierarchical``); the per-round draw accounting in
``repro.cluster.sim``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Sequence, Union

import numpy as np

#: cap trace: scalar (constant), sequence (holds last value), callable, or a
#: BudgetProvider (anything exposing ``budget_at(r)`` — the PR-7 provider
#: protocol), so a rack can ride a solar/CO2 fixture like the cluster budget
CapTrace = Union[float, Sequence, Callable[[int], float]]


def cap_trace_at(trace: CapTrace, r: int) -> float:
    """Resolve a cap trace at round ``r`` (same forms as scenario budgets).

    ``BudgetProvider``s are first-class cap traces: anything with a
    ``budget_at`` method resolves through it — the same duck-typing
    ``repro.cluster.budget.as_provider`` coerces on, so one provider
    object can drive both the cluster budget and a domain cap.
    """
    budget_at = getattr(trace, "budget_at", None)
    if budget_at is not None and callable(budget_at):
        return float(budget_at(r))
    if isinstance(trace, (int, float)):
        return float(trace)
    if callable(trace):
        return float(trace(r))
    if len(trace) == 0:
        raise ValueError("empty cap trace")
    return float(trace[min(r, len(trace) - 1)])


@dataclasses.dataclass(frozen=True)
class PowerDomain:
    """One named domain in the facility tree.

    Exactly one of ``children`` / ``nodes`` is non-empty: an *internal*
    domain caps the union of its children, a *leaf* domain owns node-id
    ranges directly.  ``cap`` is the domain's max total draw (watts) — a
    trace resolved per round via :func:`cap_trace_at`.
    """

    name: str
    cap: CapTrace
    children: tuple["PowerDomain", ...] = ()
    #: half-open [lo, hi) node-id ranges (leaves only)
    nodes: tuple[tuple[int, int], ...] = ()

    def __post_init__(self):
        if bool(self.children) == bool(self.nodes):
            raise ValueError(
                f"domain {self.name!r} must have children xor node ranges"
            )
        for lo, hi in self.nodes:
            if not 0 <= lo < hi:
                raise ValueError(
                    f"domain {self.name!r}: bad node range [{lo}, {hi})"
                )
        if isinstance(self.cap, (int, float)) and self.cap <= 0:
            raise ValueError(f"domain {self.name!r}: cap must be positive")

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def cap_at(self, r: int) -> float:
        return cap_trace_at(self.cap, r)


class PowerTopology:
    """Validated domain tree with vectorized node → leaf interning.

    ``domains`` lists every domain in DFS preorder; ``index`` maps name →
    preorder id, ``parent[i]`` is the id of ``domains[i]``'s parent (-1 for
    the root), and ``leaf_ids`` the ids of the leaves.  Construction
    validates name uniqueness and leaf-range disjointness; passing
    ``n_nodes`` additionally validates *coverage* — the leaf ranges must
    tile ``[0, n_nodes)`` exactly, with no gap at any depth.
    """

    def __init__(self, root: PowerDomain, n_nodes: int | None = None):
        self.root = root
        self.domains: list[PowerDomain] = []
        self.parent: np.ndarray
        self.index: dict[str, int] = {}
        parents: list[int] = []

        def visit(d: PowerDomain, parent_id: int) -> None:
            if d.name in self.index:
                raise ValueError(f"duplicate domain name {d.name!r}")
            my_id = len(self.domains)
            self.index[d.name] = my_id
            self.domains.append(d)
            parents.append(parent_id)
            for c in d.children:
                visit(c, my_id)

        visit(root, -1)
        self.parent = np.asarray(parents, dtype=np.int32)
        #: per-domain tree depth (root = 0), preorder-indexed
        self.depth = np.zeros(len(self.domains), dtype=np.int32)
        for i in range(1, len(self.domains)):
            self.depth[i] = self.depth[self.parent[i]] + 1
        self.leaf_ids = np.array(
            [i for i, d in enumerate(self.domains) if d.is_leaf],
            dtype=np.int32,
        )

        # flatten leaf ranges, sorted by lo, and check disjointness
        spans = [
            (lo, hi, i)
            for i in self.leaf_ids
            for lo, hi in self.domains[i].nodes
        ]
        spans.sort()
        for (lo0, hi0, i0), (lo1, hi1, i1) in zip(spans, spans[1:]):
            if lo1 < hi0:
                raise ValueError(
                    f"node ranges overlap: [{lo0}, {hi0}) of "
                    f"{self.domains[i0].name!r} and [{lo1}, {hi1}) of "
                    f"{self.domains[i1].name!r}"
                )
        self._span_lo = np.array([s[0] for s in spans], dtype=np.int64)
        self._span_hi = np.array([s[1] for s in spans], dtype=np.int64)
        self._span_leaf = np.array([s[2] for s in spans], dtype=np.int32)
        #: node count the leaf ranges were validated to cover (None = unchecked)
        self.n_nodes = n_nodes
        if n_nodes is not None:
            self._validate_coverage(n_nodes)

    def _validate_coverage(self, n_nodes: int) -> None:
        """Leaf ranges must tile ``[0, n_nodes)`` exactly: no gaps between
        consecutive (sorted, already disjoint) spans, starting at 0 and
        ending at ``n_nodes``."""
        if n_nodes <= 0:
            raise ValueError(f"n_nodes must be positive, got {n_nodes}")
        if not len(self._span_lo):
            raise ValueError("topology has no leaf node ranges")
        if self._span_lo[0] != 0:
            raise ValueError(
                f"leaf ranges leave nodes [0, {self._span_lo[0]}) uncovered"
            )
        gaps = np.flatnonzero(self._span_lo[1:] != self._span_hi[:-1])
        if len(gaps):
            i = int(gaps[0])
            raise ValueError(
                f"leaf ranges leave nodes [{self._span_hi[i]}, "
                f"{self._span_lo[i + 1]}) uncovered"
            )
        if self._span_hi[-1] != n_nodes:
            raise ValueError(
                f"leaf ranges cover [0, {self._span_hi[-1]}) but "
                f"n_nodes={n_nodes}"
            )

    def __len__(self) -> int:
        return len(self.domains)

    def __iter__(self) -> Iterator[PowerDomain]:
        return iter(self.domains)

    @property
    def names(self) -> list[str]:
        return [d.name for d in self.domains]

    def leaf_of(self, node_ids) -> np.ndarray:
        """Vectorized node id → owning-leaf domain id.

        One ``searchsorted`` over the sorted range bounds; raises on any id
        no leaf owns.
        """
        ids = np.asarray(node_ids, dtype=np.int64)
        pos = np.searchsorted(self._span_lo, ids, side="right") - 1
        bad = (pos < 0) | (ids >= self._span_hi[np.clip(pos, 0, None)])
        if bad.any():
            orphan = ids[bad][:5].tolist()
            raise ValueError(f"node ids {orphan} outside every leaf domain")
        return self._span_leaf[pos]

    def owns(self, node_id: int) -> bool:
        try:
            self.leaf_of([node_id])
            return True
        except ValueError:
            return False

    def require_leaf(self, name: str) -> int:
        """Domain id of leaf ``name``; raises on unknown or non-leaf names.
        The one arrival-placement validator shared by scenario build-time
        checks and the engine's event application."""
        i = self.index.get(name)
        if i is None or not self.domains[i].is_leaf:
            raise ValueError(f"unknown or non-leaf domain {name!r}")
        return i

    def cap_at(self, r: int, overrides: dict | None = None) -> np.ndarray:
        """Per-domain caps at round ``r`` (preorder), with id-keyed
        ``overrides`` (e.g. persisted ``DomainCapChange`` events) applied."""
        caps = np.array(
            [d.cap_at(r) for d in self.domains], dtype=np.float64
        )
        for i, cap in (overrides or {}).items():
            caps[i] = cap
        return caps

    def aggregate_leaves(self, leaf_values: np.ndarray) -> np.ndarray:
        """Sum per-leaf values up the tree → per-domain totals (preorder).

        ``leaf_values`` is indexed by domain id (non-leaf slots ignored);
        one reverse-preorder sweep accumulates children into parents.
        """
        out = np.zeros(len(self.domains), dtype=np.float64)
        out[self.leaf_ids] = np.asarray(leaf_values, dtype=np.float64)[
            self.leaf_ids
        ]
        for i in range(len(self.domains) - 1, 0, -1):
            out[self.parent[i]] += out[i]
        return out

    def derate_factors(
        self, spend: np.ndarray, allowed: np.ndarray
    ) -> np.ndarray:
        """Per-domain effective derate factor clawing spend back under caps.

        ``spend``/``allowed`` are preorder-indexed per-domain totals (spend
        already aggregated up the tree).  A domain's own factor is
        ``min(1, allowed/spend)``; the *effective* factor also honours every
        ancestor (a rack inside an over-drawn room must derate too), so one
        preorder pass takes ``min(own, parent_effective)`` — parents precede
        children in preorder.  Scaling each leaf's spend by its effective
        factor guarantees every domain's total lands at or under ``allowed``
        (spend aggregates linearly, and factors only shrink down the tree).
        """
        spend = np.asarray(spend, dtype=np.float64)
        allowed = np.asarray(allowed, dtype=np.float64)
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            own = np.where(
                spend > allowed, np.divide(allowed, np.maximum(spend, 1e-300)), 1.0
            )
        own = np.clip(np.where(np.isfinite(own), own, 1.0), 0.0, 1.0)
        eff = own.copy()
        for i in range(1, len(self.domains)):
            eff[i] = min(eff[i], eff[self.parent[i]])
        return eff

    # -- builders ------------------------------------------------------------

    @staticmethod
    def single_root(
        n_nodes: int, cap: CapTrace, name: str = "cluster"
    ) -> "PowerTopology":
        """Degenerate topology: one domain owning every node — the parity
        anchor (hierarchical solve == flat grouped solve, bit-for-bit)."""
        return PowerTopology(
            PowerDomain(name=name, cap=cap, nodes=((0, n_nodes),))
        )

    @staticmethod
    def uniform_racks(
        n_nodes: int,
        n_racks: int,
        rack_cap: CapTrace,
        site_cap: CapTrace | None = None,
        name: str = "site",
    ) -> "PowerTopology":
        """Two-level site → rack tree with contiguous equal node ranges.

        ``site_cap`` defaults to unconstrained at the root (1e18 W), i.e.
        only the rack/PDU caps bind.
        """
        if not 1 <= n_racks <= n_nodes:
            raise ValueError(f"need 1 <= n_racks={n_racks} <= n_nodes={n_nodes}")
        bounds = np.linspace(0, n_nodes, n_racks + 1).astype(int)
        racks = tuple(
            PowerDomain(
                name=f"rack{k}",
                cap=rack_cap,
                nodes=((int(bounds[k]), int(bounds[k + 1])),),
            )
            for k in range(n_racks)
        )
        return PowerTopology(
            PowerDomain(
                name=name,
                cap=1e18 if site_cap is None else site_cap,
                children=racks,
            ),
            n_nodes=n_nodes,
        )

    #: default level names for :meth:`uniform_tree` (depth below the root)
    LEVEL_NAMES = ("row", "pdu", "chassis", "rack", "shelf")

    @staticmethod
    def uniform_tree(
        n_nodes: int,
        fanouts: Sequence[int],
        caps: Sequence[CapTrace],
        name: str = "site",
        level_names: Sequence[str] | None = None,
    ) -> "PowerTopology":
        """Balanced arbitrary-depth tree: site → row → PDU → ... → leaf.

        ``fanouts[d]`` is the child count of every level-``d`` domain, so
        the tree has ``len(fanouts) + 1`` levels and ``prod(fanouts)``
        leaves; ``caps[0]`` is the root cap and ``caps[d + 1]`` the cap
        trace shared by every level-``d+1`` domain (any :data:`CapTrace`
        form, including a ``BudgetProvider``).  Leaves own contiguous,
        near-equal node ranges tiling ``[0, n_nodes)`` exactly —
        coverage-validated at build time.  Level names default to
        :data:`LEVEL_NAMES` (``site → row → pdu → ...``); domain ``k`` at
        level ``d`` is named ``f"{level_names[d - 1]}{k}"``.
        """
        fanouts = [int(f) for f in fanouts]
        if not fanouts or any(f < 1 for f in fanouts):
            raise ValueError(f"fanouts must be positive, got {fanouts}")
        if len(caps) != len(fanouts) + 1:
            raise ValueError(
                f"need len(caps) == len(fanouts) + 1 (root + one per "
                f"level), got {len(caps)} caps for {len(fanouts)} fanouts"
            )
        n_leaves = int(np.prod(fanouts))
        if not 1 <= n_leaves <= n_nodes:
            raise ValueError(
                f"need 1 <= prod(fanouts)={n_leaves} <= n_nodes={n_nodes}"
            )
        if level_names is None:
            level_names = [
                PowerTopology.LEVEL_NAMES[d]
                if d < len(PowerTopology.LEVEL_NAMES)
                else f"l{d + 1}"
                for d in range(len(fanouts))
            ]
        if len(level_names) != len(fanouts):
            raise ValueError("need one level name per fanout level")
        bounds = np.linspace(0, n_nodes, n_leaves + 1).astype(int)
        counters = [0] * len(fanouts)
        next_leaf = [0]

        def build(depth: int) -> PowerDomain:
            k = counters[depth - 1]
            counters[depth - 1] += 1
            if depth == len(fanouts):
                lo, hi = int(bounds[next_leaf[0]]), int(bounds[next_leaf[0] + 1])
                next_leaf[0] += 1
                return PowerDomain(
                    name=f"{level_names[depth - 1]}{k}",
                    cap=caps[depth],
                    nodes=((lo, hi),),
                )
            return PowerDomain(
                name=f"{level_names[depth - 1]}{k}",
                cap=caps[depth],
                children=tuple(
                    build(depth + 1) for _ in range(fanouts[depth])
                ),
            )

        root = PowerDomain(
            name=name,
            cap=caps[0],
            children=tuple(build(1) for _ in range(fanouts[0])),
        )
        return PowerTopology(root, n_nodes=n_nodes)
