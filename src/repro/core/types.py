"""Core datatypes for the EcoShift control plane.

The vocabulary follows the paper (§3.2): a *cluster* runs M applications
(jobs) under a cluster-wide budget; applications partition into *donors*
(draw below their cap, contributing to the reclaimed pool) and *receivers*
(can convert extra watts into speedup).  A policy maps a reclaimed budget B
to per-receiver upgraded cap pairs ``(c, g) >= (c_bar, g_bar)``.

On the TPU adaptation (DESIGN.md §2) ``c`` is the *host* power cap and ``g``
is the *chip* power cap; the math is identical, so we keep the paper's (c, g)
naming throughout.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Cap grids and system specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CapGrid:
    """Discrete feasible cap grid (inclusive ranges, fixed step)."""

    cpu_min: float
    cpu_max: float
    gpu_min: float
    gpu_max: float
    step: float = 25.0

    @property
    def cpu_levels(self) -> np.ndarray:
        return np.arange(self.cpu_min, self.cpu_max + 0.5 * self.step, self.step)

    @property
    def gpu_levels(self) -> np.ndarray:
        return np.arange(self.gpu_min, self.gpu_max + 0.5 * self.step, self.step)

    def pairs(self) -> np.ndarray:
        """All (c, g) pairs, shape [n_cpu * n_gpu, 2]."""
        c, g = np.meshgrid(self.cpu_levels, self.gpu_levels, indexing="ij")
        return np.stack([c.ravel(), g.ravel()], axis=-1)

    def clamp(self, c: float, g: float) -> tuple[float, float]:
        return (
            float(np.clip(c, self.cpu_min, self.cpu_max)),
            float(np.clip(g, self.gpu_min, self.gpu_max)),
        )

    def snap(self, c: float, g: float) -> tuple[float, float]:
        """Snap a continuous cap pair down onto the grid (never exceeds)."""
        c, g = self.clamp(c, g)
        c = self.cpu_min + np.floor((c - self.cpu_min) / self.step) * self.step
        g = self.gpu_min + np.floor((g - self.gpu_min) / self.step) * self.step
        return float(c), float(g)


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """One of the paper's two evaluation systems (or a TPU pod analogue)."""

    name: str
    grid: CapGrid
    #: default initial (uniform) caps for emulation sweeps
    init_cpu: float
    init_gpu: float
    #: measurement-noise sigma as a fraction of runtime (repeat-to-repeat)
    noise_sigma: float = 0.004


#: Paper System 1: 2x Xeon 8380 + A100-40GB.  Initial caps 140/150 W (Fig. 5).
SYSTEM_1 = SystemSpec(
    name="system1-a100",
    grid=CapGrid(cpu_min=100.0, cpu_max=400.0, gpu_min=100.0, gpu_max=400.0, step=25.0),
    init_cpu=140.0,
    init_gpu=150.0,
)

#: Paper System 2: 2x Xeon 8468 + H100-80GB.  Initial caps 300/300 W (Fig. 7).
SYSTEM_2 = SystemSpec(
    name="system2-h100",
    grid=CapGrid(cpu_min=200.0, cpu_max=500.0, gpu_min=100.0, gpu_max=500.0, step=25.0),
    init_cpu=300.0,
    init_gpu=300.0,
)

#: TPU v5e pod analogue: host power domain + chip power domain (DESIGN.md §2).
SYSTEM_TPU_V5E = SystemSpec(
    name="tpu-v5e-pod",
    grid=CapGrid(cpu_min=150.0, cpu_max=450.0, gpu_min=100.0, gpu_max=250.0, step=10.0),
    init_cpu=250.0,
    init_gpu=170.0,
)

SYSTEMS: Mapping[str, SystemSpec] = {
    s.name: s for s in (SYSTEM_1, SYSTEM_2, SYSTEM_TPU_V5E)
}


# ---------------------------------------------------------------------------
# Applications and allocations
# ---------------------------------------------------------------------------

#: Paper §2 sensitivity classes.
CLASS_CPU = "C"
CLASS_GPU = "G"
CLASS_BOTH = "B"
CLASS_NONE = "N"
SENSITIVITY_CLASSES = (CLASS_CPU, CLASS_GPU, CLASS_BOTH, CLASS_NONE)


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """A job on the cluster: a name, a sensitivity class and a surface id."""

    name: str
    sclass: str
    surface_id: str

    def __post_init__(self):
        if self.sclass not in SENSITIVITY_CLASSES:
            raise ValueError(f"unknown sensitivity class {self.sclass!r}")


@dataclasses.dataclass(frozen=True)
class Allocation:
    """Result of a policy: per-receiver upgraded caps (>= baseline caps)."""

    #: app name -> (cpu_cap, gpu_cap) after distribution
    caps: Mapping[str, tuple[float, float]]
    #: watts actually spent out of the reclaimed budget
    spent: float
    #: policy-predicted average relative improvement (may be NaN for heuristics)
    predicted_improvement: float = float("nan")

    def extra_power(self, baselines: Mapping[str, tuple[float, float]]) -> float:
        tot = 0.0
        for name, (c, g) in self.caps.items():
            c0, g0 = baselines[name]
            tot += (c - c0) + (g - g0)
        return tot


#: canonical set of ``FusedRoundStats.fallback_reason`` values ("" = no
#: fallback).  Docs (DESIGN.md §17) and the emitting code in ``core/mckp.py``
#: are drift-guarded against this set in ``tests/test_faults.py``.
FUSED_FALLBACK_REASONS = frozenset(
    {"off_lattice", "grid_overflow", "no_feasible_root", "empty"}
)


@dataclasses.dataclass(frozen=True)
class FusedRoundStats:
    """Counters of the device-resident fused round path (DESIGN.md §14/§17).

    Snapshot of a fused controller's warm device state: rounds that ran
    fully on device, host fallbacks (off-lattice keys, oversized grids,
    infeasible roots — structure changes stay fused since the
    capacity-slack banks of §17), cold host rebuilds of the resident
    banks, device-side compactions (layout changes repacked by on-device
    gather instead of a host rebuild), dirty rows patched by the donated
    delta uploads, rounds that short-circuited host assembly on an
    unchanged decision vector, the last round's slack occupancy, and
    cumulative seconds inside the jitted pipeline.
    """

    rounds: int = 0
    fallbacks: int = 0
    #: cold host-side bank builds + full uploads (first fused round of a
    #: shape family; never fired by churn once the banks are resident)
    rebuilds: int = 0
    #: device-side bank repacks: layout changes (leaf set / pad growth /
    #: topology edits) served by a jitted gather of the clean rows plus a
    #: dirty-row scatter — the round still runs fused (DESIGN.md §17)
    compactions: int = 0
    row_uploads: int = 0
    short_circuits: int = 0
    #: most recent round's occupancy of the capacity-slack bank layout:
    #: max over the padded dims of used/padded (1.0 = slack exhausted,
    #: the next structural growth compacts into bigger tiers)
    slack_utilization: float = 0.0
    device_s: float = 0.0
    #: why the most recent fused attempt fell back to host ("" = it didn't):
    #: "off_lattice" | "grid_overflow" | "no_feasible_root" | "empty"
    #: (the historical "structure_change" fallback is retired — structure
    #: churn patches or compacts the resident banks and stays fused)
    fallback_reason: str = ""

    @property
    def attempts(self) -> int:
        return self.rounds + self.fallbacks

    @property
    def fused_fraction(self) -> float:
        """Share of attempted fused rounds that stayed on device."""
        n = self.attempts
        return self.rounds / n if n else 0.0


@dataclasses.dataclass
class EmulationResult:
    """Outcome of one emulated redistribution round."""

    policy: str
    #: per-app relative runtime reduction vs the no-distribution baseline
    improvements: dict[str, float]
    allocation: Allocation
    budget: float

    @property
    def avg_improvement(self) -> float:
        vals = list(self.improvements.values())
        return float(np.mean(vals)) if vals else 0.0

    @property
    def jain_index(self) -> float:
        from repro.core import metrics

        return metrics.jain_index(np.array(list(self.improvements.values())))


@dataclasses.dataclass(frozen=True, eq=False)
class ReceiverBatch:
    """Columnar receiver view handed to group-collapsing controllers.

    The cluster engine materializes this instead of per-instance AppSpec
    lists: aligned name/surface-id lists, a [n, 2] baseline-caps array and
    one surface *object* per receiver.  Receivers sharing a surface
    identity and baseline collapse into one option table / DP super-stage
    (DESIGN.md §11).

    **Delta contract** (DESIGN.md §13): batches carry a process-globally
    unique monotone ``seq`` (so a controller reused across sims can never
    confuse their chains).  When the engine derived this batch by patching the previous
    one, ``prev_seq`` names it, ``delta`` lists the positions whose
    surface/baseline changed (new receivers included), and ``removed`` the
    instance names no longer present — so an incremental controller whose
    grouping state is warm at ``prev_seq`` applies O(churn) updates.
    ``delta is None`` means "no provable bound": rebuild from scratch.
    """

    names: Sequence[str]
    surface_ids: Sequence[str]
    baselines: np.ndarray  # [n, 2] float64
    surfaces: Sequence  # PowerSurface per receiver, identity-groupable
    #: per-receiver owning-leaf power-domain id (preorder index into the
    #: sim's PowerTopology); None when the cluster has no topology
    domain_ids: np.ndarray | None = None
    #: monotone batch sequence number (0 = standalone batch)
    seq: int = 0
    #: seq of the batch this one was delta-derived from (None = fresh)
    prev_seq: int | None = None
    #: positions changed vs the prev_seq batch; None = unbounded change
    delta: tuple[int, ...] | None = None
    #: names present at prev_seq but absent here
    removed: tuple[str, ...] = ()

    def __len__(self) -> int:
        return len(self.names)

    def baselines_map(self) -> dict[str, tuple[float, float]]:
        """name -> baseline caps dict, memoized on the (reused) batch."""
        m = self.__dict__.get("_baselines_map")
        if m is None:
            pairs = self.baselines.tolist()
            m = dict(zip(self.names, map(tuple, pairs)))
            object.__setattr__(self, "_baselines_map", m)
        return m


def validate_allocation(
    alloc: Allocation,
    baselines: Mapping[str, tuple[float, float]],
    budget: float,
    grid: CapGrid,
    *,
    atol: float = 1e-6,
) -> None:
    """Invariant checks shared by tests and the emulator.

    1. every allocated cap is >= its baseline (monotonic upgrade model, §6.2)
    2. every cap is inside the feasible grid range
    3. total extra power <= budget
    """
    names = list(alloc.caps.keys())
    if not names:
        if 0.0 > budget + atol:
            raise ValueError(f"allocation spends 0.0 W > budget {budget} W")
        return
    cg = np.array([alloc.caps[nm] for nm in names], dtype=np.float64)
    base = np.array([baselines[nm] for nm in names], dtype=np.float64)
    below = (cg < base - atol).any(axis=1)
    if below.any():
        i = int(np.flatnonzero(below)[0])
        c, g = cg[i]
        c0, g0 = base[i]
        raise ValueError(
            f"{names[i]}: caps ({c},{g}) below baseline ({c0},{g0})"
        )
    bad_c = (cg[:, 0] < grid.cpu_min - atol) | (cg[:, 0] > grid.cpu_max + atol)
    if bad_c.any():
        i = int(np.flatnonzero(bad_c)[0])
        raise ValueError(f"{names[i]}: cpu cap {cg[i, 0]} outside grid")
    bad_g = (cg[:, 1] < grid.gpu_min - atol) | (cg[:, 1] > grid.gpu_max + atol)
    if bad_g.any():
        i = int(np.flatnonzero(bad_g)[0])
        raise ValueError(f"{names[i]}: gpu cap {cg[i, 1]} outside grid")
    extra = float(np.cumsum((cg - base).sum(axis=1))[-1])
    if extra > budget + atol:
        raise ValueError(f"allocation spends {extra} W > budget {budget} W")


def as_receiver_order(receivers: Sequence[AppSpec]) -> list[AppSpec]:
    """Stable deterministic ordering used by DP and brute force alike."""
    return sorted(receivers, key=lambda a: a.name)
