"""Pallas TPU kernels: <name>.py + ops.py + ref.py per kernel."""
