"""Flash-decode Pallas kernel: one-token GQA attention over a KV cache.

TPU mapping:
 * grid = (B, Hkv, n_kv_blocks): one program per (sequence, KV head)
   accumulating online-softmax state across KV blocks.
 * All ``group = Hq/Hkv`` query heads of a KV head ride TOGETHER in the
   sublane dimension — q block shape (group, D) — so GQA needs no repeated
   KV reads and the MXU sees a [group, D] x [D, block_k] matmul instead of
   a starved [1, D] row per program.
 * Per-sequence validity (``lengths``) masks from an absolute iota; blocks
   entirely past the length short-circuit.

Validated in interpret mode vs ``ref.decode_attention_reference``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # [B] s32 (full, VMEM)
    q_ref,  # [1, 1, group, D]
    k_ref,  # [1, block_k, 1, D]
    v_ref,  # [1, block_k, 1, D]
    o_ref,  # [1, 1, group, D]
    m_scr,  # [group] f32
    l_scr,  # [group] f32
    acc_scr,  # [group, D] f32
    *,
    block_k: int,
    scale: float,
    softcap: float | None,
    window: int | None,
    n_kv_blocks: int,
):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[bi]  # valid KV entries for this sequence
    k_start = ki * block_k
    reachable = k_start < length
    if window is not None:
        reachable = jnp.logical_and(reachable, k_start + block_k > length - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [group, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [block_k, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [group, block_k]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1
        )
        mask = k_pos < length
        if window is not None:
            mask &= length - k_pos <= window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "window", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,  # [B, Hq, D]
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B] s32
    *,
    softcap: float | None = None,
    window: int | None = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    block_k = min(block_k, s)
    n_k = pl.cdiv(s, block_k)
    if s % block_k:
        pad = n_k * block_k - s
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # [B, Hq, D] -> [B, Hkv, group, D] so a KV head's q-group is contiguous
    qg = q.reshape(b, hkv, group, d)
    kernel = functools.partial(
        _decode_kernel,
        block_k=block_k,
        scale=scale,
        softcap=softcap,
        window=window,
        n_kv_blocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths, whole array
            pl.BlockSpec((1, 1, group, d), lambda b_, h, ki: (b_, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h, ki: (b_, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h, ki: (b_, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h, ki: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
