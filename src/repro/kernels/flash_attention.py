"""Fused GQA flash-attention Pallas kernel (train / prefill path).

TPU mapping:
 * grid = (B, Hq, n_q_blocks, n_kv_blocks); the kv axis is innermost, so a
   (batch, head, q-block) program accumulates online-softmax state across
   its kv blocks in VMEM scratch (running max m, denominator l, accum o).
 * GQA without materializing repeated KV: the BlockSpec index_map sends
   query head ``h`` to KV head ``h // group`` — zero-copy head broadcast.
 * Block shapes are (block_q x head_dim) and (block_k x head_dim) VMEM
   tiles; head_dim rides the 128-lane minor dimension, block_q the sublane
   dimension (multiples of 8).  Logits tiles are f32 in VREGs/VMEM.
 * Causal + sliding-window masking is applied from absolute iota positions;
   fully-masked kv blocks still traverse the grid (Pallas grids are dense)
   but short-circuit via @pl.when on a block-level bound check.

Validated in interpret mode against ``ref.mha_reference`` over
shape/dtype/window sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, block_q, 1, D]
    k_ref,  # [1, block_k, 1, D]
    v_ref,  # [1, block_k, 1, D]
    o_ref,  # [1, block_q, 1, D]
    m_scr,  # [block_q] f32 scratch
    l_scr,  # [block_q] f32
    acc_scr,  # [block_q, D] f32
    *,
    block_q: int,
    block_k: int,
    seq_kv: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level reachability: skip kv blocks entirely above the causal
    # diagonal or entirely left of the sliding window
    reachable = jnp.asarray(True)
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window is not None:
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 >= q_start - window + 1
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # [bq, bk]
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    n_q = pl.cdiv(sq, block_q)
    n_k = pl.cdiv(skv, block_k)
    # pad sequence dims up to block multiples (mask handles the tail)
    if sq % block_q:
        q = jnp.pad(q, ((0, 0), (0, n_q * block_q - sq), (0, 0), (0, 0)))
    if skv % block_k:
        pad = n_k * block_k - skv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_kv=skv,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        n_kv_blocks=n_k,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h, qi, ki, g=group: (b_, ki, h // g, 0),
            ),
            pl.BlockSpec(
                (1, block_k, 1, d),
                lambda b_, h, qi, ki, g=group: (b_, ki, h // g, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda b_, h, qi, ki: (b_, qi, h, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, n_q * block_q, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
