"""Pallas TPU kernel for the EcoShift cluster-DP stage (paper §3.2.2).

One DP stage is a tropical ((max,+)-semiring) convolution over the budget
grid:

    out[b] = max_{0<=k<=b} dp[b-k] + f[k],        b, k in [0, NB)

with NB = budget/granularity + 1 (the paper uses 1 W granularity, so NB can
reach ~1.4e4 for the Fig. 8 sweeps and far more for pod-scale budgets; the
full cluster solve is ``N_receivers`` such stages — promoted here from the
paper's host-Python loop to an accelerator kernel, see DESIGN.md §8.1).

TPU mapping
-----------
(max,+) cannot use the MXU (no tropical matmul), so this is a VPU kernel:

 * ``dp`` is small (NB fp32 ≈ 56 KB at NB=14001): we keep the *whole*
   left-padded operand resident in VMEM (no HBM re-streaming per block).
 * The output is tiled into ``block_b``-wide vector blocks (multiple of the
   128-lane VPU width); the grid iterates over output blocks.
 * For each shift ``k`` the candidate vector ``dp[b0-k : b0-k+block_b]`` is
   a *contiguous* VMEM slice (the Toeplitz structure turns the gather into a
   sliding window), so the inner loop is: contiguous load -> broadcast add
   f[k] -> elementwise max.  ``block_b`` elements of useful work per loop
   iteration, no scatter/gather.
 * Argmax is tracked alongside (smallest maximizing k, matching the numpy
   reference tie-break).

Left-padding ``dp`` with NB entries of -inf makes every slice in-bounds:
index ``NB + b0 - k`` is >= 1 for k <= NB-1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxplus_kernel(dp_pad_ref, f_ref, out_ref, arg_ref, *, block_b: int, nb: int):
    i = pl.program_id(0)
    b0 = i * block_b

    def body(k, carry):
        acc, arg = carry
        # contiguous sliding-window slice: dp[b - k] for b in [b0, b0+block_b)
        col = dp_pad_ref[pl.dslice(nb + b0 - k, block_b)]
        fk = f_ref[pl.dslice(k, 1)]  # [1], broadcasts
        cand = col + fk
        better = cand > acc
        acc = jnp.where(better, cand, acc)
        arg = jnp.where(better, k, arg)
        return acc, arg

    acc0 = jnp.full((block_b,), -jnp.inf, dtype=out_ref.dtype)
    arg0 = jnp.zeros((block_b,), dtype=jnp.int32)
    acc, arg = jax.lax.fori_loop(0, nb, body, (acc0, arg0))
    out_ref[...] = acc
    arg_ref[...] = arg


def _maxplus_kernel_batched(
    dp_pad_ref, f_ref, out_ref, arg_ref, *, block_b: int, nb: int
):
    i = pl.program_id(1)
    b0 = i * block_b

    def body(k, carry):
        acc, arg = carry
        # per-row contiguous sliding window: dp[r, b - k] for the block
        col = dp_pad_ref[0, pl.dslice(nb + b0 - k, block_b)]
        fk = f_ref[0, pl.dslice(k, 1)]  # [1], broadcasts
        cand = col + fk
        better = cand > acc
        acc = jnp.where(better, cand, acc)
        arg = jnp.where(better, k, arg)
        return acc, arg

    acc0 = jnp.full((block_b,), -jnp.inf, dtype=out_ref.dtype)
    arg0 = jnp.zeros((block_b,), dtype=jnp.int32)
    acc, arg = jax.lax.fori_loop(0, nb, body, (acc0, arg0))
    out_ref[0, ...] = acc
    arg_ref[0, ...] = arg


def _maxplus_stage_kernel_batched(
    dp_pad_ref, kb_ref, vb_ref, out_ref, arg_ref, *, block_b: int, nb: int,
    k_opts: int,
):
    """Sparse-option (max,+) DP stage with a backpointer output.

    Where :func:`_maxplus_kernel_batched` slides over every grid offset,
    this kernel iterates only the stage's ``k_opts`` *options* — spend
    offsets ``kb[j]`` (descending) with values ``vb[j]`` — and emits, per
    output position, the winning option index ``j`` (first maximizer in
    option order, i.e. the largest spend among ties: the sparse solvers'
    dict-DP tie-break).  That argmax row is the *backpointer table* the
    fused device-resident round gathers through instead of unwinding the
    DP in host Python (DESIGN.md §14).
    """
    i = pl.program_id(1)
    b0 = i * block_b

    def body(j, carry):
        acc, arg = carry
        k = kb_ref[0, j]
        # per-option contiguous sliding window: dp[b - kb[j]] for the block
        col = dp_pad_ref[0, pl.dslice(nb + b0 - k, block_b)]
        vj = vb_ref[0, pl.dslice(j, 1)]  # [1], broadcasts
        cand = col + vj
        better = cand > acc
        acc = jnp.where(better, cand, acc)
        arg = jnp.where(better, j, arg)
        return acc, arg

    acc0 = jnp.full((block_b,), -jnp.inf, dtype=out_ref.dtype)
    arg0 = jnp.zeros((block_b,), dtype=jnp.int32)
    acc, arg = jax.lax.fori_loop(0, k_opts, body, (acc0, arg0))
    out_ref[0, ...] = acc
    arg_ref[0, ...] = arg


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def maxplus_stage_pallas_batched(
    dp: jax.Array,
    kb: jax.Array,
    vb: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Row-batched sparse-option (max,+) stage with backpointers.

    dp: [R, NB] float; kb: [R, K] int32 spend offsets in [0, NB]
    (descending per row); vb: [R, K] option values (pad options with
    ``vb = -inf``, ``kb = 0``).  Returns

        out[r, b] = max_j dp[r, b - kb[r, j]] + vb[r, j]
        arg[r, b] = first maximizing j (int32)

    with out-of-range gathers (kb[j] > b) reading -inf.  Unlike the dense
    :func:`maxplus_conv_pallas_batched` this keeps the input dtype
    (float64 in interpret mode drives the bit-for-bit fused solver path;
    TPU compiles the same kernel in float32 for the dense paths).
    """
    if dp.ndim != 2 or kb.shape != vb.shape or kb.shape[0] != dp.shape[0]:
        raise ValueError(
            f"bad shapes dp={dp.shape} kb={kb.shape} vb={vb.shape}"
        )
    r, nb = dp.shape
    k_opts = kb.shape[1]
    vb = vb.astype(dp.dtype)
    kb = kb.astype(jnp.int32)
    nblocks = pl.cdiv(nb, block_b)
    nb_pad = nblocks * block_b
    neg = jnp.asarray(-jnp.inf, dp.dtype)
    # left pad NB (kb <= NB stays in-bounds), right pad to the block multiple
    dp_pad = jnp.concatenate(
        [
            jnp.full((r, nb), neg),
            dp,
            jnp.full((r, nb_pad - nb), neg),
        ],
        axis=1,
    )

    out, arg = pl.pallas_call(
        functools.partial(
            _maxplus_stage_kernel_batched, block_b=block_b, nb=nb,
            k_opts=k_opts,
        ),
        grid=(r, nblocks),
        in_specs=[
            pl.BlockSpec((1, dp_pad.shape[1]), lambda ri, i: (ri, 0)),
            pl.BlockSpec((1, k_opts), lambda ri, i: (ri, 0)),
            pl.BlockSpec((1, k_opts), lambda ri, i: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b), lambda ri, i: (ri, i)),
            pl.BlockSpec((1, block_b), lambda ri, i: (ri, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, nb_pad), dp.dtype),
            jax.ShapeDtypeStruct((r, nb_pad), jnp.int32),
        ],
        interpret=interpret,
    )(dp_pad, kb, vb)
    return out[:, :nb], arg[:, :nb]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def maxplus_conv_pallas_batched(
    dp: jax.Array,
    f: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Row-batched (max,+) convolution: one kernel launch for R rounds.

    dp, f: [R, NB].  out[r, b] = max_{k<=b} dp[r, b-k] + f[r, k], plus the
    per-row argmax — each row identical to :func:`maxplus_conv_pallas` on
    that row alone.  The grid adds a leading row dimension, so R
    independent DP stages (e.g. all dirty rack leaves of a hierarchical
    solve) share a single dispatch instead of a vmap of R launches.
    """
    if dp.ndim != 2 or dp.shape != f.shape:
        raise ValueError(f"dp/f must be equal-shape 2D, got {dp.shape} {f.shape}")
    r, nb = dp.shape
    dp = dp.astype(jnp.float32)
    f = f.astype(jnp.float32)
    nblocks = pl.cdiv(nb, block_b)
    nb_pad = nblocks * block_b
    neg = jnp.asarray(-jnp.inf, jnp.float32)
    dp_pad = jnp.concatenate(
        [
            jnp.full((r, nb), neg),
            dp,
            jnp.full((r, nb_pad - nb), neg),
        ],
        axis=1,
    )

    out, arg = pl.pallas_call(
        functools.partial(_maxplus_kernel_batched, block_b=block_b, nb=nb),
        grid=(r, nblocks),
        in_specs=[
            pl.BlockSpec((1, dp_pad.shape[1]), lambda ri, i: (ri, 0)),
            pl.BlockSpec((1, nb), lambda ri, i: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_b), lambda ri, i: (ri, i)),
            pl.BlockSpec((1, block_b), lambda ri, i: (ri, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, nb_pad), jnp.float32),
            jax.ShapeDtypeStruct((r, nb_pad), jnp.int32),
        ],
        interpret=interpret,
    )(dp_pad, f)
    return out[:, :nb], arg[:, :nb]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def maxplus_conv_pallas(
    dp: jax.Array,
    f: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """out[b] = max_{k<=b} dp[b-k] + f[k]; also returns argmax k (int32).

    dp, f: [NB] float32.  ``interpret=True`` runs the kernel body on CPU
    (the validation mode in this container); on a real TPU pass False.
    """
    if dp.ndim != 1 or dp.shape != f.shape:
        raise ValueError(f"dp/f must be equal-length 1D, got {dp.shape} {f.shape}")
    nb = dp.shape[0]
    dp = dp.astype(jnp.float32)
    f = f.astype(jnp.float32)
    nblocks = pl.cdiv(nb, block_b)
    nb_pad = nblocks * block_b
    neg = jnp.asarray(-jnp.inf, jnp.float32)
    # left pad NB (validity masking), right pad to the block multiple
    dp_pad = jnp.concatenate(
        [jnp.full((nb,), neg), dp, jnp.full((nb_pad - nb,), neg)]
    )

    out, arg = pl.pallas_call(
        functools.partial(_maxplus_kernel, block_b=block_b, nb=nb),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec(dp_pad.shape, lambda i: (0,)),  # whole padded dp in VMEM
            pl.BlockSpec(f.shape, lambda i: (0,)),  # whole f in VMEM
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb_pad,), jnp.float32),
            jax.ShapeDtypeStruct((nb_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(dp_pad, f)
    return out[:nb], arg[:nb]
