"""jit'd public wrappers for the Pallas kernels.

Every op auto-selects ``interpret=True`` off-TPU (this container is
CPU-only; interpret mode executes the kernel bodies with JAX semantics) and
compiles natively on TPU.  Reference semantics live in ``repro.kernels.ref``.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import mckp_dp as _mckp_dp


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def maxplus_conv(dp: jax.Array, f: jax.Array, *, block_b: int = 256):
    """(max,+)-convolution DP stage.  Returns (out, argmax_k)."""
    return _mckp_dp.maxplus_conv_pallas(
        dp, f, block_b=block_b, interpret=not _on_tpu()
    )


def maxplus_conv_batched(dp: jax.Array, f: jax.Array, *, block_b: int = 256):
    """Batched (max,+) stage: vmap of the Pallas kernel over a leading dim.

    dp, f: [R, NB].  Returns (out [R, NB], argmax_k [R, NB]).  Each stage
    of ``repro.core.mckp.solve_dense_jax_batch`` runs through this to solve
    many independent DP rounds (budget sweeps, scenario traces) at once.
    """
    interpret = not _on_tpu()
    return jax.vmap(
        lambda d, fr: _mckp_dp.maxplus_conv_pallas(
            d, fr, block_b=block_b, interpret=interpret
        )
    )(dp, f)


@functools.cache
def _maxplus_scan_fn(block_b: int, interpret: bool):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(f_groups, gids):
        def stage(dp, gid):
            out, arg = _mckp_dp.maxplus_conv_pallas(
                dp, f_groups[gid], block_b=block_b, interpret=interpret
            )
            return out, arg

        dp0 = jnp.zeros(f_groups.shape[1], dtype=f_groups.dtype)
        return jax.lax.scan(stage, dp0, gids)

    return run


def maxplus_scan(f_groups, stage_gids, *, block_b: int = 256):
    """Repeated-stage (max,+) DP scan over a group-id sequence.

    f_groups: [G, NB] per-behaviour-class dense curves; stage_gids: [N]
    int32, one class id per DP stage.  Each stage gathers its curve row and
    runs the Pallas (max,+) convolution, so N-receiver clusters with G
    distinct classes never materialize an [N, NB] curve matrix.  Returns
    (dp_final [NB], argmax_k [N, NB]) — bitwise equal to scanning the
    row-expanded matrix through ``maxplus_conv``.
    """
    import jax.numpy as jnp

    run = _maxplus_scan_fn(block_b, not _on_tpu())
    return run(f_groups, jnp.asarray(stage_gids))


def flash_attention(q, k, v, **kw):
    """Fused GQA attention (train/prefill).  See flash_attention.py."""
    from repro.kernels import flash_attention as _fa

    return _fa.flash_attention(q, k, v, interpret=not _on_tpu(), **kw)


def decode_attention(q, k_cache, v_cache, lengths, **kw):
    """Flash-decode GQA attention over a KV cache."""
    from repro.kernels import decode_attention as _da

    return _da.decode_attention(
        q, k_cache, v_cache, lengths, interpret=not _on_tpu(), **kw
    )


def rmsnorm(x, scale, *, eps: float = 1e-6):
    """Fused RMSNorm."""
    from repro.kernels import rmsnorm as _rn

    return _rn.rmsnorm(x, scale, eps=eps, interpret=not _on_tpu())
