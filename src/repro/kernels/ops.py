"""jit'd public wrappers for the Pallas kernels.

Every op auto-selects ``interpret=True`` off-TPU (this container is
CPU-only; interpret mode executes the kernel bodies with JAX semantics) and
compiles natively on TPU.  Reference semantics live in ``repro.kernels.ref``.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import mckp_dp as _mckp_dp


@functools.cache
def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.cache
def leaf_shard_mesh(n_devices: int):
    """1-D device mesh (axis ``"leaves"``) over the first ``n_devices``
    local devices.

    The fused round's batched leaf DPs ``shard_map`` over this axis: each
    [L, NB] DP row is independent, so splitting the [S, L, K] option
    banks leaf-wise across devices is bitwise-neutral — every device runs
    the identical per-row kernel and the frontier aggregation tree then
    reduces the gathered per-device partials (DESIGN.md §16).  Multi-host
    CPU smoke rides ``XLA_FLAGS=--xla_force_host_platform_device_count``.
    """
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:n_devices]), ("leaves",))


@functools.partial(jax.jit, static_argnames=("k_pad",))
def bank_compact(kb_old, vb_old, src_s, src_l, *, k_pad: int):
    """Device-side compaction of the fused round's resident option banks.

    When the bank *layout* changes (leaf set, padded dims, topology — see
    DESIGN.md §17) the surviving rows are repacked on device instead of
    rebuilt on the host: ``src_s``/``src_l`` are ``[S_new, L_new]`` int32
    gather maps into the old ``[S_old, L_old, K_old]`` banks (-1 marks a
    row with no clean source — it is initialized to the identity row
    ``kb = 0 / vb = [0, -inf, ...]`` and, if it carries real content, the
    caller scatters it afterwards via the donated row patch).  The option
    axis pads (or truncates) to ``k_pad``; a clean row's tail beyond its
    own option count is identity padding by construction, so both
    directions are exact.  Returns the new ``[S_new, L_new, k_pad]``
    (kb, vb) banks.  Pure gather/select — no values are recomputed, so a
    gathered row is bitwise the row a host rebuild would upload.
    """
    import jax.numpy as jnp

    valid = src_s >= 0
    ss = jnp.where(valid, src_s, 0)
    ll = jnp.where(valid, src_l, 0)
    kb_g = kb_old[ss, ll]  # [S_new, L_new, K_old]
    vb_g = vb_old[ss, ll]
    k_old = kb_old.shape[-1]
    if k_pad > k_old:
        pad = ((0, 0), (0, 0), (0, k_pad - k_old))
        kb_g = jnp.pad(kb_g, pad)
        vb_g = jnp.pad(vb_g, pad, constant_values=-jnp.inf)
    elif k_pad < k_old:
        kb_g = kb_g[..., :k_pad]
        vb_g = vb_g[..., :k_pad]
    kb_id = jnp.zeros_like(kb_g)
    vb_id = jnp.full_like(vb_g, -jnp.inf).at[..., 0].set(0.0)
    m = valid[..., None]
    return jnp.where(m, kb_g, kb_id), jnp.where(m, vb_g, vb_id)


def maxplus_conv(dp: jax.Array, f: jax.Array, *, block_b: int = 256):
    """(max,+)-convolution DP stage.  Returns (out, argmax_k)."""
    return _mckp_dp.maxplus_conv_pallas(
        dp, f, block_b=block_b, interpret=not _on_tpu()
    )


def maxplus_conv_batched(dp: jax.Array, f: jax.Array, *, block_b: int = 256):
    """Batched (max,+) stage: one row-batched Pallas launch.

    dp, f: [R, NB].  Returns (out [R, NB], argmax_k [R, NB]) — each row
    bitwise what ``maxplus_conv`` computes for it alone (the kernel body
    is identical; the grid just grows a leading row dimension).  Each
    stage of ``repro.core.mckp.solve_dense_jax_batch`` and of the batched
    hierarchical leaf solve runs through this to advance many independent
    DPs in a single dispatch.
    """
    return _mckp_dp.maxplus_conv_pallas_batched(
        dp, f, block_b=block_b, interpret=not _on_tpu()
    )


@functools.cache
def _maxplus_scan_batched_fn(block_b: int, interpret: bool):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(f_groups, gids):
        # f_groups: [L, G, NB]; gids: [L, N]
        n_leaves = f_groups.shape[0]
        rows_idx = jnp.arange(n_leaves)

        def stage(dp, gid_col):  # dp: [L, NB]; gid_col: [L]
            rows = f_groups[rows_idx, gid_col]
            out, arg = _mckp_dp.maxplus_conv_pallas_batched(
                dp, rows, block_b=block_b, interpret=interpret
            )
            return out, arg

        dp0 = jnp.zeros(
            (f_groups.shape[0], f_groups.shape[2]), dtype=f_groups.dtype
        )
        dp_final, args = jax.lax.scan(stage, dp0, gids.T)
        return dp_final, args.swapaxes(0, 1)

    return run


def maxplus_scan_batched(f_groups, stage_gids, *, block_b: int = 256):
    """Ragged batched repeated-stage (max,+) DP scan over many leaves.

    f_groups: [L, G, NB] per-leaf class curve banks (leaves padded to a
    shared class count and budget grid — pad rows must be the identity
    curve [0, -inf, ...]); stage_gids: [L, N] int32 per-leaf stage class
    ids (padded stages gather the identity row, which leaves the DP
    bitwise unchanged).  Returns (dp_final [L, NB], argmax_k [L, N, NB]).

    One jitted scan whose every stage is a single row-batched Pallas
    dispatch: the per-leaf Python loop of the hierarchical dense solve
    collapses into one accelerator call for all dirty leaves, and each
    leaf's row is bitwise what ``maxplus_scan`` returns for it alone.
    """
    import jax.numpy as jnp

    run = _maxplus_scan_batched_fn(block_b, not _on_tpu())
    return run(f_groups, jnp.asarray(stage_gids))


def maxplus_scan(f_groups, stage_gids, *, block_b: int = 256):
    """Repeated-stage (max,+) DP scan over a group-id sequence.

    f_groups: [G, NB] per-behaviour-class dense curves; stage_gids: [N]
    int32, one class id per DP stage.  Each stage gathers its curve row and
    runs the Pallas (max,+) convolution, so N-receiver clusters with G
    distinct classes never materialize an [N, NB] curve matrix.  Returns
    (dp_final [NB], argmax_k [N, NB]) — bitwise equal to scanning the
    row-expanded matrix through ``maxplus_conv``.

    Delegates to :func:`maxplus_scan_batched` with a leading leaf axis of
    1 — the single-row and batched scans are one kernel (each batched row
    is bitwise the single-row result; see test_maxplus_scan_batched_rows_
    bitwise), so there is exactly one scan body to maintain.
    """
    import jax.numpy as jnp

    gids = jnp.asarray(stage_gids)
    dp_final, args = maxplus_scan_batched(
        f_groups[None], gids[None], block_b=block_b
    )
    return dp_final[0], args[0]


def maxplus_stage_batched(dp, kb, vb, *, block_b: int = 256):
    """Sparse-option (max,+) stage with backpointer output.

    dp: [R, NB]; kb: [R, K] int32 descending spend offsets; vb: [R, K]
    option values.  Returns (out [R, NB], arg [R, NB]) where ``arg`` is
    the first maximizing option index — the backpointer table the fused
    device-resident round backtracks through with device gathers.
    Dtype-preserving (float64 in interpret mode for the bit-for-bit
    fused solver path).
    """
    return _mckp_dp.maxplus_stage_pallas_batched(
        dp, kb, vb, block_b=block_b, interpret=not _on_tpu()
    )


def flash_attention(q, k, v, **kw):
    """Fused GQA attention (train/prefill).  See flash_attention.py."""
    from repro.kernels import flash_attention as _fa

    return _fa.flash_attention(q, k, v, interpret=not _on_tpu(), **kw)


def decode_attention(q, k_cache, v_cache, lengths, **kw):
    """Flash-decode GQA attention over a KV cache."""
    from repro.kernels import decode_attention as _da

    return _da.decode_attention(
        q, k_cache, v_cache, lengths, interpret=not _on_tpu(), **kw
    )


def rmsnorm(x, scale, *, eps: float = 1e-6):
    """Fused RMSNorm."""
    from repro.kernels import rmsnorm as _rn

    return _rn.rmsnorm(x, scale, eps=eps, interpret=not _on_tpu())
