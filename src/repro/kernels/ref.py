"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth: kernels are validated
against these with ``assert_allclose`` over shape/dtype sweeps
(tests/test_kernels.py), and they double as the CPU fallback paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# (max,+) convolution — the EcoShift cluster-DP stage (paper §3.2.2, Eq. 2)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def maxplus_conv(dp: jax.Array, f: jax.Array, chunk: int = 512):
    """Tropical-semiring convolution.

    out[b] = max_{0<=k<=b} dp[b-k] + f[k]
    arg[b] = the smallest maximizing k.

    dp, f: [NB] float arrays.  Evaluated in b-chunks so the [chunk, NB]
    candidate tile bounds the memory footprint (the Pallas kernel tiles the
    same way in VMEM).
    """
    nb = dp.shape[0]
    ks = jnp.arange(nb)
    neg = jnp.asarray(-jnp.inf, dp.dtype)

    def one_chunk(b0):
        b = b0 + jnp.arange(chunk)  # [chunk]
        idx = b[:, None] - ks[None, :]  # [chunk, nb]
        valid = (idx >= 0) & (b[:, None] < nb)
        cand = jnp.where(valid, dp[jnp.clip(idx, 0, nb - 1)], neg) + f[None, :]
        cand = jnp.where(valid, cand, neg)
        arg = jnp.argmax(cand, axis=1)
        out = jnp.take_along_axis(cand, arg[:, None], axis=1)[:, 0]
        return out, arg

    nchunks = -(-nb // chunk)
    starts = jnp.arange(nchunks) * chunk
    outs, args = jax.lax.map(one_chunk, starts)
    return outs.reshape(-1)[:nb], args.reshape(-1)[:nb].astype(jnp.int32)


# ---------------------------------------------------------------------------
# RMSNorm (+ optional residual add) — memory-bound fusion exemplar
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the trailing axis, fp32 statistics."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention references (used by flash_attention / decode_attention kernels)
# ---------------------------------------------------------------------------


def mha_reference(
    q: jax.Array,  # [B, Tq, Hq, D]
    k: jax.Array,  # [B, Tk, Hkv, D]
    v: jax.Array,  # [B, Tk, Hkv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    logit_softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Grouped-query attention oracle, fp32 softmax.

    ``window`` enables sliding-window masking (each query attends to at most
    the previous ``window`` keys). ``q_offset`` places the query block at
    absolute positions [q_offset, q_offset+Tq) against keys [0, Tk).
    """
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    assert hq % hkv == 0
    groups = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, tq, hkv, groups, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / jnp.sqrt(d).astype(jnp.float32)
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = q_offset + jnp.arange(tq)
    kpos = jnp.arange(tk)
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, tq, hq, d).astype(q.dtype)


def decode_attention_reference(
    q: jax.Array,  # [B, Hq, D] one new token per sequence
    k_cache: jax.Array,  # [B, S, Hkv, D]
    v_cache: jax.Array,  # [B, S, Hkv, D]
    lengths: jax.Array,  # [B] valid KV lengths
) -> jax.Array:
    """Single-token GQA decode oracle with per-sequence lengths."""
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    groups = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, groups, d)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, kf) / jnp.sqrt(d).astype(jnp.float32)
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S]
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(b, hq, d).astype(q.dtype)
