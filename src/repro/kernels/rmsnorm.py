"""Fused RMSNorm Pallas kernel (memory-bound fusion exemplar).

One HBM round-trip: rows stream through VMEM in (block_rows x d) tiles;
the f32 mean-square reduction, rsqrt and scale all happen in-register.
grid = (n_row_blocks,); the scale vector stays resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + scale_ref[...].astype(jnp.float32))[None]).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,  # [..., d]
    scale: jax.Array,  # [d]
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = True,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = max(1, min(block_rows, rows))
    n_blocks = pl.cdiv(rows, block_rows)
    pad = n_blocks * block_rows - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
