import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()
# ^ MUST precede every other import (jax locks the device count on first
#   init).  The 512 placeholder host devices exist ONLY for this dry-run.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the abstract step function (train_step / prefill / serve_step),
  2. lowers it with ShapeDtypeStruct inputs under the production mesh
     (16x16 single-pod, 2x16x16 multi-pod) with the full sharding rules,
  3. compiles, prints memory_analysis() (proof-of-fit) and cost_analysis(),
  4. analyzes the partitioned HLO (trip-count-corrected flops / bytes /
     per-kind collective bytes) and derives the three roofline terms,
  5. writes one JSON per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro import configs
from repro.launch import sharding as shr
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.models.shardctx import use_rules
from repro.roofline import hlo as hlo_mod
from repro.roofline import model as roof

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def cell_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    info = shr.SHAPES[shape_name]
    if info["kind"] == "decode" and not cfg.supports_decode():
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return False, "full-attention arch skips 500k decode (DESIGN.md §4)"
    return True, ""


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    accum: int | None = None,
    layout: str = "fsdp_tp",
    ssm_chunk: int | None = None,
) -> dict:
    cfg = configs.get_config(arch)
    import dataclasses as _dc

    if accum:
        cfg = _dc.replace(cfg, grad_accum=accum)
    if ssm_chunk and cfg.ssm:
        cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk=ssm_chunk))
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    info = shr.SHAPES[shape_name]
    kind = info["kind"]
    if kind != "train":
        # serving deploys bf16 weights (fp32 masters are a training artifact)
        import dataclasses as _dc

        cfg = _dc.replace(cfg, param_dtype=cfg.dtype)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    specs = steps_mod.input_specs(model, shape_name)

    mb = info["batch"] // (cfg.grad_accum if kind == "train" else 1)
    rules = shr.activation_rules(
        cfg, mesh, multi_pod, mb, mode=kind, seq=info["seq"], layout=layout
    )

    t0 = time.time()
    if kind == "train":
        step, _ = steps_mod.make_train_step(model)
        state_sh = shr.state_sharding(specs["state"], mesh, multi_pod, layout)
        batch_sh = shr.batch_sharding(specs["batch"], mesh, multi_pod, layout)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, shr.replicated(mesh)),
            donate_argnums=(0,),
        )
        with use_rules(rules):
            lowered = jitted.lower(specs["state"], specs["batch"])
    elif kind == "prefill":
        step = steps_mod.make_prefill_step(model)
        params_sh = shr.params_sharding(specs["params"], mesh, multi_pod, layout)
        batch_sh = shr.batch_sharding(specs["batch"], mesh, multi_pod, layout)
        # the emitted KV cache leaves sharded via the production-point
        # `cache_kv` constraint inside each layer (an out_shardings
        # constraint on the stacked scan ys triggers the partitioner's
        # replicate-then-reshard fallback instead)
        jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
        with use_rules(rules):
            lowered = jitted.lower(specs["params"], specs["batch"])
    else:  # decode
        step = steps_mod.make_serve_step(model)
        params_sh = shr.params_sharding(specs["params"], mesh, multi_pod, layout)
        batch_sh = shr.batch_sharding(specs["batch"], mesh, multi_pod, layout)
        cache_sh = shr.cache_sharding(
            specs["cache"], cfg, mesh, multi_pod, info["batch"], layout
        )
        jitted = jax.jit(
            step,
            in_shardings=(params_sh, batch_sh, cache_sh, shr.replicated(mesh)),
            out_shardings=(shr.replicated(mesh), cache_sh),
            donate_argnums=(2,),
        )
        with use_rules(rules):
            lowered = jitted.lower(
                specs["params"], specs["batch"], specs["cache"], specs["lengths"]
            )
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    hc = hlo_mod.analyze(txt)

    # memory_analysis is per-device for SPMD executables
    mem_stats = {
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }
    peak = (
        mem_stats["argument_bytes"]
        + mem_stats["temp_bytes"]
        + mem_stats["output_bytes"]
        - mem_stats["alias_bytes"]
    )

    terms = roof.terms_from_perdevice(
        hc.dot_flops, hc.traffic_bytes, hc.collective_bytes
    )
    mflops = roof.model_flops(cfg, info)
    result = {
        "arch": arch,
        "shape": shape_name,
        "layout": layout,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_stats,
        "peak_bytes_per_device": int(peak),
        "fits_16gb": bool(peak < 16e9),
        "cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
        "hlo_dot_flops_per_device": hc.dot_flops,
        "hlo_traffic_bytes_per_device": hc.traffic_bytes,
        "hlo_collective_bytes_per_device": hc.collective_bytes,
        "collective_by_kind": {k: float(v) for k, v in hc.collective_by_kind.items()},
        "collective_counts": {k: float(v) for k, v in hc.collective_counts.items()},
        "while_trip_counts": hc.while_trips[:32],
        "roofline": terms.as_dict(),
        "model_flops_global": mflops,
        "model_flops_per_device": mflops / n_dev,
        "useful_flops_ratio": (
            mflops / n_dev / hc.dot_flops if hc.dot_flops else 0.0
        ),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shr.SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--layout", default="fsdp_tp", choices=["fsdp_tp", "pure_dp", "ep_pod"])
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else configs.all_arch_ids()
    shapes = [args.shape] if args.shape else list(shr.SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
                if args.layout != "fsdp_tp":
                    tag += f"__{args.layout}"
                path = out_dir / f"{tag}.json"
                try:
                    res = run_cell(
                        arch, shape_name, multi_pod,
                        accum=args.accum, layout=args.layout,
                        ssm_chunk=args.ssm_chunk,
                    )
                except Exception as e:  # noqa: BLE001 - report and continue
                    traceback.print_exc()
                    res = {
                        "arch": arch,
                        "shape": shape_name,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    failures.append(tag)
                path.write_text(json.dumps(res, indent=2))
                if "skipped" in res:
                    print(f"[skip] {tag}: {res['skipped']}")
                elif "error" in res:
                    print(f"[FAIL] {tag}: {res['error'][:200]}")
                else:
                    r = res["roofline"]
                    print(
                        f"[ ok ] {tag}: peak={res['peak_bytes_per_device']/1e9:.2f}GB"
                        f" compute={r['compute_s']*1e3:.2f}ms"
                        f" mem={r['memory_s']*1e3:.2f}ms"
                        f" coll={r['collective_s']*1e3:.2f}ms"
                        f" bottleneck={r['bottleneck']}"
                        f" (compile {res['compile_s']}s)"
                    )
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\ndry-run complete")


if __name__ == "__main__":
    main()
