"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (device count is locked on first jax init, and the
dry-run needs to set XLA_FLAGS before that happens).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(multi_pod: bool):
    """Mesh axes that batch/FSDP dimensions shard over."""
    return ("pod", "data") if multi_pod else ("data",)


def make_smoke_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU sharding tests (requires >= n_data*n_model devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
