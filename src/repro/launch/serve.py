"""Serving launcher CLI: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b \
        --batch 4 --prompt-len 48 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import Model
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=configs.all_arch_ids())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch) if args.full else configs.smoke_config(args.arch)
    if not cfg.supports_decode():
        raise SystemExit(f"{args.arch} is encoder-only; no decode path")
    cfg = dataclasses.replace(cfg, dtype="float32") if not args.full else cfg
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, s_max=args.s_max)

    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
        )
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_image_tokens, cfg.d_vision)
        )
    t0 = time.time()
    out = engine.generate(batch, n_steps=args.gen)
    dt = time.time() - t0
    print(f"{args.arch}: generated {out.shape[0]}x{out.shape[1]} tokens in "
          f"{dt:.2f}s ({out.size/dt:.1f} tok/s)")
    print("first sequence:", np.asarray(out[0]))


if __name__ == "__main__":
    main()
