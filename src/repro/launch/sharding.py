"""Sharding rules: parameter PartitionSpecs, activation rules, input specs.

Layout summary (DESIGN.md §5):
 * FSDP over the batch axes (``data``, plus ``pod`` multi-pod): every large
   parameter shards its d_model-like dimension there; XLA's SPMD partitioner
   all-gathers each scanned layer's slice inside the loop (gather-in-scan).
 * TP over ``model``: attention q-heads, MLP/MoE d_ff, vocab (embedding +
   head).  KV-head projections replicate over ``model`` when n_kv_heads
   doesn't divide the axis (GQA KV is small).
 * Decode caches: KV sequence shards over ``model`` when kv-heads can't
   (kv < 16), else heads shard; long-context (batch=1) shards the sequence
   over the batch axes as well.
 * SSM/xLSTM block parameters are FSDP-only (small models; attention/vocab
   still TP) — their states shard heads over ``model`` where divisible.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import fsdp_axes as _mesh_fsdp_axes
from repro.models.config import ArchConfig

def fsdp_axes(multi_pod: bool, layout: str = "fsdp_tp"):
    """Batch/FSDP mesh axes.
     * 'pure_dp' folds the model axis into data parallelism (small archs
       that over-shard at TP=16);
     * 'ep_pod' reserves the pod axis for expert parallelism (FSDP/batch
       stay on 'data' only)."""
    if layout == "ep_pod":
        return ("data",)
    base = _mesh_fsdp_axes(multi_pod)
    return base + ("model",) if layout == "pure_dp" else base


def tp_axis(layout: str):
    return None if layout == "pure_dp" else "model"


def ep_axis(layout: str):
    """Mesh axis holding the expert dimension (ep_pod layout only)."""
    return "pod" if layout == "ep_pod" else None


#: the four assigned shape cells
SHAPES: dict[str, dict[str, Any]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else axes
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def _maybe(n: int, mesh: Mesh, axes):
    """Use ``axes`` for a dim of size n only if it divides evenly."""
    return axes if _divisible(n, mesh, axes) else None


# ---------------------------------------------------------------------------
# Parameter specs (by tree path)
# ---------------------------------------------------------------------------


def param_pspec(
    path: str, shape: tuple[int, ...], mesh: Mesh, multi_pod: bool,
    layout: str = "fsdp_tp",
) -> P:
    """PartitionSpec for a parameter leaf, identified by its '/'-joined path.

    Stacked (scanned) parameters carry a leading n_units dim -> None.
    """
    fs = fsdp_axes(multi_pod, layout)
    tp = tp_axis(layout)
    stacked = "/units/" in path or path.startswith("units/")
    lead: tuple = (None,) if stacked else ()

    def spec(*entries) -> P:
        # drop axes that don't divide their dim
        fixed = []
        dims = shape[len(lead) :]
        for dim, ax in zip(dims, entries):
            fixed.append(_maybe(dim, mesh, ax))
        return P(*lead, *fixed)

    name = path.split("/")[-1]
    if "/attn/" in path or path.endswith("attn"):
        if name == "wq":
            return spec(fs, tp, None)
        if name in ("wk", "wv"):
            return spec(fs, tp, None)  # _maybe drops the axis if kv<16
        if name == "wo":
            return spec(tp, None, fs)
    if "/ffn/" in path or "/mlp/" in path:
        ep = ep_axis(layout)
        if name in ("w1", "w3"):
            return spec(fs, tp) if len(shape) == 2 + len(lead) else spec(
                ep, fs, tp
            )
        if name == "w2":
            return spec(tp, fs) if len(shape) == 2 + len(lead) else spec(
                ep, tp, fs
            )
        if name == "router":
            return spec(fs, None)
    if name == "table":  # embedding [V, d]
        return spec(tp, fs)
    if name == "head":  # LM head [d, V]
        return spec(fs, tp)
    if name in ("in_proj",) and "mamba" not in path:
        return spec(None, fs)  # audio frontend projector
    if name == "img_proj":
        return spec(None, fs)
    if "/mamba/" in path:
        if name == "in_proj":
            return spec(fs, None)
        if name == "out_proj":
            return spec(None, fs)
        return spec(*([None] * (len(shape) - len(lead))))
    if "/cell/" in path:  # xlstm
        if name in ("wqkvz", "wif", "wx"):
            return spec(fs, None)
        if name == "out_proj":
            return spec(fs, None)
        return spec(*([None] * (len(shape) - len(lead))))
    # norms, gates, biases, small vectors: replicated
    return P(*lead, *([None] * (len(shape) - len(lead))))


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def params_sharding(abstract_params, mesh: Mesh, multi_pod: bool, layout: str = "fsdp_tp"):
    """NamedSharding tree matching an abstract parameter tree."""

    def one(path, leaf):
        return NamedSharding(
            mesh, param_pspec(_path_str(path), leaf.shape, mesh, multi_pod, layout)
        )

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def state_sharding(abstract_state, mesh: Mesh, multi_pod: bool, layout: str = "fsdp_tp"):
    """Shardings for the full train state {params, opt(step, mu, nu)}.

    Optimizer moments mirror their parameter's spec; factored second-moment
    'row'/'col' leaves inherit the parent spec minus the reduced dim.
    """

    def one(path, leaf):
        ps = _path_str(path)
        if ps.endswith("/step") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if ps.endswith("/row"):
            parent = param_pspec(ps[:-4], leaf.shape + (1,), mesh, multi_pod, layout)
            return NamedSharding(mesh, P(*tuple(parent)[:-1]))
        if ps.endswith("/col"):
            shape = leaf.shape[:-1] + (1,) + leaf.shape[-1:]
            parent = param_pspec(ps[:-4], shape, mesh, multi_pod, layout)
            t = tuple(parent)
            return NamedSharding(mesh, P(*t[:-2], t[-1]))
        return NamedSharding(mesh, param_pspec(ps, leaf.shape, mesh, multi_pod, layout))

    return jax.tree_util.tree_map_with_path(one, abstract_state)


# ---------------------------------------------------------------------------
# Decode-cache specs
# ---------------------------------------------------------------------------


def cache_pspec(
    path: str,
    shape: tuple[int, ...],
    cfg: ArchConfig,
    mesh: Mesh,
    multi_pod: bool,
    batch: int,
    layout: str = "fsdp_tp",
) -> P:
    fs = fsdp_axes(multi_pod, layout)
    tp = tp_axis(layout)
    stacked = "units/" in path
    lead: tuple = (None,) if stacked else ()
    dims = shape[len(lead) :]
    name = path.split("/")[-1]

    if name in ("k", "v"):  # [B, S, Hkv, hd]
        b, s, hkv, hd = dims
        batch_ax = _maybe(b, mesh, fs)
        if _divisible(hkv, mesh, tp) and tp is not None:
            head_ax, seq_ax = tp, None
        else:
            head_ax, seq_ax = None, tp
        if batch_ax is None and seq_ax is None:
            # batch=1 long-context: spread the sequence over the batch axes
            seq_ax = _maybe(s, mesh, fs)
        return P(*lead, batch_ax, _maybe(s, mesh, seq_ax), head_ax, None)
    if name == "ssm_state":  # [B, H, P, N]
        b, h, pdim, n = dims
        return P(*lead, _maybe(b, mesh, fs), _maybe(h, mesh, tp), None, None)
    if name == "conv_state":  # [B, K-1, C]
        b = dims[0]
        return P(*lead, _maybe(b, mesh, fs), None, None)
    if name == "c" and len(dims) == 4:  # mlstm [B, H, dh, dh]
        b, h, dh, _ = dims
        return P(
            *lead, _maybe(b, mesh, fs), _maybe(h, mesh, tp),
            None if _divisible(h, mesh, tp) else _maybe(dh, mesh, tp),
            None,
        )
    if name in ("n",) and len(dims) == 3:  # mlstm n [B, H, dh]
        b, h, dh = dims
        return P(*lead, _maybe(b, mesh, fs), _maybe(h, mesh, tp), None)
    if len(dims) == 2:  # slstm h/c/n/m [B, d]
        b, d = dims
        return P(*lead, _maybe(b, mesh, fs), _maybe(d, mesh, tp))
    return P(*lead, *([None] * len(dims)))


def cache_sharding(
    abstract_cache, cfg: ArchConfig, mesh: Mesh, multi_pod: bool, batch: int,
    layout: str = "fsdp_tp",
):
    def one(path, leaf):
        if leaf is None:
            return None
        return NamedSharding(
            mesh,
            cache_pspec(
                _path_str(path), leaf.shape, cfg, mesh, multi_pod, batch, layout
            ),
        )

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


# ---------------------------------------------------------------------------
# Activation rules (shardctx) and batch specs
# ---------------------------------------------------------------------------


def activation_rules(
    cfg: ArchConfig,
    mesh: Mesh,
    multi_pod: bool,
    batch: int,
    *,
    mode: str = "train",
    seq: int = 0,
    sequence_parallel: bool = True,
    layout: str = "fsdp_tp",
) -> dict[str, NamedSharding]:
    """shardctx rules (NamedShardings, so no ambient-mesh context needed).

    In train/prefill the residual stream is sequence-parallel over ``model``
    (Megatron-SP): the per-layer saved activations shrink by the TP degree,
    which is what lets the 27B-314B configs fit 16 GB/chip under full remat.
    XLA materializes the all-gather/reduce-scatter pairs at the TP-op
    boundaries automatically.
    """
    fs = fsdp_axes(multi_pod, layout)
    tp = tp_axis(layout)
    bax = _maybe(batch, mesh, fs)
    sp = (
        _maybe(seq, mesh, tp)
        if (sequence_parallel and mode in ("train", "prefill") and seq and tp)
        else None
    )
    specs = {
        "act_btd": P(bax, sp, None),
        # SP boundary: blocks gather the sequence at entry (all-gather fwd /
        # reduce-scatter bwd), compute TP-sharded, and the residual
        # constraint scatters back — the Megatron-SP collective pattern.
        "act_attn_in": P(bax, None, None),
        "act_heads": P(bax, None, _maybe(cfg.n_heads, mesh, tp), None),
        "act_ff": P(bax, None, _maybe(cfg.d_ff or cfg.d_model, mesh, tp)),
        "act_vocab": P(bax, None, _maybe(cfg.padded_vocab, mesh, tp)),
        "moe_groups": P(bax, None, None),
        "moe_slots": P(
            bax, _maybe(cfg.moe.n_experts, mesh, ep_axis(layout)) if cfg.moe else None,
            None, None,
        ),
        "moe_ff": P(
            bax, _maybe(cfg.moe.n_experts, mesh, ep_axis(layout)) if cfg.moe else None,
            None, _maybe(cfg.d_ff or cfg.d_model, mesh, tp)
        ),
        # decode-path MoE intermediates [B, 1, E, ff] / [B, 1, E, d]
        "moe_dec_h": P(
            bax, None, None, _maybe(cfg.d_ff or cfg.d_model, mesh, tp)
        ),
        "moe_dec_y": P(bax, None, None, None),
    }
    # prefill cache-emission [B, S, Hkv, hd]: same layout decision as
    # cache_pspec so the scan's stacked ys land directly in decode layout
    if _divisible(cfg.n_kv_heads, mesh, tp) and tp is not None:
        specs["cache_kv"] = P(bax, None, tp, None)
    else:
        specs["cache_kv"] = P(bax, _maybe(seq, mesh, tp), None, None)
    # explicit FSDP weight-gathers: constraining the per-layer weight slice
    # to its TP-only compute layout forces the partitioner to all-gather the
    # (small) weight over the FSDP axes instead of partial-summing the
    # (huge) activations over the sharded contracting dim.  The transpose
    # of the constraint is the FSDP gradient reduce-scatter.
    hq_tp = _maybe(cfg.n_heads, mesh, tp)
    kv_tp = _maybe(cfg.n_kv_heads, mesh, tp)
    ff_tp = _maybe(cfg.d_ff or cfg.d_model, mesh, tp)
    v_tp = _maybe(cfg.padded_vocab, mesh, tp)
    specs.update(
        {
            "w_q": P(None, hq_tp, None),
            "w_kv": P(None, kv_tp, None),
            "w_o": P(hq_tp, None, None),
            "w_ffn_in": P(None, ff_tp),
            "w_ffn_out": P(ff_tp, None),
            "w_moe_in": P(
                _maybe(cfg.moe.n_experts, mesh, ep_axis(layout)) if cfg.moe else None,
                None, ff_tp,
            ),
            "w_moe_out": P(
                _maybe(cfg.moe.n_experts, mesh, ep_axis(layout)) if cfg.moe else None,
                ff_tp, None,
            ),
            "w_table": P(v_tp, None),
            "w_head": P(None, v_tp),
            "w_dense": P(None, None),  # mamba/xlstm projections: gathered
        }
    )
    return {k: NamedSharding(mesh, v) for k, v in specs.items()}


def batch_sharding(abstract_batch, mesh: Mesh, multi_pod: bool, layout: str = "fsdp_tp"):
    """Shard every batch leaf's leading (batch) dim over the batch axes."""
    fs = fsdp_axes(multi_pod, layout)

    def one(leaf):
        b = leaf.shape[0]
        spec = [_maybe(b, mesh, fs)] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, abstract_batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
