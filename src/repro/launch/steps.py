"""jit-able train / prefill / serve step factories + abstract input specs.

These are the functions the dry-run lowers and the real launcher executes:
 * ``make_train_step``  — loss + grad (with microbatch accumulation) +
   AdamW update, donate-friendly ``TrainState`` pytree.
 * ``make_prefill_step`` / ``make_serve_step`` — batched inference.
 * ``input_specs`` — ShapeDtypeStruct stand-ins for every model input of an
   (arch x shape) cell: weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.launch.sharding import SHAPES
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.train import optimizer as opt_mod

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_optimizer(cfg: ArchConfig, *, peak_lr: float = 3e-4, total_steps: int = 10000):
    sched = opt_mod.warmup_cosine(peak_lr, max(10, total_steps // 100), total_steps)
    return opt_mod.adamw(
        sched,
        weight_decay=0.1,
        max_grad_norm=1.0,
        factored=cfg.opt_factored,
        moment_dtype=jnp.dtype(cfg.opt_moment_dtype),
        update_chunks=cfg.opt_update_chunks,
    )


def make_train_step(model: Model, optimizer=None):
    cfg = model.cfg
    optimizer = optimizer or make_optimizer(cfg)
    accum = max(1, cfg.grad_accum)

    cdt = jnp.dtype(cfg.dtype)

    def loss_fn(params, batch):
        # pre-cast fp32 master params to the compute dtype ONCE, per shard,
        # before the layer scan: FSDP all-gathers then move bf16, not fp32
        # (halves weight-gather collective bytes and the gathered transient)
        params_c = jax.tree.map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, params
        )
        return model.loss(params_c, batch)

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # microbatch scan: batch leaves [B, ...] -> [accum, B/accum, ...]
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            mb = jax.tree.map(split, batch)
            acc_dt = jnp.dtype(cfg.accum_dtype)

            def acc_step(carry, mb_i):
                loss_sum, g_sum = carry
                li, gi = jax.value_and_grad(loss_fn)(params, mb_i)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_sum, gi
                )
                return (loss_sum + li, g_sum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params
            )
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.zeros(()), g0), mb)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        metrics = {"loss": loss, "grad_norm": opt_mod.global_norm(grads)}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step, optimizer


def init_train_state(model: Model, key: jax.Array, optimizer=None) -> Params:
    optimizer = optimizer or make_optimizer(model.cfg)
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params)}


def abstract_train_state(model: Model, optimizer=None) -> Params:
    optimizer = optimizer or make_optimizer(model.cfg)
    return jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), optimizer)
    )


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, batch, cache, lengths):
        logits, new_cache = model.decode_step(params, batch, cache, lengths)
        return jnp.argmax(logits, axis=-1), new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs per (arch x shape)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """Model-input ShapeDtypeStructs for one shape cell (no cache)."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    if kind == "decode":
        batch: dict = {"tokens": _sds((b, 1), jnp.int32)}
    elif cfg.family == "audio":
        batch = {"frames": _sds((b, s, cfg.frontend_dim), cfg.dtype)}
        if kind == "train":
            batch["targets"] = _sds((b, s), jnp.int32)
    else:
        batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = _sds(
            (b, cfg.n_image_tokens, cfg.d_vision), cfg.dtype
        )
    return batch


def input_specs(model: Model, shape_name: str) -> dict:
    """Everything the step function consumes, as ShapeDtypeStructs."""
    cfg = model.cfg
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    out: dict = {"batch": batch_specs(cfg, shape_name)}
    if kind == "train":
        out["state"] = abstract_train_state(model)
    else:
        out["params"] = model.abstract_params()
    if kind == "decode":
        out["cache"] = model.abstract_cache(b, s)
        out["lengths"] = _sds((b,), jnp.int32)
    return out
