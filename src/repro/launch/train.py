"""Training launcher CLI.

Smoke-scale on CPU by default (reduced config); pass ``--full`` on a real
pod to train the published config under the production mesh layout.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 60 --batch 8 --seq 128 --ckpt-dir /tmp/run1
    # crash it, then rerun the same command: it resumes bit-identically
"""

from __future__ import annotations

import argparse
import pathlib
import tempfile

from repro import configs
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.data import make_batch_fn
from repro.train.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=configs.all_arch_ids())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument(
        "--full", action="store_true",
        help="published config (pod-scale; smoke config is the CPU default)",
    )
    args = ap.parse_args()

    cfg = configs.get_config(args.arch) if args.full else configs.smoke_config(args.arch)
    model = Model(cfg)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix=f"train_{args.arch}_")
    trainer = Trainer(
        model=model,
        batch_fn=make_batch_fn(cfg, batch=args.batch, seq=args.seq),
        ckpt=CheckpointManager(pathlib.Path(ckpt_dir)),
        ckpt_every=args.ckpt_every,
        peak_lr=args.lr,
        total_steps=args.steps,
    )
    if trainer.resume():
        print(f"resumed at step {trainer.step} from {ckpt_dir}")
    else:
        trainer.init()
        print(f"new run ({args.arch}, {cfg.n_layers}L d{cfg.d_model}); ckpt -> {ckpt_dir}")

    while trainer.step < args.steps:
        n = min(args.log_every, args.steps - trainer.step)
        hist = trainer.run(n)
        h = hist[-1]
        print(
            f"step {h['step']:5d}  loss {h['loss']:.4f}  "
            f"gnorm {h['grad_norm']:.3f}  {h['seconds']:.2f}s/step"
        )
    print(f"done; final loss {trainer.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
