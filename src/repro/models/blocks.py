"""Transformer building blocks: norms, RoPE, GQA attention, gated MLP.

Conventions:
 * parameters are nested dicts of jnp arrays; ``init_*`` builds them,
   ``apply_*`` consumes them;
 * activations flow in the config compute dtype (bf16), statistics and
   softmax in fp32;
 * attention is *blocked*: a static loop over query chunks with per-chunk
   exact KV extents (static slices — no flops wasted on fully-masked
   blocks), and an inner online-softmax scan over KV chunks so the
   [*, q_chunk, kv_chunk] logits tile bounds peak memory.  This mirrors the
   Pallas flash kernel's schedule (repro/kernels/flash_attention.py) and is
   the partitioner-friendly path used by the multi-pod dry-run.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict[str, Any]


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, cfg: ArchConfig) -> Params:
    return {"scale": jnp.zeros((d,), pdtype(cfg))}


def apply_rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (full / partial-"2d" fraction)
# ---------------------------------------------------------------------------


def rope_tables(
    positions: jax.Array, head_dim: int, fraction: float, theta: float
) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape [..., rot_dim/2] for the rotating slice."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(
    x: jax.Array,  # [..., head_dim]
    sin: jax.Array,
    cos: jax.Array,
) -> jax.Array:
    """Rotate the leading ``2*half`` slice of head_dim; pass the rest through
    (chatglm3's partial/"2d" RoPE uses fraction 0.5)."""
    half = sin.shape[-1]
    rot, rest = x[..., : 2 * half], x[..., 2 * half :]
    x1, x2 = rot[..., ::2], rot[..., 1::2]
    sin = sin.astype(jnp.float32)
    cos = cos.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x1f * cos - x2f * sin
    r2 = x2f * cos + x1f * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(rot.shape).astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1) if rest.shape[-1] else out


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ArchConfig, *, cross: bool = False) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    d_kv_src = cfg.d_model if not cross else cfg.d_model  # projector maps vision->d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = pdtype(cfg)
    return {
        "wq": (jax.random.normal(k1, (d, hq, hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d_kv_src, hkv, hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d_kv_src, hkv, hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (hq, hd, d)) * s / math.sqrt(cfg.n_layers)).astype(dt),
    }


def _online_softmax_scan(
    q: jax.Array,  # [B, cq, H, hd] (KV already expanded to H q-heads)
    k_all: jax.Array,  # [B, Skv, H, hd]
    v_all: jax.Array,  # [B, Skv, H, hd]
    *,
    chunk_kv: int,
    mask_fn,  # (q_abs [cq], k_abs [ck]) -> bool [cq, ck] or None
    q_abs0: jax.Array | int,
    k_abs0: int,
    softcap: float | None,
    scale: float,
) -> jax.Array:
    """Inner flash loop: scan KV chunks with running (max, denom, accum).

    Works on the flat head layout (GQA KV pre-broadcast to the query heads)
    so the ``model``-axis head sharding survives every reshape — the SPMD
    partitioner handles [B,S,H,hd] cleanly where the grouped 5D layout
    forced involuntary reshards.
    """
    b, cq, h, hd = q.shape
    skv = k_all.shape[1]
    n_kv = -(-skv // chunk_kv)
    pad = n_kv * chunk_kv - skv
    if pad:
        k_all = jnp.pad(k_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_all = jnp.pad(v_all, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k_chunks = k_all.reshape(b, n_kv, chunk_kv, h, hd).transpose(1, 0, 2, 3, 4)
    v_chunks = v_all.reshape(b, n_kv, chunk_kv, h, hd).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32) * scale

    def step(carry, inp):
        j, kc, vc = inp
        m, lsum, acc = carry
        logits = jnp.einsum(
            "bqhd,bkhd->bhqk", qf, kc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        if mask_fn is not None:
            k_abs = k_abs0 + j * chunk_kv + jnp.arange(chunk_kv)
            q_abs = q_abs0 + jnp.arange(cq)
            msk = mask_fn(q_abs, k_abs)  # [cq, ck]
            logits = jnp.where(msk[None, None], logits, -jnp.inf)
        elif pad:
            k_abs = j * chunk_kv + jnp.arange(chunk_kv)
            logits = jnp.where(
                (k_abs < skv)[None, None, None], logits, -jnp.inf
            )
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = corr * lsum + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = corr[..., None] * acc + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, cq), jnp.float32)
    a0 = jnp.zeros((b, h, cq, hd), jnp.float32)
    # checkpoint each KV step: backward recomputes the [cq, ck] logits tile
    # instead of stacking it across the scan (flash-attention backward)
    (m, lsum, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(n_kv), k_chunks, v_chunks)
    )
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]  # [b,h,cq,hd]
    return out.transpose(0, 2, 1, 3)  # [b,cq,h,hd]


def blocked_attention(
    q: jax.Array,  # [B, Sq, Hq, hd]
    k: jax.Array,  # [B, Skv, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    n_q_chunks: int = 8,
    chunk_kv: int = 1024,
) -> jax.Array:
    """Memory-efficient GQA attention with exact per-chunk KV extents.

    Query chunks are a *static* Python loop; chunk ``i`` at absolute offset
    ``qo`` reads only KV[:qo+cq] (causal) or the window slab (local), via
    static slices — no flops are spent on fully-masked KV blocks.
    """
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    if g > 1:  # broadcast GQA KV up to the query heads (flat layout)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "act_heads")
    v = shard(v, "act_heads")

    if sq == 0:
        return q
    n_q = max(1, min(n_q_chunks, sq))
    while sq % n_q:
        n_q -= 1
    cq = sq // n_q
    chunk_kv = min(chunk_kv, skv)

    outs = []
    for i in range(n_q):
        qo = q_offset + i * cq  # absolute position of this q chunk
        qc = q[:, i * cq : (i + 1) * cq]
        if causal:
            hi = min(qo + cq, skv)  # static: q_offset is python int here
            lo = 0
            if window is not None:
                lo = max(0, hi - cq - window)
                lo -= lo % chunk_kv  # keep chunk alignment
            kc, vc = k[:, lo:hi], v[:, lo:hi]

            def mask_fn(q_abs, k_abs, _w=window):
                m = q_abs[:, None] >= k_abs[None, :]
                if _w is not None:
                    m &= q_abs[:, None] - k_abs[None, :] < _w
                return m

            out = _online_softmax_scan(
                qc, kc, vc,
                chunk_kv=chunk_kv, mask_fn=mask_fn, q_abs0=qo, k_abs0=lo,
                softcap=softcap, scale=scale,
            )
        else:
            out = _online_softmax_scan(
                qc, k, v,
                chunk_kv=chunk_kv, mask_fn=None, q_abs0=qo, k_abs0=0,
                softcap=softcap, scale=scale,
            )
        outs.append(out)
    out = jnp.concatenate(outs, axis=1)  # [b, sq, h, hd]
    return out.astype(q.dtype)


def apply_attention(
    p: Params,
    x: jax.Array,  # [B, S, d]
    cfg: ArchConfig,
    *,
    causal: bool = True,
    window: int | None = None,
    positions: jax.Array | None = None,  # [S] absolute positions
    q_offset: int = 0,
    kv_src: jax.Array | None = None,  # cross-attention context [B, Skv, d]
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    dt = cdtype(cfg)
    wq = shard(p["wq"].astype(dt), "w_q")  # explicit FSDP gather
    wk = shard(p["wk"].astype(dt), "w_kv")
    wv = shard(p["wv"].astype(dt), "w_kv")
    q = jnp.einsum("bsd,dhk->bshk", x, wq, preferred_element_type=dt)
    src = x if kv_src is None else kv_src
    k = jnp.einsum("bsd,dhk->bshk", src, wk, preferred_element_type=dt)
    v = jnp.einsum("bsd,dhk->bshk", src, wv, preferred_element_type=dt)
    if rope and kv_src is None:
        pos = positions if positions is not None else q_offset + jnp.arange(s)
        sin, cos = rope_tables(pos, hd, cfg.rotary_fraction, cfg.rope_theta)
        q = apply_rope(q, sin[:, None], cos[:, None])
        k = apply_rope(k, sin[:, None], cos[:, None])
    q = shard(q, "act_heads")
    out = blocked_attention(
        q, k, v,
        causal=causal and kv_src is None,
        window=window,
        softcap=cfg.logit_softcap,
        q_offset=q_offset,
    )
    wo = shard(p["wo"].astype(dt), "w_o")
    y = jnp.einsum(
        "bshk,hkd->bsd", out.astype(dt), wo, preferred_element_type=dt
    )
    return shard(y, "act_btd")


def decode_attention_step(
    p: Params,
    x: jax.Array,  # [B, 1, d]
    cache: Params,  # {"k": [B, S_cache, Hkv, hd], "v": ...}
    lengths: jax.Array,  # [B] tokens generated so far (absolute)
    cfg: ArchConfig,
    *,
    window: int | None = None,
    chunk_kv: int = 4096,
) -> tuple[jax.Array, Params]:
    """One-token cached attention; returns (out [B,1,d], updated cache).

    Sliding-window layers use a RING cache of size ``window`` (slot =
    abs_pos % window): the cache for gemma3's 52 local layers is 32x
    smaller than the full 32k context.  RoPE is applied at absolute
    positions before the write, so ring rotation never touches phases.
    """
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    dt = cdtype(cfg)
    s_cache = cache["k"].shape[1]
    ring = window is not None and s_cache <= window
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))[:, 0]  # [B,Hq,hd]
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))[:, 0]
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))[:, 0]
    sin, cos = rope_tables(lengths, hd, cfg.rotary_fraction, cfg.rope_theta)
    q = apply_rope(q, sin[:, None], cos[:, None])
    k_new = apply_rope(k_new, sin[:, None], cos[:, None])

    # write the new KV at slot ``abs_pos % s_cache`` per sequence
    slots = lengths % s_cache if ring else lengths

    def write(c, new, i):
        return jax.lax.dynamic_update_slice_in_dim(c, new[None], i, axis=0)

    k_cache = jax.vmap(write)(cache["k"], k_new.astype(cache["k"].dtype), slots)
    v_cache = jax.vmap(write)(cache["v"], v_new.astype(cache["v"].dtype), slots)
    new_len = lengths + 1
    s_max = s_cache
    if ring:
        window = None  # ring residency already enforces the window

    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32) / math.sqrt(hd)

    # single-shot attention over the whole cache: with one query token the
    # logits tensor [B, Hkv, G, S] is small (tens of MB even at 500k KV),
    # and it partitions perfectly — seq- or head-sharded caches reduce via
    # one small all-reduce instead of the chunk-scan's per-chunk reshards.
    # NOTE: the cache stays in its storage dtype — an .astype(f32) here gets
    # loop-hoisted by XLA into a full fp32 copy of the stacked cache;
    # preferred_element_type gives fp32 accumulation without the copy.
    logits = jnp.einsum(
        "bhgd,bkhd->bhgk", qg.astype(k_cache.dtype), k_cache,
        preferred_element_type=jnp.float32,
    )
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    k_abs = jnp.arange(s_max)
    valid = k_abs[None, :] < new_len[:, None]  # [B, S]
    if window is not None:
        valid &= new_len[:, None] - k_abs[None, :] <= window
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    pr = jnp.exp(logits - jnp.where(jnp.isfinite(m), m, 0.0))
    pr = jnp.where(jnp.isfinite(logits), pr, 0.0)
    denom = jnp.maximum(jnp.sum(pr, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", (pr / denom).astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).reshape(b, 1, hq, hd)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), p["wo"].astype(dt))
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dt = pdtype(cfg)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff) / math.sqrt(cfg.n_layers)
    return {
        "w1": (jax.random.normal(k1, (d, ff)) * s_in).astype(dt),
        "w3": (jax.random.normal(k2, (d, ff)) * s_in).astype(dt),
        "w2": (jax.random.normal(k3, (ff, d)) * s_out).astype(dt),
    }


def apply_mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = cdtype(cfg)
    act = getattr(jax.nn, cfg.act)
    def mm(a, b):
        return jnp.einsum("bsd,df->bsf", a, b, preferred_element_type=dt)
    w1 = shard(p["w1"].astype(dt), "w_ffn_in")  # explicit FSDP gathers
    w3 = shard(p["w3"].astype(dt), "w_ffn_in")
    w2 = shard(p["w2"].astype(dt), "w_ffn_out")
    h = act(mm(x, w1)) * mm(x, w3)
    h = shard(h, "act_ff")
    return shard(mm(h, w2), "act_btd")


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, cfg: ArchConfig) -> Params:
    dt = pdtype(cfg)
    v = cfg.padded_vocab  # padded so the vocab axis shards evenly
    p = {"table": (jax.random.normal(key, (v, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(jax.random.fold_in(key, 1), (cfg.d_model, v)) * 0.02
        ).astype(dt)
    return p


def embed_tokens(p: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(p["table"].astype(cdtype(cfg)), tokens, axis=0)
    return shard(x * math.sqrt(cfg.d_model), "act_btd")


def logits(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = cdtype(cfg)
    if cfg.tie_embeddings:
        out = jnp.einsum(
            "bsd,vd->bsv", x, shard(p["table"].astype(dt), "w_table"),
            preferred_element_type=dt,
        )
    else:
        out = jnp.einsum(
            "bsd,dv->bsv", x, shard(p["head"].astype(dt), "w_head"),
            preferred_element_type=dt,
        )
    return shard(out, "act_vocab")
