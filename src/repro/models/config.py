"""Architecture configuration for the model zoo.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/``.
The config fully determines parameter shapes, the layer pattern (scan
units), and which serving shapes are applicable (encoder-only archs have no
decode step; pure full-attention archs skip long_500k — DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    #: tokens per dispatch group (s_g); capacity rounds up to 128-multiples
    group_size: int = 4096

    def capacity(self, group_size: int | None = None) -> int:
        """Slots per expert per group, rounded up to the 8-sublane multiple."""
        g = group_size or self.group_size
        c = int(g * self.top_k / self.n_experts * self.capacity_factor)
        return max(8, -(-c // 8) * 8)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2/SSD block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM stack: mLSTM blocks with an sLSTM block every ``slstm_every``."""

    slstm_every: int = 8  # xLSTM[7:1]
    mlstm_chunk: int = 128
    conv_window: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None  # default d_model // n_heads
    rope_theta: float = 10000.0
    #: fraction of head_dim that rotates (chatglm3 "2d RoPE" = 0.5)
    rotary_fraction: float = 1.0
    #: sliding-window size for local-attention layers (None = full)
    sliding_window: int | None = None
    #: gemma3 pattern: this many local layers per global layer (0 = all full)
    local_per_global: int = 0
    logit_softcap: float | None = None
    #: cross-attention (image) layer every Nth layer (llama-3.2-vision)
    cross_attn_every: int | None = None
    n_image_tokens: int = 1024
    d_vision: int = 1280
    #: encoder-only (hubert): bidirectional attention, no decode step
    encoder_only: bool = False
    frontend_dim: int | None = None  # audio/vision stub frame-embedding width

    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None
    #: zamba2: shared-weight attention block every Nth position
    shared_attn_every: int | None = None

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"
    dtype: str = "bfloat16"  # compute dtype
    param_dtype: str = "float32"

    #: layers per scan unit (pattern length); n_layers % scan_unit may leave
    #: a tail that is executed unscanned
    scan_unit: int = 1
    #: gradient-accumulation microbatches in train_step
    grad_accum: int = 1
    remat: Literal["none", "full", "dots"] = "full"
    #: optimizer memory knobs (Adafactor-style factored nu; bf16 momentum)
    opt_factored: bool = False
    opt_moment_dtype: str = "float32"
    #: gradient-accumulation dtype (grok: bf16 to fit 16 GB/chip)
    accum_dtype: str = "float32"
    #: chunk the optimizer update of big stacked leaves (transient bound)
    opt_update_chunks: int = 1

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/head shard any mesh axis."""
        return -(-self.vocab // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.xlstm is not None

    # -- shape-cell applicability (DESIGN.md §4) -----------------------------

    def supports_decode(self) -> bool:
        return not self.encoder_only

    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic archs."""
        if self.encoder_only:
            return False
        if self.family in ("ssm", "hybrid"):
            return True
        # gemma3: 5:1 local:global — dominated by 1024-window layers
        return self.local_per_global >= 5

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind, length n_layers.  Kinds:
        attn (full), attn_local (windowed), attn_cross (image cross-attn),
        mamba, mamba_shared_attn, mlstm, slstm."""
        kinds: list[str] = []
        for i in range(self.n_layers):
            if self.family == "hybrid" and self.ssm is not None:
                if (
                    self.shared_attn_every
                    and i % self.shared_attn_every == 0
                ):
                    kinds.append("mamba_shared_attn")
                else:
                    kinds.append("mamba")
            elif self.xlstm is not None:
                if (i + 1) % self.xlstm.slstm_every == 0:
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.cross_attn_every and i % self.cross_attn_every == (
                self.cross_attn_every - 1
            ):
                kinds.append("attn_cross")
            elif self.local_per_global:
                # gemma3: L,L,L,L,L,G repeating
                kinds.append(
                    "attn"
                    if (i + 1) % (self.local_per_global + 1) == 0
                    else "attn_local"
                )
            elif self.sliding_window is not None:
                kinds.append("attn_local")
            else:
                kinds.append("attn")
        return kinds

    def scan_pattern(self) -> tuple[list[str], int, list[str]]:
        """(unit_kinds, n_units, tail_kinds): the stack is ``unit_kinds``
        scanned ``n_units`` times followed by unscanned ``tail_kinds``."""
        kinds = self.layer_kinds()
        u = self.scan_unit
        n_units = self.n_layers // u
        unit = kinds[:u]
        # verify the pattern actually repeats; otherwise fall back to tail
        for r in range(n_units):
            if kinds[r * u : (r + 1) * u] != unit:
                n_units = r
                break
        tail = kinds[n_units * u :]
        return unit, n_units, tail
