"""Mamba2 / SSD block (Zamba2's backbone), chunked-scan formulation.

State-space duality form (Dao & Gu 2024): per head h with head dim P and
state dim N,

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T        (h: [P, N])
    y_t = h_t C_t + D_h x_t

trained with the chunked algorithm: intra-chunk quadratic term (a decay-
masked C B^T "attention" within each chunk of length Q) plus an inter-chunk
recurrent state carried by a ``lax.scan`` over chunks.  TPU note: the
quadratic intra term is an MXU-friendly [Q, Q] matmul per head — this is the
adaptation of the paper-family's GPU scan kernels to the systolic unit
(DESIGN.md hw-adaptation log).

Decode is the O(1) recurrent update on a [B, H, P, N] state plus a rolling
depthwise-conv cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict


def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_in = ssm.d_inner(cfg.d_model)
    nheads = ssm.n_heads(cfg.d_model)
    conv_ch = d_in + 2 * ssm.d_state  # conv runs over (x, B, C) channels
    return ssm, d_in, nheads, conv_ch


def init_mamba2(key: jax.Array, cfg: ArchConfig) -> Params:
    ssm, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    in_dim = 2 * d_in + 2 * ssm.d_state + nheads  # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, in_dim)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (ssm.d_conv, conv_ch)) * 0.5).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((nheads,), dt),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nheads).astype(jnp.float32)
        ).astype(dt),
        "d_skip": jnp.ones((nheads,), dt),
        "norm_scale": jnp.zeros((d_in,), dt),
        "out_proj": (
            jax.random.normal(ks[2], (d_in, d)) * s / math.sqrt(cfg.n_layers)
        ).astype(dt),
    }


def _split_proj(zxbcdt: jax.Array, cfg: ArchConfig):
    ssm, d_in, nheads, _ = _dims(cfg)
    n = ssm.d_state
    z = zxbcdt[..., :d_in]
    x = zxbcdt[..., d_in : 2 * d_in]
    b = zxbcdt[..., 2 * d_in : 2 * d_in + n]
    c = zxbcdt[..., 2 * d_in + n : 2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n :]
    return z, x, b, c, dt_raw


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with window K."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k)
    )
    return out + b[None, None]


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(y * silu(z)) * (1 + scale)."""
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    out = gf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(y.dtype)


def apply_mamba2(
    p: Params, x_in: jax.Array, cfg: ArchConfig, *, return_state: bool = False
):
    """Full-sequence (train / prefill) chunked SSD.  x_in: [B, S, d].

    ``return_state=True`` additionally returns the decode cache holding the
    final SSM state and the conv tail (so decode continues seamlessly)."""
    ssm, d_in, nheads, _ = _dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    bsz, s, _ = x_in.shape
    q = min(ssm.chunk, s)
    while s % q:  # largest divisor <= chunk (odd smoke shapes)
        q -= 1
    n_chunks = s // q
    pdim, nstate = ssm.head_dim, ssm.d_state

    zxbcdt = jnp.einsum(
        "bsd,dk->bsk", x_in, shard(p["in_proj"].astype(dt_c), "w_dense"),
        preferred_element_type=dt_c,
    )
    z, xr, br, cr, dt_raw = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xr, br, cr], axis=-1)
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, p["conv_w"].astype(dt_c), p["conv_b"].astype(dt_c))
    )
    xr = conv_out[..., :d_in]
    br = conv_out[..., d_in : d_in + nstate]
    cr = conv_out[..., d_in + nstate :]

    dt_h = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H], negative
    log_decay = dt_h * a[None, None]  # [B,S,H] <= 0

    xh = xr.reshape(bsz, s, nheads, pdim)
    # chunked layout
    xc = xh.reshape(bsz, n_chunks, q, nheads, pdim).astype(jnp.float32)
    bc = br.reshape(bsz, n_chunks, q, nstate).astype(jnp.float32)
    cc = cr.reshape(bsz, n_chunks, q, nstate).astype(jnp.float32)
    dtc = dt_h.reshape(bsz, n_chunks, q, nheads)
    ldc = log_decay.reshape(bsz, n_chunks, q, nheads)
    cum = jnp.cumsum(ldc, axis=2)  # [B,Nc,Q,H] inclusive

    def chunk_step(state, inp):
        # state: [B,H,P,N]
        xk, bk, ck, dtk, cumk, ldk = inp  # leading axis stripped by scan
        # intra-chunk quadratic term
        # decay[t, s_] = exp(cum[t] - cum[s_]) for s_ <= t
        diff = cumk[:, :, None, :] - cumk[:, None, :, :]  # [B,Q,Q,H]
        tri = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: upper-triangle diffs are positive and can
        # overflow; exp(-inf)=0 keeps both value and gradient clean
        decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("btn,bsn->bts", ck, bk)[..., None] * decay  # [B,Q,Q,H]
        y_intra = jnp.einsum("btsh,bsh,bshp->bthp", scores, dtk, xk)
        # inter-chunk: contribution of the carried state
        y_state = jnp.einsum(
            "btn,bhpn,bth->bthp", ck, state, jnp.exp(cumk)
        )
        # state update for next chunk
        w = jnp.exp(cumk[:, -1:, :] - cumk) * dtk  # [B,Q,H]
        state_new = state * jnp.exp(cumk[:, -1])[:, :, None, None] + jnp.einsum(
            "bth,bthp,btn->bhpn", w, xk, bk
        )
        return state_new, y_intra + y_state

    state0 = jnp.zeros((bsz, nheads, pdim, nstate), jnp.float32)
    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (xc, bc, cc, dtc, cum, ldc)
    )
    # checkpoint per chunk: bwd recomputes the [Q,Q] intra tile, not a stack
    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, nheads, pdim)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(
        jnp.float32
    )
    y = y.reshape(bsz, s, d_in).astype(dt_c)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    out = shard(
        jnp.einsum("bsd,dk->bsk", y, p["out_proj"].astype(dt_c), preferred_element_type=dt_c),
        "act_btd",
    )
    if not return_state:
        return out
    k = ssm.d_conv
    tail = conv_in[:, s - (k - 1) :, :] if s >= k - 1 else jnp.pad(
        conv_in, ((0, 0), (k - 1 - s, 0), (0, 0))
    )
    return out, {"ssm_state": final_state, "conv_state": tail}


# ---------------------------------------------------------------------------
# Decode path: O(1) recurrent update
# ---------------------------------------------------------------------------


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    ssm, d_in, nheads, conv_ch = _dims(cfg)
    return {
        "ssm_state": jnp.zeros((batch, nheads, ssm.head_dim, ssm.d_state), jnp.float32),
        "conv_state": jnp.zeros((batch, ssm.d_conv - 1, conv_ch), dtype),
    }


def apply_mamba2_decode(
    p: Params, x_in: jax.Array, cache: Params, cfg: ArchConfig
) -> tuple[jax.Array, Params]:
    """Single-token step.  x_in: [B, 1, d] -> ([B, 1, d], new cache)."""
    ssm, d_in, nheads, conv_ch = _dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    bsz = x_in.shape[0]
    nstate, pdim = ssm.d_state, ssm.head_dim

    zxbcdt = x_in[:, 0] @ p["in_proj"].astype(dt_c)  # [B, in_dim]
    z, xr, br, cr, dt_raw = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xr, br, cr], axis=-1)  # [B, conv_ch]
    window = jnp.concatenate([cache["conv_state"], conv_in[:, None]], axis=1)
    w = p["conv_w"].astype(dt_c)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(dt_c)
    )
    new_conv_state = window[:, 1:]
    xr = conv_out[:, :d_in]
    br = conv_out[:, d_in : d_in + nstate].astype(jnp.float32)
    cr = conv_out[:, d_in + nstate :].astype(jnp.float32)

    dt_h = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt_h * a[None])  # [B,H]
    xh = xr.reshape(bsz, nheads, pdim).astype(jnp.float32)
    state = cache["ssm_state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt_h, xh, br
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cr)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, d_in).astype(dt_c)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_c))[:, None]
    return out, {"ssm_state": state, "conv_state": new_conv_state}
