"""Model = modality frontend + layer stack + chunked LM head.

``Model`` is family-polymorphic over the 10 assigned architectures:
 * LM families (dense/moe/hybrid/ssm): token embedding -> stack -> head;
 * ``audio`` (hubert): frame-embedding stub -> bidirectional encoder ->
   per-frame classification head (no decode path);
 * ``vlm`` (llama-3.2-vision): token embedding + projected image-embedding
   context consumed by the cross-attention layers.

The LM head + cross-entropy are fused and *chunked over tokens* so the
[B, S, vocab] logits tensor never materializes (gemma3's 262k vocab at 1M
tokens would otherwise be ~0.5 TB); backprop recomputes per-chunk logits.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks, transformer
from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict[str, Any]


def _pick_chunks(s: int, want: int) -> int:
    n = max(1, min(want, s))
    while s % n:
        n -= 1
    return n


def chunked_softmax_xent(
    x: jax.Array,  # [B, S, d] final hidden states
    embed_params: Params,
    targets: jax.Array,  # [B, S] int32
    cfg: ArchConfig,
    *,
    mask: jax.Array | None = None,  # [B, S] 1.0 = contributes
    n_chunks: int = 8,
) -> jax.Array:
    """Mean next-token cross entropy, computed in sequence chunks."""
    b, s, d = x.shape
    n = _pick_chunks(s, n_chunks)
    cs = s // n
    xs = jnp.moveaxis(x.reshape(b, n, cs, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, n, cs), 1, 0)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    ms = jnp.moveaxis(mask.reshape(b, n, cs), 1, 0)

    pad = cfg.padded_vocab - cfg.vocab

    def body(carry, inp):
        xc, tc, mc = inp
        lg = blocks.logits(embed_params, xc, cfg).astype(jnp.float32)
        if pad:
            lg = jnp.where(
                jnp.arange(cfg.padded_vocab) < cfg.vocab, lg, -jnp.inf
            )
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros(()), jnp.zeros(())), (xs, ts, ms)
    )
    return tot / jnp.maximum(cnt, 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- init ----------------------------------------------------------------

    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        dt = jnp.dtype(cfg.param_dtype)
        p: Params = {
            "stack": transformer.init_stack(k2, cfg),
            "final_ln": blocks.init_rmsnorm(cfg.d_model, cfg),
        }
        if cfg.family == "audio":
            p["embed"] = {
                "head": (
                    jax.random.normal(k1, (cfg.d_model, cfg.padded_vocab)) * 0.02
                ).astype(dt)
            }
            p["in_proj"] = (
                jax.random.normal(k3, (cfg.frontend_dim, cfg.d_model)) * 0.02
            ).astype(dt)
        else:
            p["embed"] = blocks.init_embedding(k1, cfg)
            if cfg.family == "vlm":
                p["img_proj"] = (
                    jax.random.normal(k4, (cfg.d_vision, cfg.d_model)) * 0.02
                ).astype(dt)
        return p

    def abstract_params(self) -> Params:
        """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- frontends -------------------------------------------------------------

    def _embed_inputs(self, params: Params, batch: dict) -> jax.Array:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "audio":
            x = batch["frames"].astype(dt) @ params["in_proj"].astype(dt)
            return shard(x, "act_btd")
        return blocks.embed_tokens(params["embed"], batch["tokens"], cfg)

    def _img_ctx(self, params: Params, batch: dict) -> jax.Array | None:
        if self.cfg.family != "vlm":
            return None
        dt = jnp.dtype(self.cfg.dtype)
        return batch["image_embeds"].astype(dt) @ params["img_proj"].astype(dt)

    # -- forward passes --------------------------------------------------------

    def hidden(
        self,
        params: Params,
        batch: dict,
        *,
        mode: str,
        cache: Params | None = None,
        lengths: jax.Array | None = None,
    ) -> tuple[jax.Array, Params | None]:
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        x, cache_out = transformer.apply_stack(
            params["stack"],
            x,
            cfg,
            mode=mode,
            cache=cache,
            lengths=lengths,
            img_ctx=self._img_ctx(params, batch),
        )
        x = blocks.apply_rmsnorm(params["final_ln"], x, cfg.norm_eps)
        return x, cache_out

    def loss(self, params: Params, batch: dict) -> jax.Array:
        """Training loss.  LM: next-token prediction (targets = shifted
        tokens unless given).  audio: per-frame classification."""
        cfg = self.cfg
        x, _ = self.hidden(params, batch, mode="train")
        if cfg.family == "audio":
            targets = batch["targets"]
            mask = batch.get("mask")
            return chunked_softmax_xent(x, params["embed"], targets, cfg, mask=mask)
        tokens = batch["tokens"]
        if "targets" in batch:
            targets, mask = batch["targets"], batch.get("mask")
        else:
            targets = jnp.concatenate(
                [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1
            )
            mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
        return chunked_softmax_xent(x, params["embed"], targets, cfg, mask=mask)

    def prefill(self, params: Params, batch: dict) -> tuple[jax.Array, Params]:
        """Process the full prompt; returns (last-position logits, cache)."""
        x, cache = self.hidden(params, batch, mode="prefill")
        last = x[:, -1:]
        lg = blocks.logits(params["embed"], last, self.cfg)
        return lg[:, 0], cache

    def decode_step(
        self,
        params: Params,
        batch: dict,  # {"tokens": [B,1], (+"image_embeds" for vlm)}
        cache: Params,
        lengths: jax.Array,  # [B]
    ) -> tuple[jax.Array, Params]:
        """One token for every sequence; returns (logits [B, V], new cache)."""
        x, new_cache = self.hidden(
            params, batch, mode="decode", cache=cache, lengths=lengths
        )
        lg = blocks.logits(params["embed"], x, self.cfg)
        return lg[:, 0], new_cache

    def init_cache(self, batch: int, s_max: int) -> Params:
        return transformer.init_stack_cache(
            self.cfg, batch, s_max, jnp.dtype(self.cfg.dtype)
        )

    def abstract_cache(self, batch: int, s_max: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, s_max))
