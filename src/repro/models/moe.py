"""Top-k mixture-of-experts layer (Mixtral / Grok-1 style).

GShard-style grouped capacity dispatch, the SPMD-proven formulation:

  tokens -> groups of ``group_size`` -> router top-k -> position-in-expert
  via cumsum -> one-hot dispatch einsum -> per-expert FFN -> combine einsum.

Sharding (DESIGN.md §5): with 8 experts on a 16-wide ``model`` axis, experts
cannot shard the axis evenly, so the baseline layout replicates experts and
tensor-parallelizes ``d_ff`` over ``model`` (identical collective pattern to
the dense TP MLP: one all-reduce on the output projection).  Groups shard
over ``data``.  True expert-parallel placement over the 2-wide ``pod`` axis
(4 experts per pod) is available as the ``ep_axis`` variant exercised in the
§Perf iterations.

Capacity: C = group_size * top_k / n_experts * capacity_factor rounded up to
a 128 multiple (MXU alignment); overflow tokens drop (standard GShard
behaviour), underflow slots are zero-padded.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict


def init_moe(key: jax.Array, cfg: ArchConfig) -> Params:
    assert cfg.moe is not None
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff) / math.sqrt(cfg.n_layers)
    return {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(dt),
        "w1": (jax.random.normal(k2, (e, d, ff)) * s_in).astype(dt),
        "w3": (jax.random.normal(k3, (e, d, ff)) * s_in).astype(dt),
        "w2": (jax.random.normal(k4, (e, ff, d)) * s_out).astype(dt),
    }


def _dispatch_tensors(
    gates: jax.Array,  # [G, S, E] softmax router probs
    top_k: int,
    capacity: int,
):
    """Build (dispatch [G,S,E,C] one-hot, combine [G,S,E,C] gate-weighted).

    Position-in-expert via cumulative sum over the flattened (s, k) choice
    order; tokens beyond capacity drop.
    """
    g, s, e = gates.shape
    top_vals, top_idx = jax.lax.top_k(gates, top_k)  # [G,S,K]
    # renormalize the chosen gates (Mixtral: softmax over top-k logits ==
    # normalized top-k softmax probs)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # one-hot expert choice per (token, k): [G, S, K, E]
    choice = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    # priority order: k-th choices of all tokens, token-major within k
    # flatten (K, S) so primary choices fill capacity first
    choice_ks = choice.transpose(0, 2, 1, 3).reshape(g, top_k * s, e)
    pos_ks = jnp.cumsum(choice_ks, axis=1) - choice_ks  # position in expert
    pos = pos_ks.reshape(g, top_k, s, e).transpose(0, 2, 1, 3)  # [G,S,K,E]
    keep = (pos < capacity) & (choice > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    pos_oh = pos_oh * keep[..., None]
    # [G, S, K, E, C] -> sum over K: a token occupies one slot per choice
    dispatch = jnp.sum(pos_oh, axis=2)  # [G, S, E, C]
    combine = jnp.sum(pos_oh * top_vals[..., None, None], axis=2)
    return dispatch, combine


def apply_moe(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: [B, S, d] -> same shape."""
    assert cfg.moe is not None
    moe = cfg.moe
    b, s, d = x.shape
    dt = jnp.dtype(cfg.dtype)
    tokens = b * s
    gsz = min(moe.group_size, tokens)
    while tokens % gsz:  # fall back to the largest divisor (odd smoke shapes)
        gsz -= 1
    n_groups = tokens // gsz
    cap = moe.capacity(gsz)

    xg = x.reshape(n_groups, gsz, d)
    xg = shard(xg, "moe_groups")
    logits = jnp.einsum(
        "gsd,de->gse", xg, p["router"].astype(dt), preferred_element_type=jnp.float32
    )
    gates = jax.nn.softmax(logits, axis=-1)
    dispatch, combine = _dispatch_tensors(gates, moe.top_k, cap)
    dispatch = dispatch.astype(dt)
    combine = combine.astype(dt)

    # dispatch: [G,S,E,C] x [G,S,d] -> expert slabs [G,E,C,d]
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg, preferred_element_type=dt)
    xe = shard(xe, "moe_slots")
    w1 = shard(p["w1"].astype(dt), "w_moe_in")  # explicit FSDP gathers
    w3 = shard(p["w3"].astype(dt), "w_moe_in")
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", xe, w1, preferred_element_type=dt)
    ) * jnp.einsum("gecd,edf->gecf", xe, w3, preferred_element_type=dt)
    h = shard(h, "moe_ff")
    ye = jnp.einsum(
        "gecf,efd->gecd", h, shard(p["w2"].astype(dt), "w_moe_out"),
        preferred_element_type=dt,
    )
    ye = shard(ye, "moe_slots")
    # combine back: [G,S,E,C] x [G,E,C,d] -> [G,S,d]
    y = jnp.einsum("gsec,gecd->gsd", combine, ye, preferred_element_type=dt)
    return shard(y.reshape(b, s, d), "act_btd")


def moe_decode(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Decode-path MoE for [B, 1, d]: dense-gather formulation.

    With one token per sequence the capacity machinery degenerates; compute
    all experts' FFNs on the tiny token batch and mix with top-k gates
    (FLOPs = E/topk overhead on a [B, d] matmul — negligible vs attention
    over the KV cache, and keeps the decode graph static).
    """
    assert cfg.moe is not None
    dt = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    logits = jnp.einsum(
        "bsd,de->bse", x, p["router"].astype(dt), preferred_element_type=jnp.float32
    )
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, cfg.moe.top_k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    mix = jnp.zeros_like(gates).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(s)[None, :, None],
        top_idx,
    ].set(top_vals)  # [B,S,E] sparse gate weights
    h = jax.nn.silu(
        jnp.einsum("bsd,edf->bsef", x, p["w1"].astype(dt), preferred_element_type=dt)
    ) * jnp.einsum("bsd,edf->bsef", x, p["w3"].astype(dt), preferred_element_type=dt)
    # keep the (tiny) activations batch-sharded so the partitioner reshards
    # them instead of all-gathering the multi-GB expert weights
    h = shard(h, "moe_dec_h")
    ye = jnp.einsum("bsef,efd->bsed", h, p["w2"].astype(dt), preferred_element_type=dt)
    ye = shard(ye, "moe_dec_y")
    return jnp.einsum("bse,bsed->bsd", mix.astype(dt), ye)
