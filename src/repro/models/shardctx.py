"""Logical-axis sharding hooks for model code.

Model code annotates activations with *logical* names; the launcher installs
a rule set mapping logical names to mesh ``PartitionSpec``s.  Outside a rule
context (unit tests, single-device smoke runs) every hook is a no-op, so the
model zoo runs unmodified on one CPU device.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Mapping

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules() -> Mapping[str, P] | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Mapping[str, P] | None):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard(x: jax.Array, name: str) -> jax.Array:
    """Apply the PartitionSpec registered for ``name`` (no-op if absent)."""
    rules = current_rules()
    if rules is None or name not in rules:
        return x
    return jax.lax.with_sharding_constraint(x, rules[name])
