"""Composable layer stack: pattern units, scan-over-layers, KV/state caches.

The stack is organized as ``scan_unit``-sized *pattern units* (e.g. gemma3:
five local-attention layers + one global layer), scanned ``n_units`` times
with stacked parameters (one unit lowered once — keeps 62-layer HLO small
and gives XLA's SPMD partitioner the FSDP gather-in-loop structure), plus an
unscanned tail for non-dividing layer counts.

Layer kinds (config.layer_kinds): attn, attn_local, attn_cross, mamba,
mamba_shared_attn, mlstm, slstm.  MoE configs route the FFN of attention
layers through the grouped-dispatch MoE.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks, mamba2, moe, xlstm
from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Single layers
# ---------------------------------------------------------------------------


def init_layer(key: jax.Array, kind: str, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": blocks.init_rmsnorm(cfg.d_model, cfg)}
    if kind in ("attn", "attn_local"):
        p["attn"] = blocks.init_attention(ks[0], cfg)
        if cfg.d_ff > 0:
            p["ln2"] = blocks.init_rmsnorm(cfg.d_model, cfg)
            p["ffn"] = (
                moe.init_moe(ks[1], cfg) if cfg.moe else blocks.init_mlp(ks[1], cfg)
            )
    elif kind == "attn_cross":
        p["attn"] = blocks.init_attention(ks[0], cfg, cross=True)
        p["gate"] = jnp.zeros((), jnp.dtype(cfg.param_dtype))
        if cfg.d_ff > 0:
            p["ln2"] = blocks.init_rmsnorm(cfg.d_model, cfg)
            p["ffn"] = blocks.init_mlp(ks[1], cfg)
    elif kind in ("mamba", "mamba_shared_attn"):
        p["mamba"] = mamba2.init_mamba2(ks[0], cfg)
    elif kind == "mlstm":
        p["cell"] = xlstm.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["cell"] = xlstm.init_slstm(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    return p


def init_layer_cache(
    kind: str, cfg: ArchConfig, batch: int, s_max: int, dtype
) -> Params | None:
    """Decode-time cache structure for one layer (None in train mode).

    Sliding-window layers get a RING cache of size min(window, s_max)."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

    def kv(size):
        return {
            "k": jnp.zeros((batch, size, hkv, hd), dtype),
            "v": jnp.zeros((batch, size, hkv, hd), dtype),
        }

    if kind == "attn_local" and cfg.sliding_window:
        return kv(min(s_max, cfg.sliding_window))
    if kind in ("attn", "attn_local"):
        return kv(s_max)
    if kind == "attn_cross":
        return None  # image KV is recomputed from static context
    if kind == "mamba":
        return mamba2.init_mamba2_cache(cfg, batch, dtype)
    if kind == "mamba_shared_attn":
        return {"attn": kv(s_max), "mamba": mamba2.init_mamba2_cache(cfg, batch, dtype)}
    if kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_cache(cfg, batch)
    raise ValueError(kind)  # pragma: no cover


def _layer_window(kind: str, cfg: ArchConfig) -> int | None:
    if kind == "attn_local":
        return cfg.sliding_window
    return None


def apply_layer(
    p: Params,
    x: jax.Array,
    kind: str,
    cfg: ArchConfig,
    *,
    mode: str,  # train | prefill | decode
    cache: Params | None = None,
    lengths: jax.Array | None = None,
    img_ctx: jax.Array | None = None,
    shared_attn: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    """Returns (x_out, cache_out).  cache_out is the written/updated cache in
    prefill/decode modes, None in train mode."""
    window = _layer_window(kind, cfg)
    causal = not cfg.encoder_only
    new_cache = None

    if kind in ("attn", "attn_local"):
        h = shard(blocks.apply_rmsnorm(p["ln1"], x, cfg.norm_eps), "act_attn_in")
        if mode == "decode":
            a, new_cache = blocks.decode_attention_step(
                p["attn"], h, cache, lengths, cfg, window=window
            )
        else:
            a = blocks.apply_attention(
                p["attn"], h, cfg, causal=causal, window=window
            )
            if mode == "prefill":
                dt = jnp.dtype(cfg.dtype)
                k = jnp.einsum(
                    "bsd,dhk->bshk", h,
                    shard(p["attn"]["wk"].astype(dt), "w_kv"),
                    preferred_element_type=dt,
                )
                v = jnp.einsum(
                    "bsd,dhk->bshk", h,
                    shard(p["attn"]["wv"].astype(dt), "w_kv"),
                    preferred_element_type=dt,
                )
                pos = jnp.arange(h.shape[1])
                sin, cos = blocks.rope_tables(
                    pos, cfg.resolved_head_dim, cfg.rotary_fraction, cfg.rope_theta
                )
                k = blocks.apply_rope(k, sin[:, None], cos[:, None])
                if kind == "attn_local" and window:
                    # ring cache: keep the last `window` positions at slot
                    # abs_pos % window (RoPE already applied absolutely)
                    s = k.shape[1]
                    w = min(s, window)
                    k = jnp.roll(k[:, s - w :], (s - w) % w, axis=1)
                    v = jnp.roll(v[:, s - w :], (s - w) % w, axis=1)
                new_cache = {"k": shard(k, "cache_kv"), "v": shard(v, "cache_kv")}
        x = x + a
        if cfg.d_ff > 0:
            h2 = shard(
                blocks.apply_rmsnorm(p["ln2"], x, cfg.norm_eps), "act_attn_in"
            )
            if cfg.moe:
                f = (
                    moe.moe_decode(p["ffn"], h2, cfg)
                    if mode == "decode"
                    else moe.apply_moe(p["ffn"], h2, cfg)
                )
            else:
                f = blocks.apply_mlp(p["ffn"], h2, cfg)
            x = x + f

    elif kind == "attn_cross":
        h = shard(blocks.apply_rmsnorm(p["ln1"], x, cfg.norm_eps), "act_attn_in")
        a = blocks.apply_attention(p["attn"], h, cfg, kv_src=img_ctx, causal=False)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * a
        if cfg.d_ff > 0:
            h2 = shard(
                blocks.apply_rmsnorm(p["ln2"], x, cfg.norm_eps), "act_attn_in"
            )
            x = x + blocks.apply_mlp(p["ffn"], h2, cfg)

    elif kind == "mamba":
        h = shard(blocks.apply_rmsnorm(p["ln1"], x, cfg.norm_eps), "act_attn_in")
        if mode == "decode":
            m, new_cache = mamba2.apply_mamba2_decode(p["mamba"], h, cache, cfg)
        elif mode == "prefill":
            m, new_cache = mamba2.apply_mamba2(p["mamba"], h, cfg, return_state=True)
        else:
            m = mamba2.apply_mamba2(p["mamba"], h, cfg)
        x = x + m

    elif kind == "mamba_shared_attn":
        # zamba2: shared-weight attention block, then the mamba block
        h = shard(
            blocks.apply_rmsnorm(shared_attn["ln"], x, cfg.norm_eps), "act_attn_in"
        )
        if mode == "decode":
            a, attn_cache = blocks.decode_attention_step(
                shared_attn["attn"], h, cache["attn"], lengths, cfg
            )
        else:
            a = blocks.apply_attention(shared_attn["attn"], h, cfg, causal=True)
            attn_cache = None
            if mode == "prefill":
                dt = jnp.dtype(cfg.dtype)
                k = jnp.einsum(
                    "bsd,dhk->bshk", h,
                    shard(shared_attn["attn"]["wk"].astype(dt), "w_kv"),
                    preferred_element_type=dt,
                )
                v = jnp.einsum(
                    "bsd,dhk->bshk", h,
                    shard(shared_attn["attn"]["wv"].astype(dt), "w_kv"),
                    preferred_element_type=dt,
                )
                pos = jnp.arange(h.shape[1])
                sin, cos = blocks.rope_tables(
                    pos, cfg.resolved_head_dim, cfg.rotary_fraction, cfg.rope_theta
                )
                k = blocks.apply_rope(k, sin[:, None], cos[:, None])
                attn_cache = {"k": shard(k, "cache_kv"), "v": shard(v, "cache_kv")}
        x = x + a
        if cfg.d_ff > 0:
            h_mlp = shard(
                blocks.apply_rmsnorm(shared_attn["ln2"], x, cfg.norm_eps),
                "act_attn_in",
            )
            x = x + blocks.apply_mlp(shared_attn["mlp"], h_mlp, cfg)
        h = shard(blocks.apply_rmsnorm(p["ln1"], x, cfg.norm_eps), "act_attn_in")
        if mode == "decode":
            m, mamba_cache = mamba2.apply_mamba2_decode(
                p["mamba"], h, cache["mamba"], cfg
            )
        elif mode == "prefill":
            m, mamba_cache = mamba2.apply_mamba2(p["mamba"], h, cfg, return_state=True)
        else:
            m = mamba2.apply_mamba2(p["mamba"], h, cfg)
            mamba_cache = None
        x = x + m
        if mode != "train":
            new_cache = {"attn": attn_cache, "mamba": mamba_cache}

    elif kind in ("mlstm", "slstm"):
        h = shard(blocks.apply_rmsnorm(p["ln1"], x, cfg.norm_eps), "act_attn_in")
        if kind == "mlstm":
            if mode == "decode":
                y, new_cache = xlstm.apply_mlstm_decode(p["cell"], h, cache, cfg)
            elif mode == "prefill":
                y, new_cache = xlstm.apply_mlstm(p["cell"], h, cfg, return_state=True)
            else:
                y = xlstm.apply_mlstm(p["cell"], h, cfg)
        else:
            if mode == "decode":
                y, new_cache = xlstm.apply_slstm_decode(p["cell"], h, cache, cfg)
            elif mode == "prefill":
                y, new_cache = xlstm.apply_slstm(p["cell"], h, cfg, return_state=True)
            else:
                y = xlstm.apply_slstm(p["cell"], h, cfg)
        x = x + y

    else:  # pragma: no cover
        raise ValueError(kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stack: scanned pattern units + tail
# ---------------------------------------------------------------------------


def needs_shared_attn(cfg: ArchConfig) -> bool:
    return any(k == "mamba_shared_attn" for k in cfg.layer_kinds())


def init_stack(key: jax.Array, cfg: ArchConfig) -> Params:
    unit, n_units, tail = cfg.scan_pattern()
    k_units, k_tail, k_shared = jax.random.split(key, 3)

    def init_unit(k):
        return {
            f"l{i}": init_layer(jax.random.fold_in(k, i), kind, cfg)
            for i, kind in enumerate(unit)
        }

    p: Params = {}
    if n_units:
        p["units"] = jax.vmap(init_unit)(jax.random.split(k_units, n_units))
    p["tail"] = {
        f"t{i}": init_layer(jax.random.fold_in(k_tail, i), kind, cfg)
        for i, kind in enumerate(tail)
    }
    if needs_shared_attn(cfg):
        # zamba2: one shared attention+MLP block reused at every application
        p["shared_attn"] = {
            "ln": blocks.init_rmsnorm(cfg.d_model, cfg),
            "attn": blocks.init_attention(k_shared, cfg),
            "ln2": blocks.init_rmsnorm(cfg.d_model, cfg),
            "mlp": blocks.init_mlp(jax.random.fold_in(k_shared, 1), cfg),
        }
    return p


def init_stack_cache(cfg: ArchConfig, batch: int, s_max: int, dtype) -> Params:
    unit, n_units, tail = cfg.scan_pattern()

    def unit_cache():
        return {
            f"l{i}": init_layer_cache(kind, cfg, batch, s_max, dtype)
            for i, kind in enumerate(unit)
        }

    cache: Params = {}
    if n_units:
        cache["units"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[unit_cache() for _ in range(n_units)]
        )
    cache["tail"] = {
        f"t{i}": init_layer_cache(kind, cfg, batch, s_max, dtype)
        for i, kind in enumerate(tail)
    }
    return cache


def _remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat == "dots"
        else None
    )
    return jax.checkpoint(fn, policy=policy)


def apply_stack(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    mode: str,
    cache: Params | None = None,
    lengths: jax.Array | None = None,
    img_ctx: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    unit, n_units, tail = cfg.scan_pattern()
    shared = p.get("shared_attn")
    want_cache = mode in ("prefill", "decode")

    def apply_unit(unit_params, x, unit_cache):
        new_caches = {}
        for i, kind in enumerate(unit):
            lc = unit_cache.get(f"l{i}") if unit_cache is not None else None
            x, nc = apply_layer(
                unit_params[f"l{i}"],
                x,
                kind,
                cfg,
                mode=mode,
                cache=lc,
                lengths=lengths,
                img_ctx=img_ctx,
                shared_attn=shared,
            )
            new_caches[f"l{i}"] = nc
        return x, new_caches

    new_unit_caches = None
    if n_units:
        if cache is not None:  # decode: thread per-unit caches through xs
            def body(carry, xs):
                unit_params, unit_cache = xs
                y, ncache = apply_unit(unit_params, carry, unit_cache)
                return y, ncache

            x, new_unit_caches = jax.lax.scan(body, x, (p["units"], cache["units"]))
        elif want_cache:  # prefill: emit produced caches as scan ys
            def body(carry, unit_params):
                y, ncache = apply_unit(unit_params, carry, None)
                return y, ncache

            x, new_unit_caches = jax.lax.scan(body, x, p["units"])
        else:  # train: no caches; remat each unit

            def body(carry, unit_params):
                y, _ = apply_unit(unit_params, carry, None)
                return y, None

            x, _ = jax.lax.scan(_remat_wrap(body, cfg), x, p["units"])

    new_tail = {}
    for i, kind in enumerate(tail):
        lc = cache["tail"].get(f"t{i}") if cache is not None else None
        x, nc = apply_layer(
            p["tail"][f"t{i}"],
            x,
            kind,
            cfg,
            mode=mode,
            cache=lc,
            lengths=lengths,
            img_ctx=img_ctx,
            shared_attn=shared,
        )
        new_tail[f"t{i}"] = nc

    out_cache = None
    if want_cache:
        out_cache = {"tail": new_tail}
        if n_units:
            out_cache["units"] = new_unit_caches
    return x, out_cache
