"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, true recurrence) — Beck et al. 2024 [arXiv:2405.04517].

Implementation notes (DESIGN.md assumptions log):
 * mLSTM uses the chunkwise-parallel form (same machinery as SSD): intra-
   chunk decay-masked q k^T matmuls on the MXU + an inter-chunk scan over
   (C, n) state.  We use the bounded-gate variant (log-sigmoid forget gates,
   clipped exponential input gates, fp32 accumulation, denominator
   max(|q n|, 1)) rather than the paper's running-max stabilizer — tested
   stable to 500k-step rollouts in fp32.
 * sLSTM is a genuine hidden-to-hidden recurrence (block-diagonal R per
   head) and cannot be parallelized over time; it runs as a lax.scan over
   timesteps with the x-projections hoisted out of the loop.
 * Per the xLSTM architecture these blocks replace attention+FFN entirely
   (d_ff = 0 in the assigned config); the 48-layer stack alternates
   mLSTM with an sLSTM every ``slstm_every`` layers (7:1).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.shardctx import shard

Params = dict


def _dims(cfg: ArchConfig):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return h, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key: jax.Array, cfg: ArchConfig) -> Params:
    h, dh = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    # projections: q, k, v (d each), gates i, f (h each), output gate z (d)
    return {
        "wqkvz": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(dt),
        "wif": (jax.random.normal(ks[1], (d, 2 * h)) * s).astype(dt),
        "b_if": jnp.concatenate(
            [jnp.full((h,), -2.0), jnp.full((h,), 3.0)]
        ).astype(dt),  # input gates start small, forget gates near 1
        "norm_scale": jnp.zeros((d,), dt),
        "out_proj": (
            jax.random.normal(ks[2], (d, d)) * s / math.sqrt(cfg.n_layers)
        ).astype(dt),
    }


def _mlstm_gates(p: Params, x: jax.Array, cfg: ArchConfig):
    h, dh = _dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    qkvz = jnp.einsum(
        "...d,dk->...k", x, shard(p["wqkvz"].astype(dt_c), "w_dense"),
        preferred_element_type=dt_c,
    )
    q, k, v, z = jnp.split(qkvz, 4, axis=-1)
    gates = (x @ p["wif"].astype(dt_c)).astype(jnp.float32) + p["b_if"].astype(
        jnp.float32
    )
    ig, fg = gates[..., :h], gates[..., h:]
    log_f = jax.nn.log_sigmoid(fg)  # <= 0
    log_i = jnp.clip(ig, -10.0, 10.0)  # bounded exponential input gate
    shape = x.shape[:-1] + (h, dh)
    return (
        q.reshape(shape),
        k.reshape(shape) / math.sqrt(dh),
        v.reshape(shape),
        z,
        log_f,
        log_i,
    )


def apply_mlstm(
    p: Params, x: jax.Array, cfg: ArchConfig, *, return_state: bool = False
):
    """Full-sequence chunkwise mLSTM.  x: [B, S, d]."""
    h, dh = _dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    bsz, s, d = x.shape
    qh, kh, vh, z, log_f, log_i = _mlstm_gates(p, x, cfg)
    chunk = min(cfg.xlstm.mlstm_chunk, s)
    while s % chunk:  # largest divisor <= chunk (odd smoke shapes)
        chunk -= 1
    n_chunks = s // chunk

    def to_chunks(t, extra=()):
        return jnp.moveaxis(
            t.reshape((bsz, n_chunks, chunk) + t.shape[2:]), 1, 0
        )

    qc = to_chunks(qh.astype(jnp.float32))
    kc = to_chunks(kh.astype(jnp.float32))
    vc = to_chunks(vh.astype(jnp.float32))
    fc = to_chunks(log_f)
    ic = to_chunks(log_i)

    def chunk_step(carry, inp):
        c_state, n_state = carry  # [B,H,dh,dh], [B,H,dh]
        qk, kk, vk, fk, ik = inp
        cum = jnp.cumsum(fk, axis=1)  # [B,Q,H] inclusive
        # intra-chunk: D[t,s] = exp(cum_t - cum_s + i_s), s <= t
        diff = cum[:, :, None, :] - cum[:, None, :, :] + ik[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask BEFORE exp (overflow + grad-NaN safety), exp(-inf) == 0
        decay = jnp.exp(
            jnp.where(tri[None, :, :, None], diff, -jnp.inf)
        )  # [B,Q,Q,H]
        scores = jnp.einsum("bthd,bshd->btsh", qk, kk) * decay
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vk)
        n_intra = jnp.einsum("btsh,bshd->bthd", decay, kk)
        # inter-chunk state contribution
        carry_scale = jnp.exp(cum)  # [B,Q,H]
        y_state = jnp.einsum("bthd,bhde,bth->bthe", qk, c_state, carry_scale)
        n_carry = jnp.einsum("bthd,bhd,bth->bth", qk, n_state, carry_scale)
        denom_vec = jnp.einsum("bthd,bthd->bth", qk, n_intra) + n_carry
        y = (y_intra + y_state) / jnp.maximum(jnp.abs(denom_vec), 1.0)[..., None]
        # state update
        w = jnp.exp(cum[:, -1:, :] - cum + ik)  # [B,Q,H]
        c_new = c_state * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bth,bthd,bthe->bhde", w, kk, vk
        )
        n_new = n_state * jnp.exp(cum[:, -1])[:, :, None] + jnp.einsum(
            "bth,bthd->bhd", w, kk
        )
        return (c_new, n_new), y

    c0 = jnp.zeros((bsz, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((bsz, h, dh), jnp.float32)
    (c_fin, n_fin), ys = jax.lax.scan(
        jax.checkpoint(chunk_step), (c0, n0), (qc, kc, vc, fc, ic)
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, d).astype(dt_c)
    y = y * jax.nn.silu(z)
    xf = y.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (
        xf * jax.lax.rsqrt(var + cfg.norm_eps)
        * (1.0 + p["norm_scale"].astype(jnp.float32))
    ).astype(dt_c)
    out = shard(y @ p["out_proj"].astype(dt_c), "act_btd")
    if return_state:
        return out, {"c": c_fin, "n": n_fin}
    return out


def init_mlstm_cache(cfg: ArchConfig, batch: int) -> Params:
    h, dh = _dims(cfg)
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
    }


def apply_mlstm_decode(
    p: Params, x: jax.Array, cache: Params, cfg: ArchConfig
) -> tuple[jax.Array, Params]:
    """One-token recurrent step.  x: [B, 1, d]."""
    h, dh = _dims(cfg)
    dt_c = jnp.dtype(cfg.dtype)
    bsz = x.shape[0]
    qh, kh, vh, z, log_f, log_i = _mlstm_gates(p, x, cfg)
    q1 = qh[:, 0].astype(jnp.float32)  # [B,H,dh]
    k1 = kh[:, 0].astype(jnp.float32)
    v1 = vh[:, 0].astype(jnp.float32)
    f1 = jnp.exp(log_f[:, 0])[..., None, None]  # [B,H,1,1]
    i1 = jnp.exp(log_i[:, 0])[..., None, None]
    c_new = cache["c"] * f1 + i1 * k1[..., :, None] * v1[..., None, :]
    n_new = cache["n"] * f1[..., 0] + i1[..., 0] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_new)), 1.0)
    y = (num / den[..., None]).reshape(bsz, 1, cfg.d_model).astype(dt_c)
    y = y * jax.nn.silu(z)
    xf = y.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (
        xf * jax.lax.rsqrt(var + cfg.norm_eps)
        * (1.0 + p["norm_scale"].astype(jnp.float32))
    ).astype(dt_c)
    return y @ p["out_proj"].astype(dt_c), {"c": c_new, "n": n_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key: jax.Array, cfg: ArchConfig) -> Params:
    h, dh = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        # x-projections for gates i, f, z, o
        "wx": (jax.random.normal(ks[0], (d, 4 * d)) * s).astype(dt),
        # block-diagonal recurrent matrices per head, per gate
        "r": (jax.random.normal(ks[1], (4, h, dh, dh)) / math.sqrt(dh)).astype(dt),
        "bias": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]
        ).astype(dt),
        "norm_scale": jnp.zeros((d,), dt),
        "out_proj": (
            jax.random.normal(ks[2], (d, d)) * s / math.sqrt(cfg.n_layers)
        ).astype(dt),
    }


def _slstm_cell(p: Params, xg: jax.Array, state, cfg: ArchConfig):
    """One sLSTM step.  xg: [B, 4d] precomputed x-projection + bias."""
    h_, dh = _dims(cfg)
    hp, cp, np_, mp = state  # h, c, n (all [B,d]), m [B,d] stabilizer
    bsz = xg.shape[0]
    hh = hp.reshape(bsz, h_, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hh.astype(jnp.float32), p["r"].astype(jnp.float32))
    rec = rec.reshape(bsz, 4 * hp.shape[-1])
    pre = xg.astype(jnp.float32) + rec
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    log_i = jnp.clip(i_raw, -10.0, 10.0)
    m_new = jnp.maximum(log_f + mp, log_i)
    i_g = jnp.exp(log_i - m_new)
    f_g = jnp.exp(log_f + mp - m_new)
    z_g = jnp.tanh(z_raw)
    o_g = jax.nn.sigmoid(o_raw)
    c_new = f_g * cp + i_g * z_g
    n_new = f_g * np_ + i_g
    h_new = o_g * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def apply_slstm(
    p: Params, x: jax.Array, cfg: ArchConfig, *, return_state: bool = False
):
    """Sequential scan over time.  x: [B, S, d]."""
    dt_c = jnp.dtype(cfg.dtype)
    bsz, s, d = x.shape
    xg = jnp.einsum(
        "bsd,dk->bsk", x, shard(p["wx"].astype(dt_c), "w_dense"),
        preferred_element_type=dt_c,
    ) + p["bias"].astype(dt_c)  # [B,S,4d]

    def step(state, xg_t):
        new = _slstm_cell(p, xg_t, state, cfg)
        return new, new[0]

    z0 = jnp.zeros((bsz, d), jnp.float32)
    state0 = (z0, z0, z0, jnp.full((bsz, d), -1e9, jnp.float32))
    fin, hs = jax.lax.scan(step, state0, jnp.moveaxis(xg, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(dt_c)
    xf = y.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (
        xf * jax.lax.rsqrt(var + cfg.norm_eps)
        * (1.0 + p["norm_scale"].astype(jnp.float32))
    ).astype(dt_c)
    out = shard(y @ p["out_proj"].astype(dt_c), "act_btd")
    if return_state:
        return out, {"h": fin[0], "c": fin[1], "n": fin[2], "m": fin[3]}
    return out


def init_slstm_cache(cfg: ArchConfig, batch: int) -> Params:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, d), -1e9, jnp.float32)}


def apply_slstm_decode(
    p: Params, x: jax.Array, cache: Params, cfg: ArchConfig
) -> tuple[jax.Array, Params]:
    dt_c = jnp.dtype(cfg.dtype)
    xg = x[:, 0] @ p["wx"].astype(dt_c) + p["bias"].astype(dt_c)
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h_new, c_new, n_new, m_new = _slstm_cell(p, xg, state, cfg)
    y = h_new[:, None].astype(dt_c)
    xf = y.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = (
        xf * jax.lax.rsqrt(var + cfg.norm_eps)
        * (1.0 + p["norm_scale"].astype(jnp.float32))
    ).astype(dt_c)
    return (
        y @ p["out_proj"].astype(dt_c),
        {"h": h_new, "c": c_new, "n": n_new, "m": m_new},
    )
