"""Trip-count-aware analysis of compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically — a scan of N matmuls reports ~1/N of the true flops), so we
walk the HLO call graph ourselves:

 * computations reached through ``while`` bodies multiply their costs by the
   loop trip count (inferred from the largest integer constant compared
   against the induction variable in the loop condition);
 * ``fusion`` ops are costed at the call site — one read of each operand +
   one write of the result (fused internals stay on-chip), matching the
   HBM-traffic roofline convention;
 * dot FLOPs = 2 x numel(result) x prod(contracted lhs dims);
 * collective bytes use per-device ring-traffic weights:
   all-reduce 2x, all-gather/all-to-all/reduce-scatter/collective-permute
   1x their (max of operand/result) payload.

Returned sizes are PER DEVICE (the compiled module is the SPMD-partitioned
per-device program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_COLLECTIVE_WEIGHT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

#: ops that move no data (views / bookkeeping)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shapes_in(text: str) -> list[tuple[str, int]]:
    """All 'dtype[a,b,c]' shapes in a string -> [(dtype, numel)]."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        out.append((dt, numel))
    return out


def _bytes_of(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * n for dt, n in _shapes_in(text))


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    result_shape: str  # 'f32[256,256]' prefix of the rhs
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo]
    #: symbol table: op name -> result shape text
    shapes: dict[str, str]
    root: str | None = None

    def loop_invariant_symbols(self, resident_budget: int = 64 << 20) -> set[str]:
        """Carry slots whose reads are VMEM-resident across iterations.

        Two classes, both billed once per loop entry instead of per trip:
         * loop-INVARIANT slots (GTE passed through unchanged to the ROOT
           tuple at the same index) — recurrent weights, stacked params;
         * small CHANGING carries (< ``resident_budget`` bytes) — running
           gradient accumulators / recurrent states that fit v5e's 128 MB
           VMEM and never round-trip HBM inside the loop.
        Multi-GB carries (KV caches) stay billed per access.
        """
        gte_by_name: dict[str, int] = {}
        for op in self.ops:
            if op.kind == "get-tuple-element":
                m = re.search(r"index=(\d+)", op.line)
                if m:
                    gte_by_name[op.name] = int(m.group(1))
        out = set()
        # small carries are resident regardless of invariance
        for nm in gte_by_name:
            if _bytes_of(self.shapes.get(nm, "")) <= resident_budget:
                out.add(nm)
        if self.root is None or self.root not in self.shapes:
            return out
        root_op = next((o for o in self.ops if o.name == self.root), None)
        if root_op is None or root_op.kind != "tuple":
            return out
        m = re.search(r"tuple\(([^)]*)\)", root_op.line)
        if not m:
            return out
        for j, nm in enumerate(_OPERAND_RE.findall(m.group(1))):
            if gte_by_name.get(nm) == j:
                out.add(nm)
        return out


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Parse computations; returns (computations, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and "=" not in stripped.split("(")[0]:
                cur = Computation(name=m.group(1), ops=[], shapes={})
                if stripped.startswith("ENTRY"):
                    entry = m.group(1)
                continue
        else:
            if stripped == "}" or stripped.startswith("} "):
                comps[cur.name] = cur
                cur = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.groups()
            if line.lstrip().startswith("ROOT"):
                cur.root = name
            result_shape = rhs.split(" ", 1)[0]
            # op kind: first identifier after the result shape
            after = rhs
            # strip result shape + layout braces
            km = re.search(r"\}?\s*([a-z][a-z0-9\-]*)\(", after)
            kind = km.group(1) if km else "unknown"
            # async collectives: 'all-reduce-start' etc.
            cur.shapes[name] = result_shape
            cur.ops.append(
                OpInfo(name=name, kind=kind, result_shape=result_shape, line=rhs)
            )
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (scan upper bound)."""
    best = 1
    for op in cond.ops:
        for m in re.finditer(r"constant\((\d+)\)", op.line):
            best = max(best, int(m.group(1)))
    return best


_CALL_RE = re.compile(r"(?:calls|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


@dataclasses.dataclass
class HLOCosts:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    while_trips: list = dataclasses.field(default_factory=list)

    def add_collective(self, kind: str, byts: float, mult: float):
        w = _COLLECTIVE_WEIGHT[kind]
        self.collective_bytes += w * byts * mult
        self.collective_by_kind[kind] = (
            self.collective_by_kind.get(kind, 0.0) + w * byts * mult
        )
        self.collective_counts[kind] = self.collective_counts.get(kind, 0) + mult


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    """2 * numel(result) * prod(contracted lhs dims)."""
    res = _shapes_in(op.result_shape)
    if not res:
        return 0.0
    numel_res = res[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m:
        return 0.0
    cdims = [int(x) for x in m.group(1).split(",") if x]
    # first operand name after 'dot('
    dm = re.search(r"dot\(([^)]*)\)", op.line)
    if not dm:
        return 0.0
    # lhs may be inline-shaped (f32[..] %x) or a bare reference (%x); split
    # on operand boundaries, not the commas inside shape brackets
    lhs_txt = re.split(r",\s+(?=[a-z0-9]+\[|%)", dm.group(1))[0].strip()
    sm = _SHAPE_RE.search(lhs_txt)
    if sm:
        dims = [int(x) for x in sm.group(2).split(",") if x]
    else:
        nm = _OPERAND_RE.search(lhs_txt)
        if not nm or nm.group(1) not in comp.shapes:
            return 0.0
        raw = _SHAPE_RE.search(comp.shapes[nm.group(1)])
        dims = [int(x) for x in raw.group(2).split(",") if x] if raw else []
    contracted = 1
    for c in cdims:
        if c < len(dims):
            contracted *= dims[c]
    return 2.0 * numel_res * contracted


def _operands(op: OpInfo, comp: Computation) -> list[str]:
    call_args = re.search(r"\(([^)]*)\)", op.line)
    if not call_args:
        return []
    return [nm for nm in _OPERAND_RE.findall(call_args.group(1)) if nm in comp.shapes]


def _op_traffic_split(
    op: OpInfo, comp: Computation, comps=None, invariant: set[str] | None = None
) -> tuple[float, float]:
    """(variant_bytes, invariant_bytes) — invariant operands are billed once
    per loop entry by the walker (VMEM-resident across iterations)."""
    invariant = invariant or set()
    res = _bytes_of(op.result_shape)
    kind = op.kind
    if kind in ("dynamic-slice", "slice", "gather"):
        # slices of (possibly invariant) stacks read fresh data per iter
        return 2.0 * res, 0.0
    if kind == "dynamic-update-slice":
        ops = _operands(op, comp)
        upd = _bytes_of(comp.shapes[ops[1]]) if len(ops) > 1 else 0
        return 3.0 * upd, 0.0
    if kind == "scatter":
        ops = _operands(op, comp)
        upd = _bytes_of(comp.shapes[ops[-1]]) if ops else 0
        return 3.0 * upd, 0.0
    if kind == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        fused = comps.get(m.group(1)) if m else None
        if fused is not None:
            pidx: dict[int, str] = {}
            for fop in fused.ops:
                pm = re.search(r"parameter\((\d+)\)", fop.line)
                if pm:
                    pidx[int(pm.group(1))] = fop.name
            reads: dict[str, float] = defaultdict(float)
            for fop in fused.ops:
                if fop.kind == "parameter":
                    continue
                f_ops = _operands(fop, fused)
                if fop.kind in ("dynamic-slice", "slice", "gather"):
                    for nm in f_ops:
                        reads[nm] += _bytes_of(fop.result_shape)
                elif fop.kind == "dynamic-update-slice" and len(f_ops) >= 2:
                    dest, upd = f_ops[0], f_ops[1]
                    ub = _bytes_of(fused.shapes[upd])
                    reads[dest] += 2.0 * ub
                    reads[upd] += ub
                else:
                    for nm in f_ops:
                        reads[nm] += _bytes_of(fused.shapes[nm])
            var, inv = float(res), 0.0
            for i, nm in enumerate(_operands(op, comp)):
                full = _bytes_of(comp.shapes[nm])
                pname = pidx.get(i)
                billed = min(full, reads[pname]) if pname in reads else full
                if nm in invariant:
                    inv += billed
                else:
                    var += billed
            return var, inv
    var, inv = float(res), 0.0
    for nm in _operands(op, comp):
        if nm in invariant:
            inv += _bytes_of(comp.shapes[nm])
        else:
            var += _bytes_of(comp.shapes[nm])
    return var, inv


def _op_traffic(op: OpInfo, comp: Computation, comps=None) -> float:
    """HBM-traffic estimate (bytes) for a top-level (unfused) op.

    Slice-like ops read only what they produce — counting the full operand
    would bill a scan's stacked-parameter tensor once per iteration:
     * dynamic-slice / slice: 2x result (read slice + write),
     * dynamic-update-slice: 2x update + result-write of the touched region
       (operand 0 aliases the result),
     * gather: 2x result,
     * fusion: result + per-parameter read, where a parameter consumed only
       by slicing ops inside the fused computation counts its sliced size.
    """
    res = _bytes_of(op.result_shape)
    kind = op.kind
    if kind in ("dynamic-slice", "slice", "gather"):
        return 2.0 * res
    if kind == "dynamic-update-slice":
        ops = _operands(op, comp)
        upd = _bytes_of(comp.shapes[ops[1]]) if len(ops) > 1 else 0
        return 3.0 * upd  # read update, read+write the touched region
    if kind == "scatter":
        ops = _operands(op, comp)
        upd = _bytes_of(comp.shapes[ops[-1]]) if ops else 0
        return 3.0 * upd
    if kind == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        fused = comps.get(m.group(1)) if m else None
        if fused is not None:
            # parameter index -> def name inside the fused computation
            pidx: dict[int, str] = {}
            for fop in fused.ops:
                pm = re.search(r"parameter\((\d+)\)", fop.line)
                if pm:
                    pidx[int(pm.group(1))] = fop.name
            # bytes actually read from each symbol inside the fusion
            reads: dict[str, float] = defaultdict(float)
            for fop in fused.ops:
                if fop.kind == "parameter":
                    continue
                f_ops = _operands(fop, fused)
                if fop.kind in ("dynamic-slice", "slice", "gather"):
                    for nm in f_ops:
                        reads[nm] += _bytes_of(fop.result_shape)
                elif fop.kind == "dynamic-update-slice" and len(f_ops) >= 2:
                    dest, upd = f_ops[0], f_ops[1]
                    ub = _bytes_of(fused.shapes[upd])
                    reads[dest] += 2.0 * ub  # read+write touched region
                    reads[upd] += ub
                else:
                    for nm in f_ops:
                        reads[nm] += _bytes_of(fused.shapes[nm])
            total = res
            for i, nm in enumerate(_operands(op, comp)):
                full = _bytes_of(comp.shapes[nm])
                pname = pidx.get(i)
                billed = min(full, reads[pname]) if pname in reads else full
                total += billed
            return total
    total = res
    for nm in _operands(op, comp):
        total += _bytes_of(comp.shapes[nm])
    return total


def analyze(text: str) -> HLOCosts:
    comps, entry = parse_hlo(text)
    costs = HLOCosts()
    if entry is None:
        return costs

    def walk(
        comp_name: str,
        mult: float,
        inv_mult: float,
        depth: int = 0,
        extra_invariant: frozenset[str] = frozenset(),
    ):
        """``mult``: per-iteration execution count; ``inv_mult``: count for
        loop-invariant operand reads (once per enclosing-loop entry).
        ``extra_invariant``: callee parameter names bound to loop-invariant
        caller operands (the CPU backend wraps fusions in ``call`` ops, which
        would otherwise hide a stacked carry's invariance from the billing)."""
        if depth > 32 or comp_name not in comps:
            return
        comp = comps[comp_name]
        invariant = comp.loop_invariant_symbols() | extra_invariant
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                cm = _COND_RE.search(op.line)
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                trips = _trip_count(comps[cm.group(1)]) if cm and cm.group(1) in comps else 1
                costs.while_trips.append(trips)
                if bm:
                    walk(bm.group(1), mult * trips, mult, depth + 1)
                continue
            if kind == "conditional":
                for br in re.findall(r"%([\w.\-]+)", op.line.split("conditional", 1)[1]):
                    if br in comps:
                        walk(br, mult, inv_mult, depth + 1)
                continue
            if kind == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", op.line)
                if m:
                    callee_inv = set()
                    callee = comps.get(m.group(1))
                    if callee is not None:
                        pidx = {}
                        for fop in callee.ops:
                            pm = re.search(r"parameter\((\d+)\)", fop.line)
                            if pm:
                                pidx[int(pm.group(1))] = fop.name
                        for i, nm in enumerate(_operands(op, comp)):
                            if nm in invariant and i in pidx:
                                callee_inv.add(pidx[i])
                    walk(
                        m.group(1), mult, inv_mult, depth + 1,
                        frozenset(callee_inv),
                    )
                continue
            base = kind.replace("-start", "")
            if base in _COLLECTIVES:
                payload = max(
                    _bytes_of(op.result_shape),
                    _op_traffic(op, comp, comps) - _bytes_of(op.result_shape),
                )
                costs.add_collective(base, payload, mult)
                costs.traffic_bytes += _op_traffic(op, comp, comps) * mult
                continue
            if kind.endswith("-done"):
                continue
            if kind in _FREE_OPS:
                continue
            if kind == "dot":
                costs.dot_flops += _dot_flops(op, comp) * mult
            if kind == "fusion":
                # dots inside fused computations still execute
                m = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m and m.group(1) in comps:
                    fused = comps[m.group(1)]
                    for fop in fused.ops:
                        if fop.kind == "dot":
                            costs.dot_flops += _dot_flops(fop, fused) * mult
            var, inv = _op_traffic_split(op, comp, comps, invariant)
            costs.traffic_bytes += var * mult + inv * inv_mult

    walk(entry, 1.0, 1.0)
    return costs
