"""Three-term roofline model (TPU v5e constants) + power-scaled variants.

The same module serves two masters (DESIGN.md §7):
 * §Roofline reporting at full power — compute/memory/collective seconds per
   (arch x shape x mesh) from the dry-run's analyzed HLO;
 * the EcoShift emulator — step time as a function of (host cap, chip cap),
   which is how the 10 assigned architectures become "applications" with
   power-performance surfaces.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# -- TPU v5e hardware constants (per chip) ----------------------------------
PEAK_BF16_FLOPS = 197e12  # MXU bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (~4 links usable; we budget 1 link/term)
CHIP_TDP_W = 250.0  # nominal chip power envelope used by the power model
HOST_TDP_W = 450.0  # host (CPU) power envelope per 8-chip host


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """All terms in seconds (per training/serving step, per device)."""

    compute_s: float
    memory_s: float
    collective_s: float
    host_s: float = 0.0

    @property
    def step_s(self) -> float:
        """Perfect-overlap model: the slowest engine wins."""
        return max(self.compute_s, self.memory_s, self.collective_s, self.host_s)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
            "host": self.host_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "host_s": self.host_s,
            "step_s": self.step_s,
            "bottleneck": self.bottleneck,
        }


def terms_from_perdevice(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    freq_frac: float = 1.0,
    host_bytes_per_device: float = 0.0,
    host_frac: float = 1.0,
) -> RooflineTerms:
    """Roofline terms from per-device quantities (compiled SPMD module).

    ``freq_frac`` scales the chip clock (power capping): MXU throughput
    scales ~linearly with clock; HBM bandwidth is partially clock-coupled
    (beta=0.5 exponent — memory controllers derate slower than core clock).
    ``host_frac`` scales host-side throughput with the host power cap.
    """
    compute = flops_per_device / (PEAK_BF16_FLOPS * freq_frac)
    memory = bytes_per_device / (HBM_BW * freq_frac**0.5)
    collective = collective_bytes_per_device / ICI_BW
    host = host_bytes_per_device / (2e9 * host_frac) if host_bytes_per_device else 0.0
    return RooflineTerms(
        compute_s=compute, memory_s=memory, collective_s=collective, host_s=host
    )


def model_flops(cfg, shape_info: dict) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens.

    For decode steps D = batch (one token each).  Returns GLOBAL flops for
    one step; divide by chips for the per-device 'useful' figure.
    """
    n = param_count(cfg, active_only=True)
    if shape_info["kind"] == "train":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 6.0 * n * tokens
    if shape_info["kind"] == "prefill":
        tokens = shape_info["batch"] * shape_info["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * shape_info["batch"]  # decode: one token per sequence


def param_count(cfg, *, active_only: bool = False) -> float:
    """Analytic parameter count (embedding + per-layer weights)."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    mlp = 3 * d * ff
    total = 0.0
    kinds = cfg.layer_kinds()
    shared_counted = False
    for k in kinds:
        if k in ("attn", "attn_local", "attn_cross"):
            total += attn
            if ff:
                if cfg.moe:
                    e = cfg.moe.n_experts
                    use = cfg.moe.top_k if active_only else e
                    total += use * mlp + d * e
                else:
                    total += mlp
        elif k in ("mamba", "mamba_shared_attn"):
            ssm = cfg.ssm
            d_in = ssm.d_inner(d)
            nh = ssm.n_heads(d)
            in_dim = 2 * d_in + 2 * ssm.d_state + nh
            total += d * in_dim + d_in * d
            if k == "mamba_shared_attn" and not shared_counted:
                total += attn + (mlp if ff else 0)
                shared_counted = True
        elif k == "mlstm":
            total += 4 * d * d + d * d  # qkvz + out
        elif k == "slstm":
            total += 4 * d * d + d * d  # wx + out (+ small R)
    total += v * d  # embedding
    if not cfg.tie_embeddings and not cfg.encoder_only:
        total += v * d  # head
    return total


# ---------------------------------------------------------------------------
# Power-scaled performance surfaces for the EcoShift emulator
# ---------------------------------------------------------------------------


def freq_fraction(chip_power_w: float, *, tdp: float = CHIP_TDP_W) -> float:
    """Monotone-concave DVFS curve: f/f_max as a function of the chip cap.

    Below ~40% TDP the chip can't sustain base clocks (floor 0.25); above
    TDP it saturates at 1.  Shape matches the diminishing-returns behaviour
    the paper measures on A100/H100 (§2 Fig. 2).
    """
    x = np.clip(chip_power_w / tdp, 0.0, 1.5)
    frac = 1.0 - np.exp(-(x - 0.18) / 0.35)
    return float(np.clip(frac, 0.25, 1.0))


def host_fraction(host_power_w: float, *, tdp: float = HOST_TDP_W) -> float:
    x = np.clip(host_power_w / tdp, 0.0, 1.5)
    frac = 1.0 - np.exp(-(x - 0.15) / 0.40)
    return float(np.clip(frac, 0.25, 1.0))


def step_time_under_caps(
    flops_pd: float,
    bytes_pd: float,
    coll_pd: float,
    host_bytes_pd: float,
    chip_cap_w: float,
    host_cap_w: float,
) -> float:
    """Emulator hook: step seconds under (host, chip) power caps."""
    t = terms_from_perdevice(
        flops_pd,
        bytes_pd,
        coll_pd,
        freq_frac=freq_fraction(chip_cap_w),
        host_bytes_per_device=host_bytes_pd,
        host_frac=host_fraction(host_cap_w),
    )
    return t.step_s
