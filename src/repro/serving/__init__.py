"""Serving substrate: KV-cache engine, batched prefill/decode."""
