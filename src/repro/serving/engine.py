"""Batched serving engine: prefill -> padded KV cache -> greedy decode.

Static-shape discipline throughout (dry-run and TPU friendly): the cache is
pre-padded to ``s_max``, per-sequence validity is tracked by a ``lengths``
vector, and every decode step is one fixed-shape jit call.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model

Params = dict[str, Any]


def pad_cache_to(cache: Params, target: Params | int) -> Params:
    """Pad every KV leaf's sequence axis (third from last) to its target.

    ``target`` is either the abstract cache structure for the serving
    ``s_max`` (ring-buffer leaves keep their window size) or a plain int
    applied to all KV leaves.  Non-KV state leaves (SSM states, conv tails,
    xLSTM matrix memories) pass through untouched.
    """

    def pad_leaf(leaf, want: int):
        s = leaf.shape[-3]
        if s < want:
            widths = [(0, 0)] * leaf.ndim
            widths[-3] = (0, want - s)
            return jnp.pad(leaf, widths)
        return leaf

    if isinstance(target, int):
        def pad(path, leaf):
            key = path[-1].key if hasattr(path[-1], "key") else None
            if key in ("k", "v") and leaf is not None:
                return pad_leaf(leaf, target)
            return leaf

        return jax.tree_util.tree_map_with_path(pad, cache)

    def pad2(path, leaf, tgt):
        key = path[-1].key if hasattr(path[-1], "key") else None
        if key in ("k", "v") and leaf is not None:
            return pad_leaf(leaf, tgt.shape[-3])
        return leaf

    return jax.tree_util.tree_map_with_path(pad2, cache, target)


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Params
    s_max: int

    def __post_init__(self):
        self._decode_jit = jax.jit(self.model.decode_step)
        self._prefill_jit = jax.jit(self.model.prefill)

    def prefill(self, batch: dict) -> tuple[jax.Array, Params, jax.Array]:
        """Returns (next_tokens [B], padded cache, lengths [B])."""
        key = "frames" if self.model.cfg.family == "audio" else "tokens"
        b, s = batch[key].shape[:2]
        logits, cache = self._prefill_jit(self.params, batch)
        target = self.model.abstract_cache(b, self.s_max)
        cache = pad_cache_to(cache, target)
        lengths = jnp.full((b,), s, jnp.int32)
        return jnp.argmax(logits, axis=-1), cache, lengths

    def decode(
        self,
        first_tokens: jax.Array,  # [B]
        cache: Params,
        lengths: jax.Array,
        n_steps: int,
        *,
        extra: dict | None = None,  # e.g. image_embeds for vlm
    ) -> jax.Array:
        """Greedy-decode ``n_steps`` tokens; returns [B, n_steps]."""
        toks = first_tokens
        out = []
        for _ in range(n_steps):
            batch = {"tokens": toks[:, None]}
            if extra:
                batch.update(extra)
            logits, cache = self._decode_jit(self.params, batch, cache, lengths)
            lengths = lengths + 1
            toks = jnp.argmax(logits, axis=-1)
            out.append(toks)
        return jnp.stack(out, axis=1)

    def generate(self, batch: dict, n_steps: int) -> jax.Array:
        """prefill + greedy decode in one call."""
        extra = (
            {"image_embeds": batch["image_embeds"]}
            if self.model.cfg.family == "vlm"
            else None
        )
        first, cache, lengths = self.prefill(batch)
        rest = self.decode(first, cache, lengths, n_steps - 1, extra=extra)
        return jnp.concatenate([first[:, None], rest], axis=1)
