"""Mesh-agnostic checkpointing: atomic, versioned, elastically re-shardable.

Format: one msgpack file per step holding {path: (dtype, shape, raw bytes)}
plus a metadata dict.  Arrays are saved in LOGICAL (unsharded) form, so a
checkpoint written on one mesh restores onto any other — elastic scaling is
``load(..., shardings=new_mesh_shardings)`` and the arrays land directly in
their new layout via ``jax.device_put``.

Fault tolerance: writes go to ``<name>.tmp`` then os.replace (atomic on
POSIX); ``latest_step`` ignores temporaries and half-written files, so a
crash mid-save can never corrupt the restore path.  ``keep_n`` old steps are
garbage-collected after each successful save.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
from typing import Any

import jax
import msgpack
import numpy as np

PyTree = Any

_SEP = "\x1f"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(
    path: str | pathlib.Path, tree: PyTree, metadata: dict | None = None
) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    payload = {
        "__meta__": metadata or {},
        "arrays": {
            k: {
                "dtype": str(v.dtype),
                "shape": list(v.shape),
                "data": v.tobytes(),
            }
            for k, v in flat.items()
        },
    }
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic commit


def load_checkpoint(
    path: str | pathlib.Path,
    template: PyTree,
    *,
    shardings: PyTree | None = None,
) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template``.

    ``shardings`` (same structure) places each array directly onto its
    (possibly different-mesh) sharding — the elastic-rescale path.
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = payload["arrays"]

    leaves_p = jax.tree_util.tree_leaves_with_path(template)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out_leaves = []
    for i, (path_t, leaf) in enumerate(leaves_p):
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path_t
        )
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = arrays[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(
            rec["shape"]
        )
        want_shape = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != template {want_shape}")
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, out_leaves), payload["__meta__"]


@dataclasses.dataclass
class CheckpointManager:
    directory: str | pathlib.Path
    keep_n: int = 3

    def __post_init__(self):
        self.directory = pathlib.Path(self.directory)
        self.directory.mkdir(parents=True, exist_ok=True)

    def _path(self, step: int) -> pathlib.Path:
        return self.directory / f"step_{step:010d}.ckpt"

    def save(self, step: int, tree: PyTree, metadata: dict | None = None) -> None:
        meta = dict(metadata or {})
        meta["step"] = step
        save_checkpoint(self._path(step), tree, meta)
        self._gc()

    def steps(self) -> list[int]:
        out = []
        for p in self.directory.glob("step_*.ckpt"):
            try:
                out.append(int(p.stem.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(
        self, template: PyTree, *, step: int | None = None, shardings=None
    ) -> tuple[PyTree, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_checkpoint(self._path(step), template, shardings=shardings)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_n]:
            self._path(s).unlink(missing_ok=True)
