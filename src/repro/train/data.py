"""Synthetic data pipeline: deterministic, checkpointable, packed.

``batch_at(step)`` is a pure function of (config, step), which makes the
pipeline trivially fault-tolerant: resuming a run is just resuming the step
counter — no iterator state to snapshot.  Documents are drawn with
log-normal lengths and packed into fixed-length rows with EOS separators
(loss-masking the separators), emulating a production packed-LM pipeline.

The pipeline also exposes ``host_bytes_per_batch`` — the per-step host-side
data volume used by the power emulator's host-throughput term (EcoShift's
CPU-cap sensitivity; DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    eos_id: int = 0
    mean_doc_len: float = 600.0
    seed: int = 0


class PackedLMDataset:
    """Deterministic packed-token batches for LM training."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        tokens = np.empty((cfg.batch, cfg.seq), np.int32)
        mask = np.ones((cfg.batch, cfg.seq), np.float32)
        for b in range(cfg.batch):
            row: list[int] = []
            boundaries: list[int] = []
            while len(row) < cfg.seq:
                doc_len = max(8, int(rng.lognormal(np.log(cfg.mean_doc_len), 0.6)))
                # Zipf-distributed tokens: a learnable unigram marginal, so
                # convergence tests (and example runs) show real loss drops
                doc = (rng.zipf(1.4, size=doc_len) - 1) % (cfg.vocab - 1) + 1
                row.extend(doc.tolist())
                row.append(cfg.eos_id)
                boundaries.append(min(len(row) - 1, cfg.seq - 1))
            tokens[b] = np.array(row[: cfg.seq], np.int32)
            mask[b, boundaries] = 0.0  # don't train across document joins
        # next-token targets
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = cfg.eos_id
        mask[:, -1] = 0.0
        return {"tokens": tokens, "targets": targets, "mask": mask}

    @property
    def host_bytes_per_batch(self) -> int:
        # raw tokens + targets + mask as produced on the host
        return self.cfg.batch * self.cfg.seq * (4 + 4 + 4)


def make_batch_fn(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Model-family-aware batch function (frames/images for audio/vlm)."""
    if cfg.family == "audio":
        def batch_at(step: int) -> dict[str, np.ndarray]:
            rng = np.random.default_rng((seed << 20) ^ step)
            return {
                "frames": rng.normal(0, 1, (batch, seq, cfg.frontend_dim)).astype(
                    np.float32
                ),
                "targets": rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32),
            }

        return batch_at

    base = PackedLMDataset(DataConfig(batch=batch, seq=seq, vocab=cfg.vocab, seed=seed))
    if cfg.family == "vlm":
        def batch_at(step: int) -> dict[str, np.ndarray]:
            out = dict(base.batch_at(step))
            rng = np.random.default_rng((seed << 21) ^ step)
            out["image_embeds"] = rng.normal(
                0, 1, (batch, cfg.n_image_tokens, cfg.d_vision)
            ).astype(np.float32)
            return out

        return batch_at
    return base.batch_at
