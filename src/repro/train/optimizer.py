"""Pure-JAX optimizers and schedules (no optax dependency).

Implements the optax-style (init, update) GradientTransformation pair for
AdamW with decoupled weight decay, global-norm clipping and warmup+cosine
schedules.  Used by both the big-model training loop and the NCF predictor.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], Any]
    update: Callable[..., tuple[PyTree, Any]]


def _tree_zeros_like(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves)
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # preserve the gradient dtype: an f32-scalar multiply would silently
    # upcast bf16 gradient trees to fp32 (2x transient memory)
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw(
    learning_rate: float | Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float | None = None,
    mask: Callable[[PyTree], PyTree] | None = None,
    factored: bool = False,
    moment_dtype=jnp.float32,
    update_chunks: int = 1,
) -> Optimizer:
    """AdamW with optional grad clipping, weight-decay mask, and memory-
    factored second moments.

    ``factored=True`` stores Adafactor-style (row, col) second-moment
    factors for >=2D leaves instead of a full nu tensor — the distributed-
    optimization memory trick that lets grok-1-314b's optimizer state fit a
    single 256-chip pod (DESIGN.md §5).  ``moment_dtype=bf16`` halves the
    first-moment footprint.  ``update_chunks > 1`` applies the update to
    big stacked (scan-unit) leaves in sequential chunks along the unit dim,
    bounding the fp32 transients of the update math to 1/chunks of the
    leaf (the reason grok's update fits next to its gradients).
    """

    def _nu_init(p):
        if factored and p.ndim >= 2:
            row = jnp.zeros(p.shape[:-1], jnp.float32)
            col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return {"row": row, "col": col}
        return jnp.zeros_like(p, dtype=jnp.float32)

    def init(params: PyTree) -> AdamState:
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=moment_dtype), params),
            nu=jax.tree.map(_nu_init, params),
        )

    def _nu_update_and_v(nu, g):
        g2 = jnp.square(g.astype(jnp.float32)) + 1e-30
        if isinstance(nu, dict):  # factored
            row = b2 * nu["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
            col = b2 * nu["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
            v = (
                row[..., :, None]
                * col[..., None, :]
                / jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)[..., None]
            )
            return {"row": row, "col": col}, v
        nu_new = b2 * nu + (1 - b2) * g2
        return nu_new, nu_new

    def _is_factored(x):
        return isinstance(x, dict) and set(x) == {"row", "col"}

    def update(grads: PyTree, state: AdamState, params: PyTree):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = learning_rate(step) if callable(learning_rate) else learning_rate
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)
        decay_mask = (
            mask(params) if mask is not None else jax.tree.map(lambda _: True, params)
        )

        def leaf_update(p, m, nu, g, dm):
            """(p_new, m_new, nu_new) for one leaf, fp32 math."""
            m_new = (
                b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)
            ).astype(moment_dtype)
            nu_new, v = _nu_update_and_v(nu, g)
            upd = (m_new.astype(jnp.float32) / b1c) / (jnp.sqrt(v / b2c) + eps)
            if weight_decay:
                upd = upd + jnp.where(dm, weight_decay, 0.0) * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return p_new, m_new, nu_new

        def maybe_chunked(p, m, nu, g, dm):
            chunkable = (
                update_chunks > 1
                and p.ndim >= 3
                and p.shape[0] % update_chunks == 0
                and p.size >= 1 << 22
            )
            if not chunkable:
                return leaf_update(p, m, nu, g, dm)

            def resh(x):
                return x.reshape((update_chunks, x.shape[0] // update_chunks) + x.shape[1:])

            xs = (resh(p), jax.tree.map(resh, m), jax.tree.map(resh, nu), resh(g))
            outs = jax.lax.map(lambda a: leaf_update(*a, dm), xs)

            def unresh(x):
                return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

            return jax.tree.map(unresh, outs)

        triples = jax.tree.map(
            maybe_chunked, params, state.mu, state.nu, grads, decay_mask,
            is_leaf=lambda x: _is_factored(x),
        )
        def unpack(i):
            return jax.tree.map(
                lambda t: t[i], triples, is_leaf=lambda x: isinstance(x, tuple)
            )
        new_params, mu, nu = unpack(0), unpack(1), unpack(2)
        return new_params, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def warmup_cosine(
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    *,
    min_ratio: float = 0.1,
) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup then cosine decay to ``min_ratio * peak_lr``."""

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
