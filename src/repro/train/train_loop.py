"""Trainer: the end-to-end training driver with checkpoint/restart.

Single-process (CPU smoke / examples) and mesh-sharded execution share this
loop; the dry-run exercises the same ``make_train_step`` the Trainer runs.
Fault tolerance: every ``ckpt_every`` steps the full state (params + opt +
step + data cursor) commits atomically; ``Trainer.resume()`` continues from
the latest checkpoint, and because the data pipeline is a pure function of
the step counter the restored run is bit-identical to an uninterrupted one.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.launch.steps import init_train_state, make_optimizer, make_train_step
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass
class Trainer:
    model: Model
    batch_fn: Callable[[int], dict]
    ckpt: CheckpointManager | None = None
    ckpt_every: int = 50
    peak_lr: float = 3e-4
    total_steps: int = 1000
    log_every: int = 10

    def __post_init__(self):
        self.optimizer = make_optimizer(
            self.model.cfg, peak_lr=self.peak_lr, total_steps=self.total_steps
        )
        step_fn, _ = make_train_step(self.model, self.optimizer)
        self._step_jit = jax.jit(step_fn, donate_argnums=(0,))
        self.state = None
        self.step = 0
        self.history: list[dict] = []

    # -- lifecycle -----------------------------------------------------------

    def init(self, seed: int = 0) -> None:
        self.state = init_train_state(
            self.model, jax.random.PRNGKey(seed), self.optimizer
        )
        self.step = 0

    def resume(self) -> bool:
        """Restore the latest checkpoint; returns True if one existed."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = jax.eval_shape(
            lambda: init_train_state(
                self.model, jax.random.PRNGKey(0), self.optimizer
            )
        )
        self.state, meta = self.ckpt.restore(template)
        self.step = int(meta["step"])
        return True

    # -- run -----------------------------------------------------------------

    def run(self, n_steps: int) -> list[dict]:
        assert self.state is not None, "call init() or resume() first"
        for _ in range(n_steps):
            batch = {k: jax.numpy.asarray(v) for k, v in self.batch_fn(self.step).items()}
            t0 = time.time()
            self.state, metrics = self._step_jit(self.state, batch)
            loss = float(metrics["loss"])
            rec = {
                "step": self.step,
                "loss": loss,
                "grad_norm": float(metrics["grad_norm"]),
                "seconds": time.time() - t0,
            }
            if not np.isfinite(loss):
                raise FloatingPointError(f"loss diverged at step {self.step}")
            self.history.append(rec)
            self.step += 1
            if self.ckpt is not None and self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, self.state)
        return self.history
