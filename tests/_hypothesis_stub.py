"""Fallback shim for images without ``hypothesis`` installed.

Test modules do::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ImportError:
        from _hypothesis_stub import hypothesis, st

With real hypothesis absent, ``@hypothesis.given(...)`` replaces the test
with a skip marker (the rest of the module keeps collecting and running),
``settings`` is a no-op decorator, and every ``st.<strategy>(...)`` call
returns a placeholder.
"""

from __future__ import annotations

import types

import pytest


def _given(*_args, **_kwargs):
    def deco(fn):
        def skipper():
            pytest.skip("hypothesis not installed")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper

    return deco


def _settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _Strategies(types.ModuleType):
    def __getattr__(self, name):
        def strategy(*_args, **_kwargs):
            return None

        return strategy


hypothesis = types.ModuleType("hypothesis")
hypothesis.given = _given
hypothesis.settings = _settings
st = _Strategies("hypothesis.strategies")
