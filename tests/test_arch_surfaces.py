"""Roofline-derived architecture surfaces (dry-run -> EcoShift bridge)."""

import pathlib

import numpy as np
import pytest

from repro.core import arch_surfaces, policies
from repro.core.arch_surfaces import RooflineSurface
from repro.core.types import SYSTEM_TPU_V5E, AppSpec

GRID = SYSTEM_TPU_V5E.grid


def train_like():
    """MXU-bound job: big flops, modest host work."""
    return RooflineSurface(
        flops_pd=5e13, bytes_pd=1e11, coll_pd=5e9, host_bytes_pd=1e6,
        host_base_s=0.010,
    )


def decode_like():
    """Host-bound job: tiny device step, big per-token host overhead."""
    return RooflineSurface(
        flops_pd=5e9, bytes_pd=5e9, coll_pd=1e8, host_bytes_pd=1e5,
        host_base_s=0.020,
    )


def collective_like():
    """ICI-bound job: no cap helps -> insensitive donor."""
    return RooflineSurface(
        flops_pd=1e12, bytes_pd=1e10, coll_pd=2e12, host_bytes_pd=1e5,
        host_base_s=0.005,
    )


class TestRooflineSurface:
    @pytest.mark.parametrize("surf", [train_like(), decode_like(), collective_like()])
    def test_monotone_in_caps(self, surf):
        caps = [(150, 100), (250, 150), (350, 200), (450, 250)]
        ts = [float(surf.runtime(c, g)) for c, g in caps]
        assert all(b <= a + 1e-12 for a, b in zip(ts, ts[1:]))

    def test_train_job_is_chip_sensitive(self):
        s = train_like()
        base = (200.0, 120.0)
        d_chip = float(s.improvement(base, 200, 250))
        d_host = float(s.improvement(base, 450, 120))
        assert d_chip > 0.2
        assert d_chip > 5 * d_host

    def test_decode_job_is_host_sensitive(self):
        s = decode_like()
        base = (170.0, 120.0)
        d_host = float(s.improvement(base, 450, 120))
        d_chip = float(s.improvement(base, 170, 250))
        assert d_host > 0.15
        assert d_host > 2 * d_chip

    def test_collective_job_is_insensitive(self):
        s = collective_like()
        base = (200.0, 120.0)
        assert float(s.improvement(base, 450, 250)) < 0.02

    def test_power_draw_below_caps(self):
        for surf in (train_like(), decode_like(), collective_like()):
            dc, dg = surf.power_draw(300.0, 200.0)
            assert dc <= 300.0 + 1e-9
            assert dg <= 200.0 + 1e-9

    def test_ecoshift_routes_power_by_job_type(self):
        """Chip watts to the training job, host watts to the decode job."""
        apps = [AppSpec("train", "G", "train"), AppSpec("decode", "C", "decode")]
        surfs = {"train": train_like(), "decode": decode_like()}
        base = {"train": (200.0, 120.0), "decode": (200.0, 120.0)}
        alloc = policies.ecoshift(apps, base, 200.0, SYSTEM_TPU_V5E, surfs)
        c_t, g_t = alloc.caps["train"]
        c_d, g_d = alloc.caps["decode"]
        assert g_t - 120.0 > c_t - 200.0  # train gets mostly chip watts
        assert c_d - 200.0 > g_d - 120.0  # decode gets mostly host watts


@pytest.mark.skipif(
    not (pathlib.Path(arch_surfaces.DRYRUN_DIR)).exists()
    or not list(pathlib.Path(arch_surfaces.DRYRUN_DIR).glob("*.json")),
    reason="dry-run artifacts not present",
)
class TestBuiltSuite:
    def test_loads_cells_with_classes(self):
        apps, surfs = arch_surfaces.build_arch_suite()
        assert len(apps) >= 20  # 32 cells on the single-pod mesh
        assert len(surfs) == len(apps)
        names = {a.name for a in apps}
        assert any("train_4k" in n for n in names)
        assert any("decode_32k" in n for n in names)
        for a in apps[:10]:
            t = float(surfs[a.name].runtime(300.0, 200.0))
            assert np.isfinite(t) and t > 0

    def test_cluster_round_on_arch_jobs(self):
        from repro.core.emulator import ClusterEmulator

        apps, surfs = arch_surfaces.build_arch_suite()
        emu = ClusterEmulator.build(SYSTEM_TPU_V5E, apps, surfs, n_nodes=24, seed=0)
        eco = emu.run_round("ecoshift", budget=1500.0)
        dps = emu.run_round("dps", budget=1500.0)
        assert eco.avg_improvement >= dps.avg_improvement - 0.005
