"""Per-architecture smoke tests (assignment: reduced config per family).

Every assigned arch: one forward/train step on CPU, asserting output shapes
and finite loss/grads.  Every decodable arch: prefill->decode consistency
against the full-sequence forward (validates KV caches, the chunked-SSD vs
recurrent Mamba2 paths, chunked vs recurrent mLSTM, MoE dispatch, sliding
windows and cross-attention in one invariant).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.config import MoEConfig
from repro.models.model import Model
from repro.serving.engine import pad_cache_to

ARCHS = configs.all_arch_ids()
B, S = 2, 64


def make_batch(cfg, key, s=S):
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(ks[0], (B, s, cfg.frontend_dim)),
            "targets": jax.random.randint(ks[1], (B, s), 0, cfg.vocab),
        }
    batch = {"tokens": jax.random.randint(ks[0], (B, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, cfg.d_vision)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(m.loss))(params, batch)
    assert np.isfinite(float(loss))
    assert 0 < float(loss) < 20.0  # ~log(vocab) at init
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.all(np.isfinite(np.asarray(g))), f"{arch}: NaN grad at {path}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = configs.smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    x, _ = jax.jit(lambda p, b: m.hidden(p, b, mode="train"))(params, batch)
    assert x.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(x, dtype=np.float32)))


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if configs.get_config(a).supports_decode()]
)
def test_prefill_decode_consistency(arch):
    """decode(prefill(s-1), token_s) == prefill(s) last logits."""
    s_total = 65
    cfg = dataclasses.replace(configs.smoke_config(arch), dtype="float32")
    if cfg.moe:
        # capacity >= group size: no token drops -> exact equality expected
        cfg = dataclasses.replace(
            cfg, moe=MoEConfig(4, 2, capacity_factor=2.0, group_size=64)
        )
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, s_total), 0, cfg.vocab)
    full = {"tokens": tokens}
    pre = {"tokens": tokens[:, : s_total - 1]}
    extra = {}
    if cfg.family == "vlm":
        img = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_vision)
        )
        full["image_embeds"] = img
        pre["image_embeds"] = img
        extra["image_embeds"] = img

    lg_full, _ = jax.jit(m.prefill)(params, full)
    _, cache = jax.jit(m.prefill)(params, pre)
    cache = pad_cache_to(cache, m.abstract_cache(B, s_total + 8))
    lengths = jnp.full((B,), s_total - 1, jnp.int32)
    lg_dec, _ = jax.jit(m.decode_step)(
        params, {"tokens": tokens[:, s_total - 1 :], **extra}, cache, lengths
    )
    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-9
    err = float(jnp.max(jnp.abs(lg_full - lg_dec))) / scale
    assert err < 5e-4, f"{arch}: prefill/decode mismatch relerr={err:.2e}"


@pytest.mark.parametrize("arch", ["gemma3-27b", "zamba2-2.7b", "xlstm-1.3b"])
def test_multi_step_greedy_decode(arch):
    """Engine generates a few greedy tokens without shape/NaN issues."""
    from repro.serving.engine import ServeEngine

    cfg = dataclasses.replace(configs.smoke_config(arch), dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model=m, params=params, s_max=96)
    batch = make_batch(cfg, jax.random.PRNGKey(1), s=64)
    out = eng.generate(batch, n_steps=4)
    assert out.shape == (B, 4)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < cfg.padded_vocab))


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    want = {
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in want.items():
        cfg = configs.get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
        assert got == (L, d, h, kv, ff, v), f"{arch}: {got}"
    # family features
    assert configs.get_config("mixtral-8x22b").moe.n_experts == 8
    assert configs.get_config("grok-1-314b").moe.top_k == 2
    assert configs.get_config("zamba2-2.7b").ssm.d_state == 64
    assert configs.get_config("gemma3-27b").local_per_global == 5
    assert configs.get_config("hubert-xlarge").encoder_only
    assert configs.get_config("llama-3.2-vision-11b").cross_attn_every == 5
    assert configs.get_config("xlstm-1.3b").xlstm is not None
    assert configs.get_config("chatglm3-6b").rotary_fraction == 0.5


def test_scan_patterns_cover_all_layers():
    for arch in ARCHS:
        cfg = configs.get_config(arch)
        unit, n_units, tail = cfg.scan_pattern()
        assert len(unit) * n_units + len(tail) == cfg.n_layers, arch
        kinds = cfg.layer_kinds()
        assert len(kinds) == cfg.n_layers


def test_shape_cell_applicability():
    """DESIGN.md §4 skip table."""
    dec = {a: configs.get_config(a).supports_decode() for a in ARCHS}
    lng = {a: configs.get_config(a).supports_long_context() for a in ARCHS}
    assert not dec["hubert-xlarge"]
    assert sum(dec.values()) == 9
    assert {a for a, v in lng.items() if v} == {
        "gemma3-27b",
        "zamba2-2.7b",
        "xlstm-1.3b",
    }
