"""BudgetProvider API certification (DESIGN.md §15).

Covers: provider semantics (constant / trace replay / composition /
step overrides), the ``as_provider`` shim and ``with_budget``
deprecation path, the ``OverrideBook`` round-aware ``DomainCapChange``
routing (including the same-round precedence + float-handling bugfix),
the shipped day-scale fixtures, and the ``ControllerConfig`` alias
contract (legacy kwargs == config, explicit kwarg beats config).
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.cluster import ClusterSim, PowerTopology, scenario as sc
from repro.cluster import budget as bm
from repro.cluster.controller import (
    ControllerConfig,
    EcoShiftController,
    EcoShiftHierController,
    EcoShiftOnlineController,
    OracleController,
    make_controller,
)
from repro.core import surfaces, types


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


# ---------------------------------------------------------------------------
# Provider semantics
# ---------------------------------------------------------------------------


class TestProviders:
    def test_constant(self):
        p = bm.ConstantProvider(150.0)
        assert p.budget_at(0) == 150.0
        assert p.budget_at(10**6) == 150.0
        assert p.forecast(3, 4) == (150.0, 150.0, 150.0, 150.0)
        assert bm.ConstantProvider(None).budget_at(0) is None

    def test_trace_scalar_and_sequence(self):
        assert bm.TraceReplayProvider(42).budget_at(7) == 42.0
        p = bm.TraceReplayProvider([10.0, 20.0, 30.0])
        assert [p.budget_at(r) for r in range(5)] == [10.0, 20.0, 30.0, 30.0, 30.0]
        # hold-last shows up in the forecast too
        assert p.forecast(1, 3) == (20.0, 30.0, 30.0)

    def test_trace_empty_and_callable(self):
        assert bm.TraceReplayProvider([]).budget_at(0) is None
        p = bm.TraceReplayProvider(lambda r: 100.0 + r)
        assert p.budget_at(5) == 105.0
        assert p.forecast(0, 3) == (100.0, 101.0, 102.0)

    def test_trace_rejects_junk(self):
        with pytest.raises(TypeError):
            bm.TraceReplayProvider(object())

    def test_trace_unwraps_nested(self):
        inner = bm.TraceReplayProvider([1.0, 2.0])
        outer = bm.TraceReplayProvider(inner)
        assert outer.trace == [1.0, 2.0]

    def test_scaled(self):
        p = bm.ScaledProvider([100.0, 200.0], 0.5)
        assert p.budget_at(0) == 50.0
        assert p.budget_at(1) == 100.0
        assert bm.ScaledProvider(None, 0.5).budget_at(0) is None

    def test_min_composition(self):
        p = bm.MinProvider([100.0, 300.0], bm.ConstantProvider(200.0))
        assert p.budget_at(0) == 100.0
        assert p.budget_at(1) == 200.0
        # unset members are ignored; all-unset rounds stay None
        q = bm.MinProvider(bm.ConstantProvider(None), 50.0)
        assert q.budget_at(0) == 50.0
        assert bm.MinProvider(None, None).budget_at(0) is None
        with pytest.raises(ValueError):
            bm.MinProvider()

    def test_composition_sugar(self):
        p = bm.ConstantProvider(100.0).scaled(0.5).min_with(40.0)
        assert p.budget_at(0) == 40.0
        q = bm.ConstantProvider(100.0).scaled(0.3)
        assert q.budget_at(0) == pytest.approx(30.0)

    def test_step_override_from_round_on(self):
        p = bm.StepOverrideProvider(100.0, [(3, 60.0)])
        assert [p.budget_at(r) for r in range(5)] == [100.0, 100.0, 100.0, 60.0, 60.0]
        # latest applicable step wins
        q = bm.StepOverrideProvider(100.0, [(2, 80.0), (4, 50.0)])
        assert q.budget_at(3) == 80.0
        assert q.budget_at(4) == 50.0

    def test_as_provider_shim(self):
        assert bm.as_provider(None) is None
        p = bm.ConstantProvider(1.0)
        assert bm.as_provider(p) is p  # idempotent passthrough
        w = bm.as_provider([1.0, 2.0])
        assert isinstance(w, bm.TraceReplayProvider)
        assert bm.as_provider(w) is w

    def test_as_watts_numpy_scalars(self):
        # the shared coercion accepts numpy scalars and agrees with float()
        v = np.float32(123.456)
        assert bm.as_watts(v) == float(v)
        assert bm.as_watts(np.float64(7.25)) == 7.25
        assert bm.as_watts(None) is None

    def test_protocol_conformance(self):
        for p in (
            bm.ConstantProvider(1.0),
            bm.TraceReplayProvider([1.0]),
            bm.ScaledProvider(1.0, 2.0),
            bm.MinProvider(1.0),
            bm.StepOverrideProvider(1.0, ()),
        ):
            assert isinstance(p, bm.BudgetProvider)


# ---------------------------------------------------------------------------
# OverrideBook: round-aware DomainCapChange routing
# ---------------------------------------------------------------------------


class TestOverrideBook:
    def test_step_applies_from_its_round(self):
        book = bm.OverrideBook()
        book.set(2, 5, 900.0)
        assert book.active(4) == {}  # future cap not visible earlier
        assert book.active(5) == {2: 900.0}
        assert book.active(9) == {2: 900.0}

    def test_latest_step_wins(self):
        book = bm.OverrideBook()
        book.set(1, 2, 800.0)
        book.set(1, 6, 500.0)
        assert book.active(3) == {1: 800.0}
        assert book.active(6) == {1: 500.0}

    def test_numpy_cap_coerces_like_budget(self):
        # a DomainCapChange carrying a numpy scalar resolves through the
        # same as_watts as a budget-trace step — bit-identical floats
        book = bm.OverrideBook()
        cap = np.float32(333.3)
        book.set(0, 0, cap)
        assert book.active(0)[0] == bm.TraceReplayProvider([cap]).budget_at(0)

    def test_provider_for(self):
        book = bm.OverrideBook()
        book.set(3, 4, 250.0)
        p = book.provider_for(3, base=1000.0)
        assert p.budget_at(3) == 1000.0
        assert p.budget_at(4) == 250.0
        assert book.provider_for(7, base=111.0).budget_at(0) == 111.0

    def test_clear_and_bool(self):
        book = bm.OverrideBook()
        assert not book
        book.set(0, 0, 1.0)
        assert book and len(book) == 1
        book.clear()
        assert not book


# ---------------------------------------------------------------------------
# Fixtures
# ---------------------------------------------------------------------------


class TestFixtures:
    def test_shipped_fixtures_load(self):
        for name in bm.FIXTURES:
            fix = bm.load_fixture(name)
            assert len(fix["values"]) == 96  # 15-minute day
            assert all(np.isfinite(v) for v in fix["values"])

    def test_resample(self):
        t24 = bm.fixture_trace("co2_day", 24)
        t96 = bm.fixture_trace("co2_day", 96)
        assert len(t24) == 24 and len(t96) == 96
        assert t24[0] == t96[0]

    def test_solar_budget_floor(self):
        p = bm.solar_budget(1000.0, floor_watts=200.0, n_rounds=96)
        vals = [p.budget_at(r) for r in range(96)]
        assert min(vals) == 200.0  # night rounds hit the grid backstop
        assert max(vals) <= 1000.0
        assert max(vals) > 500.0  # midday actually follows the sun


# ---------------------------------------------------------------------------
# Scenario integration: shim, deprecation, precedence
# ---------------------------------------------------------------------------


class TestScenarioIntegration:
    def test_raw_trace_auto_wraps(self):
        scen = sc.Scenario(n_rounds=4, budget=[100.0, 200.0])
        assert isinstance(scen.budget, bm.TraceReplayProvider)
        assert scen.budget_at(0) == 100.0
        assert scen.budget_at(3) == 200.0  # hold-last preserved

    def test_replace_keeps_provider(self):
        scen = sc.Scenario(n_rounds=4, budget=500.0)
        p = scen.budget
        scen2 = dataclasses.replace(scen, n_rounds=8)
        assert scen2.budget is p  # as_provider idempotence across replace

    def test_with_budget_deprecated_but_equivalent(self):
        base = sc.Scenario(n_rounds=6)
        with pytest.warns(DeprecationWarning, match="with_budget_provider"):
            old = base.with_budget([10.0, 20.0, 30.0])
        new = base.with_budget_provider([10.0, 20.0, 30.0])
        assert [old.budget_at(r) for r in range(6)] == [
            new.budget_at(r) for r in range(6)
        ]

    def test_with_budget_engine_parity_bit_for_bit(self, suite):
        """The deprecation shim must be a pure alias: a full engine run
        under ``with_budget(trace)`` equals ``with_budget_provider``."""
        system, apps, surfs = suite
        trace = [900.0, 600.0, 1200.0, 750.0]

        def _run(scen):
            sim = ClusterSim.build(system, apps, surfs, n_nodes=16, seed=2)
            return sim.run(scen, make_controller("ecoshift", system))

        with pytest.warns(DeprecationWarning):
            old = _run(sc.Scenario(n_rounds=4).with_budget(trace))
        new = _run(
            sc.Scenario(n_rounds=4).with_budget_provider(
                bm.TraceReplayProvider(trace)
            )
        )
        for a, b in zip(old.records, new.records):
            assert dict(a.result.allocation.caps) == dict(
                b.result.allocation.caps
            )
            assert a.result.improvements == b.result.improvements

    def test_with_budget_provider_no_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            sc.Scenario(n_rounds=2).with_budget_provider(100.0)

    def test_forecast_none_and_values(self):
        scen = sc.Scenario(n_rounds=4)
        assert scen.budget_forecast(0, 3) == (None, None, None)
        scen = scen.with_budget_provider([10.0, 20.0])
        assert scen.budget_forecast(0, 3) == (10.0, 20.0, 20.0)

    def test_carbon_aware_defaults(self):
        scen = sc.Scenario.carbon_aware(24, 3000.0)
        assert scen.carbon_at(0) is not None
        assert scen.price_at(0) is not None
        assert scen.budget_at(0) == 3000.0
        assert len(scen.carbon_forecast(0, 24)) == 24

    def test_provider_budget_runs_unchanged(self, suite):
        # a provider-built scenario is bit-for-bit a raw-trace scenario
        system, apps, surfs = suite
        trace = [3000.0 + 100.0 * (r % 3) for r in range(8)]
        res = []
        for budget in (trace, bm.TraceReplayProvider(trace)):
            sim = ClusterSim.build(system, apps[:6], surfs, n_nodes=12, seed=0)
            scen = sc.Scenario(n_rounds=8, budget=budget)
            res.append(sim.run(scen, make_controller("ecoshift", system)))
        for ra, rb in zip(res[0].records, res[1].records):
            assert ra.result.allocation.caps == rb.result.allocation.caps


class TestSameRoundPrecedence:
    """DomainCapChange vs budget-trace step on the same round.

    Contract (Scenario.budget_at docstring): round ``r``'s events apply
    before round ``r``'s budget/headroom resolution, so both take effect
    *that* round; the cap override binds from its round on and never
    earlier; both coerce through ``as_watts``.
    """

    def _run(self, suite, cap_value):
        system, apps, surfs = suite
        n = 12
        topo = PowerTopology.uniform_racks(n, 2, rack_cap=4000.0)
        k = 3
        scen = (
            sc.Scenario(
                n_rounds=6,
                budget=[3000.0] * k + [2000.0] * 3,  # budget step at round k
            )
            .with_topology(topo)
            .with_domain_cap(k, "rack0", cap_value)  # cap change, same round
        )
        sim = ClusterSim.build(system, apps[:6], surfs, n_nodes=n, seed=0)
        return sim.run(scen, make_controller("ecoshift_hier", system)), k

    def test_both_take_effect_on_shared_round(self, suite):
        res, k = self._run(suite, 2500.0)
        # before round k: neither the budget step nor the cap change
        assert res.records[k - 1].result.budget == 3000.0
        assert res.records[k - 1].domain_caps["rack0"] == 4000.0
        # at round k: both, simultaneously
        assert res.records[k].result.budget == 2000.0
        assert res.records[k].domain_caps["rack0"] == 2500.0
        # and the override persists
        assert res.records[k + 1].domain_caps["rack0"] == 2500.0

    def test_numpy_cap_value_agrees_with_float(self, suite):
        # same scenario, cap passed as np.float32: recorded cap must be
        # exactly float(np.float32(...)) — the shared as_watts coercion
        cap = np.float32(2500.7)
        res, k = self._run(suite, cap)
        assert res.records[k].domain_caps["rack0"] == float(cap)


# ---------------------------------------------------------------------------
# ControllerConfig aliases
# ---------------------------------------------------------------------------


class TestControllerConfig:
    def test_legacy_kwargs_match_config(self, suite):
        system, _, _ = suite
        a = EcoShiftController(system, solver="dense", unit=2.0, fused=True)
        b = EcoShiftController(
            system,
            config=ControllerConfig(solver="dense", unit=2.0, fused=True),
        )
        assert (a.solver, a.unit, a.fused) == (b.solver, b.unit, b.fused)
        assert a.config == b.config

    def test_explicit_kwarg_beats_config(self, suite):
        system, _, _ = suite
        cfg = ControllerConfig(horizon=8, eco_factor=0.7, solver="dense")
        c = EcoShiftController(system, config=cfg, horizon=4)
        assert c.horizon == 4  # kwarg wins
        assert c.eco_factor == 0.7 and c.solver == "dense"  # config holds

    def test_defaults_are_historical(self, suite):
        system, _, _ = suite
        c = EcoShiftController(system)
        assert (c.solver, c.unit, c.grouped, c.incremental, c.fused) == (
            "sparse", 1.0, True, True, False,
        )
        assert c.horizon == 1 and c.eco_factor == 1.0

    def test_hier_config_carries_topology(self, suite):
        system, _, _ = suite
        topo = PowerTopology.single_root(8, cap=1e6)
        c = EcoShiftHierController(
            system, config=ControllerConfig(topology=topo)
        )
        assert c.topology is topo

    def test_online_requires_predictor(self, suite):
        system, _, _ = suite
        with pytest.raises(ValueError, match="predictor"):
            EcoShiftOnlineController(system)

    def test_oracle_exhaustive_alias(self, suite):
        system, _, _ = suite
        a = OracleController(system, exhaustive=True)
        b = OracleController(system, config=ControllerConfig(exhaustive=True))
        assert a.exhaustive is True and b.exhaustive is True

    def test_make_controller_accepts_config(self, suite):
        system, _, _ = suite
        c = make_controller(
            "ecoshift", system, config=ControllerConfig(horizon=6, eco_factor=0.8)
        )
        assert c.horizon == 6 and c.eco_factor == 0.8
