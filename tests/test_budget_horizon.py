"""Receding-horizon (MPC) allocation certification (DESIGN.md §15).

The load-bearing contracts:

 * **passthrough parity** — with ``horizon=1`` or ``eco_factor>=1`` the
   planner returns None and the controller takes the literally unchanged
   myopic path, bit-for-bit, on every solver variant;
 * **compliance** — a planned round's spend never exceeds that round's
   instantaneous budget, and the plan's weighted spend never exceeds the
   eco allowance (ceil cost rounding is conservative by construction);
 * **banking** — under a dynamic CO2/price weight signal the planner
   sheds spend on dirty rounds, improving perf-per-CO2 over myopic;
 * **robustness** — structure changes (arrivals/failures) mid-horizon
   keep fused and host MPC rounds bit-for-bit equal.
"""

import numpy as np
import pytest

from repro.cluster import ClusterSim, PowerTopology, scenario as sc
from repro.cluster import budget as bm
from repro.cluster.controller import make_controller
from repro.core import mckp, surfaces, types


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


def _caps_trace(res):
    return [r.result.allocation.caps for r in res.records]


def _run(suite, scen, policy="ecoshift", n_nodes=18, n_apps=6, **kw):
    system, apps, surfs = suite
    sim = ClusterSim.build(system, apps[:n_apps], surfs, n_nodes=n_nodes, seed=0)
    ctrl = make_controller(policy, system, **kw)
    return sim.run(scen, ctrl), ctrl


# ---------------------------------------------------------------------------
# plan_horizon unit tests (synthetic frontiers)
# ---------------------------------------------------------------------------


class TestPlanHorizon:
    # a concave frontier: spends 0..10, value = sqrt(spend)
    KEYS = np.arange(11, dtype=np.float64)
    VALS = np.sqrt(np.arange(11, dtype=np.float64))

    def test_frontier_records_strictly_increasing(self):
        keys = np.array([0.0, 1.0, 2.0, 3.0, 4.0])
        vals = np.array([0.0, 2.0, 2.0, 1.5, 3.0])
        rk, rv = mckp.frontier_records(keys, vals)
        assert rk.tolist() == [0.0, 1.0, 4.0]
        assert rv.tolist() == [0.0, 2.0, 3.0]

    def test_short_circuits(self):
        assert mckp.plan_horizon(self.KEYS, self.VALS, [10.0]) is None  # H=1
        assert (
            mckp.plan_horizon(self.KEYS, self.VALS, [10.0, 10.0], eco_factor=1.0)
            is None
        )
        assert (
            mckp.plan_horizon(
                np.empty(0), np.empty(0), [10.0, 10.0], eco_factor=0.5
            )
            is None
        )

    def test_uniform_weights_shed_is_allowance_bound(self):
        # equal weights: the DP spreads the eco allowance; total weighted
        # spend must stay under eco * sum(umax)
        caps = [10.0, 10.0, 10.0]
        plan = mckp.plan_horizon(self.KEYS, self.VALS, caps, eco_factor=0.5)
        assert plan is not None
        assert sum(plan) <= 0.5 * 30.0 + 1e-9
        for s, c in zip(plan, caps):
            assert s <= c + 1e-9
            # every committed spend is an achievable frontier state
            assert any(abs(s - k) < 1e-9 for k in self.KEYS)

    def test_banks_toward_clean_rounds(self):
        # round 0 dirty (w=10), round 1 clean (w=1): the plan sheds round
        # 0 and pushes spend to round 1
        plan = mckp.plan_horizon(
            self.KEYS, self.VALS, [10.0, 10.0], [10.0, 1.0], eco_factor=0.5
        )
        assert plan is not None
        assert plan[0] < plan[1]
        # weighted allowance respected
        assert 10.0 * plan[0] + 1.0 * plan[1] <= 0.5 * 110.0 + 1e-9

    def test_caps_always_respected(self):
        caps = [10.0, 3.0, 5.0]
        plan = mckp.plan_horizon(
            self.KEYS, self.VALS, caps, [1.0, 1.0, 1.0], eco_factor=0.6
        )
        assert plan is not None
        for s, c in zip(plan, caps):
            assert s <= c + 1e-9

    def test_none_when_round0_cap_already_binds(self):
        # round 0 has the tightest cap: shedding happens on later rounds
        # and round 0 keeps its myopic optimum -> "don't restrict" (None)
        plan = mckp.plan_horizon(
            self.KEYS, self.VALS, [3.0, 7.0, 5.0], [1.0, 1.0, 1.0],
            eco_factor=0.6,
        )
        assert plan is None

    def test_none_when_plan_equals_myopic(self):
        # concave-but-cheap horizon: allowance covers the myopic optimum
        # at every round except none -> the DP picks umax everywhere and
        # the function reports "don't restrict"
        plan = mckp.plan_horizon(
            self.KEYS, self.VALS, [10.0, 10.0], eco_factor=0.999999
        )
        # eco ~= 1: the allowance floor(grid) rounding may or may not
        # shave one cell; either None or a plan that keeps round 0 at umax
        assert plan is None or plan[0] <= 10.0

    def test_levels_subsampling_keeps_endpoints(self):
        keys = np.linspace(0, 1000, 5000)
        vals = np.sqrt(keys)
        plan = mckp.plan_horizon(
            keys, vals, [1000.0, 1000.0], [5.0, 1.0], eco_factor=0.5,
            levels=16,
        )
        assert plan is not None
        assert plan[1] <= 1000.0 + 1e-9


# ---------------------------------------------------------------------------
# Engine-level passthrough parity (bit-for-bit)
# ---------------------------------------------------------------------------


class TestPassthroughParity:
    BUDGET = [3000.0, 2800.0, 3100.0, 2900.0, 3000.0, 2700.0, 3050.0, 2950.0]

    def test_h1_is_plain_controller(self, suite):
        scen = sc.Scenario(n_rounds=8, budget=self.BUDGET)
        a, _ = _run(suite, scen)
        b, _ = _run(suite, scen, horizon=1, eco_factor=0.6)
        assert _caps_trace(a) == _caps_trace(b)

    def test_eco_one_is_plain_controller(self, suite):
        scen = sc.Scenario(n_rounds=8, budget=self.BUDGET).with_carbon(
            bm.fixture_trace("co2_day", 8)
        )
        a, _ = _run(suite, scen)
        b, ctrl = _run(suite, scen, horizon=6, eco_factor=1.0)
        assert _caps_trace(a) == _caps_trace(b)
        assert ctrl.last_planned_budget is None  # planner never engaged

    def test_h1_hier_parity(self, suite):
        topo = PowerTopology.uniform_racks(18, 3, rack_cap=4000.0)
        scen = sc.Scenario(n_rounds=8, budget=self.BUDGET).with_topology(topo)
        a, _ = _run(suite, scen, policy="ecoshift_hier")
        b, _ = _run(
            suite, scen, policy="ecoshift_hier", horizon=1, eco_factor=0.6
        )
        assert _caps_trace(a) == _caps_trace(b)

    def test_constant_provider_is_static_scenario(self, suite):
        # forecast == constant: a ConstantProvider scenario is bit-for-bit
        # a scalar-budget scenario, planner configured or not
        a, _ = _run(
            suite,
            sc.Scenario(n_rounds=6, budget=3000.0),
            horizon=6,
            eco_factor=1.0,
        )
        b, _ = _run(
            suite,
            sc.Scenario(n_rounds=6, budget=bm.ConstantProvider(3000.0)),
            horizon=6,
            eco_factor=1.0,
        )
        assert _caps_trace(a) == _caps_trace(b)


# ---------------------------------------------------------------------------
# Active MPC: compliance + banking
# ---------------------------------------------------------------------------


class TestActiveMPC:
    def _co2_scenario(self, n_rounds=16):
        return sc.Scenario(
            n_rounds=n_rounds,
            budget=3000.0,
            carbon=bm.fixture_trace("co2_day", n_rounds),
        )

    def _ppc(self, res):
        val = sum(r.avg_improvement for r in res.records)
        grams = sum(
            r.carbon_intensity * r.result.allocation.spent for r in res.records
        )
        return val, grams

    def test_compliance_every_round(self, suite):
        res, ctrl = _run(suite, self._co2_scenario(), horizon=8, eco_factor=0.7)
        for rec in res.records:
            assert rec.result.allocation.spent <= rec.result.budget + 1e-6
        # the planner actually engaged at least once over the day
        planned = [
            r for r in res.records if r.result.allocation.spent < 0.95 * 3000.0
        ]
        assert planned, "eco_factor=0.7 never shed any spend"

    def test_ppc_beats_myopic(self, suite):
        scen = self._co2_scenario()
        myo, _ = _run(suite, scen)
        mpc, _ = _run(suite, scen, horizon=8, eco_factor=0.7)
        v0, g0 = self._ppc(myo)
        v1, g1 = self._ppc(mpc)
        assert g1 < g0  # strictly less carbon
        assert v1 / g1 > v0 / g0  # strictly better perf-per-CO2

    def test_price_weight_fallback(self, suite):
        # no carbon signal: the engine falls back to the price feed
        scen = sc.Scenario(
            n_rounds=12,
            budget=3000.0,
            power_price=bm.fixture_trace("price_day", 12),
        )
        res, ctrl = _run(suite, scen, horizon=6, eco_factor=0.7)
        for rec in res.records:
            assert rec.result.allocation.spent <= rec.result.budget + 1e-6

    def test_hier_mpc_compliance(self, suite):
        topo = PowerTopology.uniform_racks(18, 3, rack_cap=4000.0)
        scen = self._co2_scenario().with_topology(topo)
        res, _ = _run(
            suite, scen, policy="ecoshift_hier", horizon=8, eco_factor=0.7
        )
        for rec in res.records:
            assert rec.result.allocation.spent <= rec.result.budget + 1e-6
            # rack caps hold too (engine enforces; belt-and-braces check)
            for name, draw in rec.domain_draw.items():
                assert draw <= rec.domain_caps[name] + 1e-6


# ---------------------------------------------------------------------------
# Structure changes mid-horizon: fused vs host bit-for-bit
# ---------------------------------------------------------------------------


class TestStructureChanges:
    def test_fused_host_parity_through_events(self, suite):
        system, apps, surfs = suite
        n = 18
        topo = PowerTopology.uniform_racks(n, 3, rack_cap=4000.0)
        scen = (
            sc.Scenario(
                n_rounds=14,
                budget=3200.0,
                carbon=bm.fixture_trace("co2_day", 14),
            )
            .with_topology(topo)
            .with_failure(4, 2, 7)
            .with_arrival(8, apps[0], domain="rack1")
            .with_straggler(10, 11, 1.6)
        )
        results = []
        for fused in (False, True):
            sim = ClusterSim.build(system, apps[:6], surfs, n_nodes=n, seed=0)
            ctrl = make_controller(
                "ecoshift_hier", system, horizon=8, eco_factor=0.7, fused=fused
            )
            results.append(sim.run(scen, ctrl))
        assert _caps_trace(results[0]) == _caps_trace(results[1])

    def test_mpc_survives_flat_events(self, suite):
        system, apps, surfs = suite
        scen = (
            sc.Scenario(
                n_rounds=12,
                budget=3000.0,
                carbon=bm.fixture_trace("co2_day", 12),
            )
            .with_failure(3, 1)
            .with_straggler(6, 4, 1.5)
        )
        res, _ = _run(suite, scen, horizon=6, eco_factor=0.7)
        assert res.n_rounds == 12
        for rec in res.records:
            assert rec.result.allocation.spent <= rec.result.budget + 1e-6
