"""Cluster control-loop tests: scenario engine, controllers, batched DP.

Certifies the refactor's contracts:
 * vectorized measurement is bit-for-bit equal to the legacy per-node loop
   on identical RNG streams (and >= 5x faster at 100 nodes);
 * round 0 of every migrated policy equals the single-round emulator path;
 * multi-round regression: failure -> pool return -> warm re-optimization;
 * the vmap-batched Pallas (max,+) DP equals per-round single calls.
"""

import time

import numpy as np
import pytest

from repro.cluster import ClusterSim, Scenario
from repro.cluster.controller import make_controller
from repro.core import curves, mckp, policies, surfaces, types
from repro.core.emulator import ClusterEmulator


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


def _sim(suite, n_nodes=40, seed=0):
    system, apps, surfs = suite
    return ClusterSim.build(system, apps, surfs, n_nodes=n_nodes, seed=seed)


# ---------------------------------------------------------------------------
# Vectorized measurement == legacy loop
# ---------------------------------------------------------------------------


class TestMeasurementEquivalence:
    @pytest.mark.parametrize("policy", ["dps", "ecoshift", "mixed_adaptive"])
    def test_bitwise_equal_on_same_rng_stream(self, suite, policy):
        import dataclasses

        sim = _sim(suite)
        sim.nodes = [  # include a straggler in the measured set
            n if n.node_id != 3 else dataclasses.replace(n, slowdown=2.0)
            for n in sim.nodes
        ]
        controller = make_controller(policy, suite[0])
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        alloc = controller.allocate([n.app for n in recv], baselines, 1500.0, seen)
        vec = sim.measure_improvements(recv, alloc, sim.round_rng(policy, 0))
        loop = sim.measure_improvements_loop(recv, alloc, sim.round_rng(policy, 0))
        assert vec == loop  # bit-for-bit, not allclose

    def test_zero_noise_path(self, suite):
        system, apps, surfs = suite
        quiet = types.SystemSpec(
            name=system.name, grid=system.grid, init_cpu=system.init_cpu,
            init_gpu=system.init_gpu, noise_sigma=0.0,
        )
        sim = ClusterSim.build(quiet, apps, surfs, n_nodes=20, seed=1)
        controller = make_controller("dps", quiet)
        res1 = sim.run_round(controller, budget=800.0)
        res2 = sim.run_round(controller, budget=800.0)
        assert res1.improvements == res2.improvements

    def test_speedup_at_100_nodes(self, suite):
        sim = _sim(suite, n_nodes=100, seed=0)
        controller = make_controller("dps", suite[0])
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        alloc = controller.allocate([n.app for n in recv], baselines, 2000.0, seen)

        def best_of(fn, trials=3):
            ts = []
            for _ in range(trials):
                rng = sim.round_rng("dps", 0)
                t0 = time.perf_counter()
                fn(recv, alloc, rng)
                ts.append(time.perf_counter() - t0)
            return min(ts)

        t_loop = best_of(sim.measure_improvements_loop)
        t_vec = best_of(sim.measure_improvements)
        assert t_loop / t_vec >= 5.0, f"only {t_loop / t_vec:.1f}x"


# ---------------------------------------------------------------------------
# Round 0 of the engine == the single-round emulator
# ---------------------------------------------------------------------------


class TestRoundZeroParity:
    @pytest.mark.parametrize(
        "policy", ["uniform", "dps", "mixed_adaptive", "ecoshift", "oracle"]
    )
    def test_scenario_round0_matches_run_round(self, suite, policy):
        system, apps, surfs = suite
        emu = ClusterEmulator.build(system, apps, surfs, n_nodes=25, seed=7)
        want = emu.run_round(policy, budget=1200.0)

        sim = ClusterSim.build(system, apps, surfs, n_nodes=25, seed=7)
        trace = sim.run(Scenario.constant(1, budget=1200.0), policy)
        got = trace.records[0].result
        assert got.improvements == want.improvements
        assert dict(got.allocation.caps) == dict(want.allocation.caps)
        assert got.budget == want.budget

    @pytest.mark.parametrize("solver", ["sparse", "dense", "jax"])
    def test_warm_controller_matches_pure_policy(self, suite, solver):
        """Budget-independent cached option tables solve identically to the
        per-call tables the pure policy function builds."""
        system, apps, surfs = suite
        sim = _sim(suite, n_nodes=20, seed=4)
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        ctrl = make_controller("ecoshift", system, solver=solver)
        for budget in (400.0, 1100.0, 2500.0):  # warm after first call
            got = ctrl.allocate([n.app for n in recv], baselines, budget, seen)
            want = policies.ecoshift(
                [n.app for n in recv], baselines, budget, system, seen,
                solver=solver,
            )
            assert dict(got.caps) == dict(want.caps)
            assert got.spent == want.spent
        assert ctrl.cached_tables == len(recv)


# ---------------------------------------------------------------------------
# Multi-round scenarios
# ---------------------------------------------------------------------------


class TestScenarios:
    def test_failure_returns_pool_and_reoptimizes(self, suite):
        system, apps, surfs = suite
        sim = _sim(suite, n_nodes=20, seed=2)
        victim = sim.alive_nodes()[0].node_id
        scen = Scenario(n_rounds=3).with_failure(1, victim)  # donor-derived pool
        trace = sim.run(scen, "ecoshift")
        assert trace.n_rounds == 3
        pre, post = trace.records[0], trace.records[1]
        assert post.n_alive == pre.n_alive - 1
        # the dead node's whole cap allotment joins the pool
        assert post.result.budget > pre.result.budget
        # survivors get more watts -> re-optimized improvement not worse
        assert post.result.avg_improvement >= pre.result.avg_improvement - 0.01
        # the victim is no longer a receiver
        assert np.isnan(trace.improvements_of(f_victim_name(sim, victim))[1])

    def test_straggler_invalidates_warm_state(self, suite):
        system, _, _ = suite
        sim = _sim(suite, n_nodes=15, seed=5)
        victim = [n for n in sim.alive_nodes() if n.app.sclass in "CG"][0]
        ctrl = make_controller("ecoshift", system)
        scen = Scenario.constant(2, budget=1000.0).with_straggler(
            1, victim.node_id, 2.0
        )
        trace = sim.run(scen, ctrl)
        # slowdown scales the true surface but not relative improvements of a
        # multiplicatively-slowed app; both rounds must still measure sanely
        v = trace.improvements_of(victim.app.name)
        assert np.isfinite(v).all()
        node = [n for n in sim.nodes if n.node_id == victim.node_id][0]
        assert node.slowdown == 2.0

    def test_arrival_and_phase_change(self, suite):
        system, apps, surfs = suite
        sim = _sim(suite, n_nodes=10, seed=6)
        newcomer = apps[0]
        other = apps[1].name
        target = sim.alive_nodes()[0].node_id
        scen = (
            Scenario.constant(2, budget=900.0)
            .with_arrival(1, newcomer)
            .with_phase_change(1, target, other)
        )
        trace = sim.run(scen, "dps")
        assert trace.records[1].n_alive == 11
        changed = [n for n in sim.nodes if n.node_id == target][0]
        assert changed.base_app == other

    def test_budget_traces(self):
        scen = Scenario(n_rounds=4, budget=(100.0, 200.0))
        assert scen.budget_at(0) == 100.0
        assert scen.budget_at(3) == 200.0  # short trace holds last value
        scen = Scenario(n_rounds=4, budget=lambda r: 50.0 * (r + 1))
        assert scen.budget_at(2) == 150.0
        scen = Scenario.price_capped(
            2, pool_watts=500.0, prices=(0.1, 0.5), spend_cap=100.0
        )
        assert scen.budget_at(0) == 500.0  # cheap power: full pool
        assert scen.budget_at(1) == 200.0  # expensive power: cap / price
        assert scen.price_at(1) == 0.5

    def test_event_round_validation(self):
        with pytest.raises(ValueError):
            Scenario.constant(2).with_failure(5, 0)


def f_victim_name(sim, node_id):
    return [n for n in sim.nodes if n.node_id == node_id][0].app.name


# ---------------------------------------------------------------------------
# Batched (vmap) DP == single calls
# ---------------------------------------------------------------------------


class TestBatchedDP:
    def _rounds(self, suite):
        system, apps, surfs = suite
        base = (system.init_cpu, system.init_gpu)
        budgets = [300.0, 900.0, 1600.0]
        rounds = []
        for i, b in enumerate(budgets):
            names = sorted(a.name for a in apps[: 3 + i])
            rounds.append(
                [
                    curves.build_options(n, surfs[n], base, system.grid, b)
                    for n in names
                ]
            )
        return rounds, budgets

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    def test_solve_batch_matches_singles(self, suite, backend):
        rounds, budgets = self._rounds(suite)
        batch = mckp.solve_dense_jax_batch(rounds, budgets, backend=backend)
        for opts, budget, got in zip(rounds, budgets, batch):
            want = mckp.solve_dense_jax(opts, budget, backend=backend)
            assert got.picks == want.picks
            assert got.total_value == want.total_value
            assert got.spent == want.spent

    def test_batched_kernel_matches_single(self):
        import jax.numpy as jnp

        from repro.kernels import ops

        rng = np.random.default_rng(0)
        dp = jnp.asarray(rng.uniform(0, 1, (4, 96)), jnp.float32)
        f = jnp.asarray(rng.uniform(0, 1, (4, 96)), jnp.float32)
        out_b, arg_b = ops.maxplus_conv_batched(dp, f)
        for r in range(4):
            out_s, arg_s = ops.maxplus_conv(dp[r], f[r])
            np.testing.assert_array_equal(np.asarray(out_b[r]), np.asarray(out_s))
            np.testing.assert_array_equal(np.asarray(arg_b[r]), np.asarray(arg_s))

    def test_controller_allocate_batch(self, suite):
        system, _, _ = suite
        sim = _sim(suite, n_nodes=12, seed=8)
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        ctrl = make_controller("ecoshift", system, solver="jax")
        budgets = (500.0, 1500.0)
        batch = ctrl.allocate_batch([n.app for n in recv], baselines, budgets, seen)
        for budget, got in zip(budgets, batch):
            want = ctrl.allocate([n.app for n in recv], baselines, budget, seen)
            assert dict(got.caps) == dict(want.caps)


# ---------------------------------------------------------------------------
# Acceptance scenario: >=5 rounds, >=50 nodes, one failure + one straggler
# ---------------------------------------------------------------------------


class TestAcceptanceScenario:
    @pytest.mark.parametrize("policy", ["ecoshift", "dps"])
    def test_seeded_multi_round(self, suite, policy):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=50, seed=0)
        victim_f = sim.alive_nodes()[0].node_id
        victim_s = [n for n in sim.alive_nodes() if n.app.sclass in "CG"][0]
        scen = (
            Scenario.constant(5, budget=2000.0)
            .with_failure(2, victim_f)
            .with_straggler(3, victim_s.node_id, 1.8)
        )
        trace = sim.run(scen, policy)
        assert trace.n_rounds == 5
        assert trace.records[2].n_alive == 49
        assert np.isfinite(trace.improvement_trace).all()
        assert (trace.improvement_trace > 0).all()
        # replay with a fresh sim: fully deterministic
        sim2 = ClusterSim.build(system, apps, surfs, n_nodes=50, seed=0)
        trace2 = sim2.run(scen, policy)
        for a, b in zip(trace.records, trace2.records):
            assert a.result.improvements == b.result.improvements
