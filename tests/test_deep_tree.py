"""N-level topology parity + conservation suite (DESIGN.md §16).

The load-bearing contracts of the arbitrary-depth solver added in ISSUE 8:

 * **collapse parity**: a random-depth tree whose intermediate domains are
   unconstrained is *bit-for-bit* the two-level collapse (root → leaf
   domains in DFS order) — picks, total_value, spent and every leaf's
   domain_spent;
 * **splice parity**: splicing an unconstrained single-child intermediate
   out of the tree never changes the solution at the bit level;
 * **conservation**: every internal domain's reported spend equals the sum
   of its children's, at every ancestor level, under randomized instances
   and randomized engine event storms (failures, stragglers, deratings);
 * **fused parity**: the device-resident fused deep solve is bit-for-bit
   the host sparse solve, including domain_spent at every level, and its
   fallbacks surface a machine-readable ``fallback_reason``.
"""

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # image without hypothesis: property tests skip
    from _hypothesis_stub import hypothesis, st

from repro.cluster import ClusterSim, PowerTopology, Scenario
from repro.cluster.controller import make_controller
from repro.core import mckp, surfaces, types
from test_hier_alloc import _assert_bitwise_equal, _random_groups


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


def _random_deep_tree(rng, budget, *, unconstrained_internal=True):
    """Random ragged tree, depth 2–4, returning (root, leaves_in_dfs_order).

    Leaf caps are binding multiples of 25 W; internal caps are 1e18 when
    ``unconstrained_internal`` (the collapse-parity regime) or random
    binding multiples of 25 W otherwise."""
    leaves = []

    def build(d, path):
        if d == 0 or (d < 3 and rng.random() < 0.3):
            g = _random_groups(
                rng, budget, n_groups=int(rng.integers(1, 3)),
                prefix=f"L{path}_",
            )
            dom = mckp.DomainGroups(
                name=f"leaf{path}",
                cap=float(rng.integers(2, 20)) * 25.0,
                groups=tuple(g),
            )
            leaves.append(dom)
            return dom
        cap = (
            1e18 if unconstrained_internal
            else float(rng.integers(4, 40)) * 25.0
        )
        kids = tuple(
            build(d - 1, f"{path}{i}")
            for i in range(int(rng.integers(1, 4)))
        )
        return mckp.DomainGroups(name=f"d{path}", cap=cap, children=kids)

    depth = int(rng.integers(2, 5))
    root_kids = tuple(
        build(depth - 1, str(i)) for i in range(int(rng.integers(2, 4)))
    )
    root = mckp.DomainGroups(name="site", cap=budget, children=root_kids)
    return root, leaves


def _internal_domains(dom):
    if dom.children:
        yield dom
        for c in dom.children:
            yield from _internal_domains(c)


# ---------------------------------------------------------------------------
# Collapse / splice parity: deep tree == two-level, bit-for-bit
# ---------------------------------------------------------------------------


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=25, deadline=None)
def test_collapse_parity_property(seed):
    """Unconstrained-intermediate deep trees solve bit-for-bit like their
    two-level collapse (root → leaves in DFS order)."""
    rng = np.random.default_rng(seed)
    budget = float(rng.integers(6, 30)) * 25.0
    deep, leaves = _random_deep_tree(rng, budget)
    flat = mckp.DomainGroups(
        name="site", cap=budget, children=tuple(leaves)
    )
    a = mckp.solve_hierarchical(deep, budget)
    b = mckp.solve_hierarchical(flat, budget)
    _assert_bitwise_equal(a, b)
    for leaf in leaves:
        assert a.domain_spent[leaf.name] == b.domain_spent[leaf.name]


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=25, deadline=None)
def test_unconstrained_splice_parity_property(seed):
    """Wrapping every root child in an unconstrained single-child
    intermediate level — the inverse of splicing that level out — never
    changes the solution at the bit level."""
    rng = np.random.default_rng(seed)
    budget = float(rng.integers(6, 30)) * 25.0
    base, _ = _random_deep_tree(rng, budget, unconstrained_internal=False)
    wrapped = mckp.DomainGroups(
        name="site",
        cap=budget,
        children=tuple(
            mckp.DomainGroups(name=f"wrap{i}", cap=1e18, children=(c,))
            for i, c in enumerate(base.children)
        ),
    )
    a = mckp.solve_hierarchical(base, budget)
    b = mckp.solve_hierarchical(wrapped, budget)
    _assert_bitwise_equal(a, b)
    for dom in _internal_domains(base):
        assert a.domain_spent[dom.name] == b.domain_spent[dom.name]


# ---------------------------------------------------------------------------
# Conservation at every ancestor level
# ---------------------------------------------------------------------------


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=25, deadline=None)
def test_ancestor_conservation_property(seed):
    """Every internal domain's reported spend is the sum of its children's
    and never exceeds its cap — at every level of a random binding tree."""
    rng = np.random.default_rng(seed)
    budget = float(rng.integers(6, 30)) * 25.0
    root, _ = _random_deep_tree(rng, budget, unconstrained_internal=False)
    sol = mckp.solve_hierarchical(root, budget)
    assert sol.spent <= budget + 1e-9
    for dom in _internal_domains(root):
        kids = sum(sol.domain_spent[c.name] for c in dom.children)
        np.testing.assert_allclose(
            sol.domain_spent[dom.name], kids, atol=1e-6
        )
        assert sol.domain_spent[dom.name] <= dom.cap + 1e-6


# ---------------------------------------------------------------------------
# Fused deep solve: bit-for-bit the host path, reasons on fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_fused_deep_parity(seed):
    rng = np.random.default_rng(3000 + seed)
    budget = float(rng.integers(6, 30)) * 25.0
    root, _ = _random_deep_tree(
        rng, budget,
        unconstrained_internal=bool(rng.integers(0, 2)),
    )
    host = mckp.solve_hierarchical(root, budget)
    fstate = mckp.FusedState()
    fused = mckp.solve_hierarchical_fused(
        root, budget, state=mckp.HierState(), fstate=fstate
    )
    assert fused is not None, fstate.stats["fallback_reason"]
    assert fstate.stats["fallback_reason"] == ""
    _assert_bitwise_equal(host, fused)
    assert host.domain_spent.keys() == fused.domain_spent.keys()
    for name, spent in host.domain_spent.items():
        assert fused.domain_spent[name] == spent, name


def test_fused_warm_resolve_stays_bitwise():
    """Re-solving the same deep tree against resident banks (warm path:
    no uploads, device round) stays bit-for-bit, and a budget change
    rides the same banks."""
    rng = np.random.default_rng(99)
    budget = 600.0
    root, _ = _random_deep_tree(rng, budget, unconstrained_internal=False)
    state, fstate = mckp.HierState(), mckp.FusedState()
    for b in (budget, budget, budget - 100.0):
        host = mckp.solve_hierarchical(root, b)
        fused = mckp.solve_hierarchical_fused(
            root, b, state=state, fstate=fstate
        )
        assert fused is not None, fstate.stats["fallback_reason"]
        _assert_bitwise_equal(host, fused)
    assert fstate.stats["fallbacks"] == 0


def test_fused_fallback_reasons():
    """Fallbacks carry a machine-readable reason in the stats."""
    from repro.core import curves

    def one_leaf_root(costs, cap, budget):
        t = curves.OptionTable(
            name="odd",
            costs=np.asarray(costs, dtype=float),
            values=np.linspace(0.0, 0.5, len(costs)),
            caps=np.stack(
                [100.0 + np.asarray(costs, dtype=float),
                 np.full(len(costs), 100.0)], axis=-1,
            ),
        )
        g = mckp.GroupedOptions(table=t, members=("n0",))
        return mckp.DomainGroups(
            name="site",
            cap=budget,
            children=(mckp.DomainGroups(name="r0", cap=cap, groups=(g,)),),
        )

    # grid overflow: lattice pitch 25 W but a 150 kW spend key
    fstate = mckp.FusedState()
    out = mckp.solve_hierarchical_fused(
        one_leaf_root([0.0, 25.0, 150000.0], 1e18, 200000.0),
        200000.0, state=mckp.HierState(), fstate=fstate,
    )
    assert out is None
    assert fstate.stats["fallback_reason"] == "grid_overflow"

    # structure change against resident banks is NOT a fallback
    # (DESIGN.md §17): the differently-shaped tree compacts or patches
    # the banks and solves fused in the same call, bit-for-bit with the
    # host solver
    rng = np.random.default_rng(7)
    tree_a, _ = _random_deep_tree(rng, 500.0)
    tree_b, _ = _random_deep_tree(rng, 500.0)
    state, fstate = mckp.HierState(), mckp.FusedState()
    assert (
        mckp.solve_hierarchical_fused(
            tree_a, 500.0, state=state, fstate=fstate
        )
        is not None
    )
    out = mckp.solve_hierarchical_fused(
        tree_b, 500.0, state=mckp.HierState(), fstate=fstate
    )
    assert out is not None
    assert fstate.stats["fallbacks"] == 0
    assert fstate.stats["rebuilds"] == 1  # cold start only
    host = mckp.solve_hierarchical(tree_b, 500.0)
    assert out.picks == host.picks
    assert out.total_value == host.total_value
    assert out.spent == host.spent
    assert out.domain_spent == host.domain_spent


# ---------------------------------------------------------------------------
# Engine level: deep topologies under randomized event storms
# ---------------------------------------------------------------------------


def _deep_engine_topology(system, apps, surfs, n, fanouts, rng, sim_seed):
    """uniform_tree with binding caps at every level: committed draw plus
    a little randomized headroom per domain (tightening toward leaves)."""
    from repro.core.topology import PowerDomain

    probe = ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=sim_seed,
        initial_caps=(150.0, 150.0),
        topology=PowerTopology.uniform_tree(
            n, fanouts, [1e15] * (len(fanouts) + 1)
        ),
    )
    _, committed, _ = probe.domain_headroom(0)
    topo0 = probe.topology

    def recap(dom, depth):
        i = topo0.index[dom.name]
        if depth == 0:
            cap = 1e18
        else:
            cap = float(committed[i]) + float(rng.integers(2, 8)) * 50.0 / depth
        return PowerDomain(
            name=dom.name, cap=cap, nodes=dom.nodes,
            children=tuple(recap(c, depth + 1) for c in dom.children),
        )

    return PowerTopology(recap(topo0.domains[0], 0), n_nodes=n)


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("fused", [False, True])
def test_deep_event_storm_conserves_every_level(suite, seed, fused):
    """A 4-level topology rides a randomized storm (failures, stragglers,
    a mid-run PDU derating): every round, every domain stays at or under
    its cap and every ancestor's draw is exactly its children's sum."""
    system, apps, surfs = suite
    rng = np.random.default_rng(500 + seed)
    n = 48
    fanouts = (2, 2, 2)
    topo = _deep_engine_topology(
        system, apps, surfs, n, fanouts, rng, seed
    )
    sim_seed = seed
    derate_dom = f"pdu{int(rng.integers(0, 4))}"
    derate_i = topo.index[derate_dom]
    derated = float(topo.domains[derate_i].cap) - 25.0
    scen = (
        Scenario.constant(5, budget=float(rng.integers(4, 20)) * 100.0)
        .with_topology(topo)
        .with_failure(1, *rng.choice(n, size=3, replace=False).tolist())
        .with_straggler(2, int(rng.integers(0, n)), 1.6)
        .with_domain_cap(3, derate_dom, derated)
    )
    sim = ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=sim_seed,
        initial_caps=(150.0, 150.0), topology=topo,
    )
    trace = sim.run(
        scen, make_controller("ecoshift_hier", system, fused=fused)
    )
    for rec in trace.records:
        assert rec.domain_draw is not None
        for name, draw in rec.domain_draw.items():
            assert draw <= rec.domain_caps[name] + 1e-6, (
                rec.round, name, draw, rec.domain_caps[name]
            )
        # conservation at every ancestor level
        for dom in topo.domains:
            if dom.is_leaf:
                continue
            kids = sum(rec.domain_draw[c.name] for c in dom.children)
            np.testing.assert_allclose(
                rec.domain_draw[dom.name], kids, atol=1e-6,
                err_msg=f"round {rec.round}, domain {dom.name}",
            )
    # the derate had teeth and held
    after = trace.records[3]
    assert after.domain_caps[derate_dom] == derated
    assert after.domain_draw[derate_dom] <= derated + 1e-6


def test_fallback_reason_surfaces_through_engine(suite):
    """controller.last_fallback_reason and the round profile expose why a
    fused round fell back (empty on fused success and on host paths)."""
    system, apps, surfs = suite
    n = 24
    topo = PowerTopology.uniform_tree(
        n, (2, 2), [1e18, 9000.0, 4000.0]
    )
    sim = ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=1,
        initial_caps=(150.0, 150.0), topology=topo,
    )
    ctrl = make_controller("ecoshift_hier", system, fused=True)
    sim.run_round(ctrl, budget=900.0)
    assert ctrl.last_fallback_reason == ""
    assert sim.last_round_profile["alloc_fallback_reason"] == ""
    stats = ctrl.fused_stats()
    assert stats.fallback_reason == ""
    assert stats.rounds >= 1

    # host controller: the key exists and is empty
    sim2 = ClusterSim.build(
        system, apps, surfs, n_nodes=n, seed=1,
        initial_caps=(150.0, 150.0), topology=topo,
    )
    sim2.run_round(make_controller("ecoshift_hier", system), budget=900.0)
    assert sim2.last_round_profile["alloc_fallback_reason"] == ""
