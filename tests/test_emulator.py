"""Cluster-emulator tests: partition, rounds, failures, stragglers."""

import numpy as np
import pytest

from repro.core import emulator, surfaces, types


@pytest.fixture(scope="module")
def cluster():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return emulator.ClusterEmulator.build(
        system, apps, surfs, n_nodes=40, seed=0
    ), system


class TestPartition:
    def test_donors_are_insensitive_class(self, cluster):
        emu, _ = cluster
        donors, receivers, pool = emu.partition()
        assert pool > 0
        assert len(donors) + len(receivers) == 40
        for d in donors:
            assert d.app.sclass == types.CLASS_NONE

    def test_pool_matches_headroom(self, cluster):
        emu, system = cluster
        donors, _, pool = emu.partition()
        expect = 0.0
        for d in donors:
            nc, ng = emu.surfaces[d.base_app].power_draw(1e9, 1e9)
            expect += (d.caps[0] - float(nc)) + (d.caps[1] - float(ng))
        np.testing.assert_allclose(pool, expect)


class TestRounds:
    def test_explicit_budget_round(self, cluster):
        emu, _ = cluster
        res = emu.run_round("ecoshift", budget=1000.0)
        assert res.budget == 1000.0
        assert res.avg_improvement > 0
        assert res.allocation.spent <= 1000.0 + 1e-6
        assert 0 <= res.jain_index <= 1

    def test_uniform_is_zero(self, cluster):
        emu, _ = cluster
        res = emu.run_round("uniform", budget=1000.0)
        # pure measurement noise around zero
        assert abs(res.avg_improvement) < 0.01

    def test_ecoshift_beats_heuristics_with_true_surfaces(self, cluster):
        emu, _ = cluster
        b = 2000.0
        eco = emu.run_round("ecoshift", budget=b)
        dps = emu.run_round("dps", budget=b)
        mad = emu.run_round("mixed_adaptive", budget=b)
        assert eco.avg_improvement >= dps.avg_improvement - 0.005
        assert eco.avg_improvement >= mad.avg_improvement - 0.005

    def test_reproducible(self, cluster):
        emu, _ = cluster
        r1 = emu.run_round("dps", budget=500.0)
        r2 = emu.run_round("dps", budget=500.0)
        assert r1.improvements == r2.improvements


class TestFaultTolerance:
    def test_failed_node_returns_power_to_pool(self):
        system = types.SYSTEM_1
        apps, surfs = surfaces.build_paper_suite(system)
        emu = emulator.ClusterEmulator.build(system, apps, surfs, n_nodes=20, seed=1)
        _, _, pool0 = emu.partition()
        victim = emu.alive_nodes()[0]
        emu.fail_nodes([victim.node_id])
        _, recv, pool1 = emu.partition()
        assert all(n.node_id != victim.node_id for n in recv)
        # pool grows by at least the victim's cap allotment minus its old slack
        assert pool1 >= pool0
        assert pool1 >= victim.caps[0] + victim.caps[1]

    def test_reoptimization_after_failure_improves(self):
        system = types.SYSTEM_1
        apps, surfs = surfaces.build_paper_suite(system)
        emu = emulator.ClusterEmulator.build(system, apps, surfs, n_nodes=20, seed=2)
        base = emu.run_round("ecoshift")  # donor-derived pool
        receivers = [n for n in emu.alive_nodes()]
        emu.fail_nodes([receivers[0].node_id])
        re_opt = emu.run_round("ecoshift")
        # more watts per surviving receiver -> avg improvement not worse
        assert re_opt.budget > base.budget
        assert re_opt.avg_improvement >= base.avg_improvement - 0.01

    def test_straggler_surface_slowdown(self):
        system = types.SYSTEM_1
        apps, surfs = surfaces.build_paper_suite(system)
        emu = emulator.ClusterEmulator.build(system, apps, surfs, n_nodes=10, seed=3)
        node = emu.alive_nodes()[0]
        t0 = float(emu._surface(node).runtime(200.0, 200.0))
        emu.add_straggler(node.node_id, slowdown=2.0)
        node2 = [n for n in emu.alive_nodes() if n.node_id == node.node_id][0]
        t1 = float(emu._surface(node2).runtime(200.0, 200.0))
        np.testing.assert_allclose(t1, 2.0 * t0)
