"""Fault-injection + self-healing tests (DESIGN.md §18).

Certifies the robustness contracts:
 * fault events validate at build time (``Scenario.with_faults``) and
   storms are bit-reproducible from their seed;
 * actuation faults have exact semantics — NACK keeps the previously
   applied caps, partial application interpolates from them, delayed
   commands land next round displacing that round's own command;
 * the PowerGuard watchdog keeps the *settled* draw under every domain
   cap and the round budget in the same round the excursion appears
   (a stuck actuator causes at most a sub-round excursion);
 * NACKed receivers are pinned at their last-confirmed caps with
   exponential backoff, and the freed headroom is redistributed;
 * ``Controller.snapshot()/restore()`` (and the msgpack file round-trip)
   is bit-for-bit: a crash-restored controller replays the uninterrupted
   run exactly; a cold crash (no restore) reconverges in K = 0 rounds on
   a clean channel because warm caches are pure accelerators;
 * bounded warm caches (``ControllerConfig.max_*``) never change results;
 * the ``fallback_reason`` enum is drift-guarded across code and docs.
"""

import re
from pathlib import Path

import numpy as np
import pytest

from repro.cluster import ClusterSim, PowerTopology, Scenario
from repro.cluster.controller import (
    ControllerConfig,
    load_snapshot,
    make_controller,
    save_snapshot,
)
from repro.cluster.faults import (
    ActuationDelay,
    ActuationNack,
    ActuationPartial,
    ActuationReport,
    ControllerCrash,
    FaultInjector,
    TelemetryCorrupt,
    TelemetryDelay,
    TelemetryDrop,
    TelemetryStale,
    corrupt_batch,
    fault_storm,
    validate_faults,
)
from repro.cluster.predictor import TelemetryBatch
from repro.core import surfaces, types

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


def _sim(suite, n_nodes=24, seed=3):
    system, apps, surfs = suite
    return ClusterSim.build(system, apps, surfs, n_nodes=n_nodes, seed=seed)


def _applied_caps(record):
    """name -> settled (cpu, gpu) caps the measurement actually saw."""
    return {
        t.instance: tuple(np.asarray(t.allocated_caps).tolist())
        for t in record.telemetry
    }


def _caps_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        assert tuple(a[k]) == tuple(b[k]), k


# ---------------------------------------------------------------------------
# Build-time validation + storm determinism
# ---------------------------------------------------------------------------


class TestValidation:
    def test_unknown_event_type_fails_fast(self):
        with pytest.raises(TypeError, match="object"):
            validate_faults([object()], 4)

    def test_scenario_event_is_not_a_fault(self, suite):
        # a scenario Event on the fault channel names the offender too
        from repro.cluster.scenario import NodeFailure

        with pytest.raises(TypeError, match="NodeFailure"):
            Scenario.constant(4).with_faults([NodeFailure(round=1, node_ids=(0,))])

    def test_round_range(self):
        with pytest.raises(ValueError, match="outside"):
            validate_faults([TelemetryDrop(round=9)], 4)

    def test_bad_corrupt_mode_and_fraction(self):
        with pytest.raises(ValueError, match="mode"):
            validate_faults([TelemetryCorrupt(round=0, mode="zap")], 4)
        with pytest.raises(ValueError, match="fraction"):
            validate_faults([TelemetryCorrupt(round=0, fraction=0.0)], 4)

    def test_actuation_must_target_something(self):
        with pytest.raises(ValueError, match="targets"):
            validate_faults([ActuationNack(round=0)], 4)

    def test_with_faults_composes(self):
        a = TelemetryDrop(round=1)
        b = ActuationNack(round=2, fraction=0.5)
        sc = Scenario.constant(4).with_faults([a]).with_faults([b])
        assert sc.faults == (a, b)

    def test_storm_is_seed_deterministic(self):
        kw = dict(
            telemetry_drop=0.2, telemetry_corrupt=0.4, telemetry_stale=0.2,
            actuation_nack=0.4, actuation_partial=0.3, actuation_delay=0.3,
            crash_rounds=(5,),
        )
        assert fault_storm(20, 7, **kw) == fault_storm(20, 7, **kw)
        assert fault_storm(20, 7, **kw) != fault_storm(20, 8, **kw)

    def test_storm_events_validate(self):
        sc = Scenario.constant(16).with_fault_storm(
            seed=0, telemetry_corrupt=0.5, actuation_nack=0.5,
            crash_rounds=(8,),
        )
        assert any(isinstance(e, ControllerCrash) for e in sc.faults)


# ---------------------------------------------------------------------------
# Telemetry channel: corruption + delivery routing
# ---------------------------------------------------------------------------


def _tiny_batch(round=0, n=8, seed=0):
    rng = np.random.default_rng(seed)
    strings = tuple(f"i{j}" for j in range(n)) + ("app",)
    t0 = rng.uniform(50.0, 80.0, n)
    t1 = t0 * rng.uniform(0.6, 0.9, n)
    return TelemetryBatch(
        round=round,
        inst_gids=np.arange(n),
        app_gids=np.full(n, n),
        strings=strings,
        baseline_caps=np.full((n, 2), 100.0),
        allocated_caps=np.full((n, 2), 120.0),
        t_baseline=t0,
        t_allocated=t1,
        improvement=(t0 - t1) / t0,
    )


class TestTelemetryFaults:
    @pytest.mark.parametrize("mode", ["nan", "inf", "outlier", "negative"])
    def test_corrupt_modes(self, mode):
        batch = _tiny_batch()
        orig_t0 = batch.t_baseline.copy()
        orig_t1 = batch.t_allocated.copy()
        out = corrupt_batch(
            batch, TelemetryCorrupt(round=0, fraction=0.5, mode=mode, seed=1)
        )
        # copy-on-write: the true measurement arrays are never mutated
        assert np.array_equal(batch.t_baseline, orig_t0)
        assert np.array_equal(batch.t_allocated, orig_t1)
        bad = ~(
            np.isfinite(out.t_baseline)
            & np.isfinite(out.t_allocated)
            & (out.t_allocated > 0)
            & (out.t_allocated < out.t_baseline * 1e2)
        )
        assert bad.sum() == 4  # fraction=0.5 of 8
        # corruption is internally consistent: improvement recomputed
        ok = ~bad
        assert np.array_equal(
            out.improvement[ok],
            (out.t_baseline[ok] - out.t_allocated[ok]) / out.t_baseline[ok],
        )

    def test_drop_and_delay_routing(self):
        inj = FaultInjector(
            [TelemetryDrop(round=0), TelemetryDelay(round=1, rounds=1)]
        )
        b0, b1, b2 = (_tiny_batch(round=r) for r in range(3))
        out, kinds = inj.deliver(0, b0)
        assert out == [] and kinds == ("drop",)
        out, kinds = inj.deliver(1, b1)
        assert out == [] and kinds == ("delay",)
        out, kinds = inj.deliver(2, b2)
        assert out == [b1, b2] and kinds == ("delayed_delivery",)

    def test_stale_repeat_displaces_current(self):
        inj = FaultInjector([TelemetryStale(round=2, age=1)])
        b0, b1, b2 = (_tiny_batch(round=r) for r in range(3))
        assert inj.deliver(0, b0) == ([b0], ())
        assert inj.deliver(1, b1) == ([b1], ())
        out, kinds = inj.deliver(2, b2)
        assert out == [b1] and kinds == ("stale",)


# ---------------------------------------------------------------------------
# Actuation channel semantics (pure controller: no pinning feedback)
# ---------------------------------------------------------------------------


class TestActuationSemantics:
    def test_nack_keeps_previously_applied_caps(self, suite):
        sim = _sim(suite)
        sc = Scenario(2, budget=[700.0, 1500.0]).with_faults(
            [ActuationNack(round=1, fraction=1.0, seed=1)]
        )
        res = sim.run(sc, make_controller("dps", suite[0]))
        a0, a1 = (_applied_caps(r) for r in res.records)
        _caps_equal(a1, a0)  # every receiver kept round 0's applied caps
        assert set(res.records[1].nacked)  # and the deviation was reported
        # the command itself did move (budget doubled)
        cmd1 = res.records[1].result.allocation.caps
        assert any(tuple(cmd1[k]) != a1[k] for k in a1)

    def test_partial_interpolates_from_applied(self, suite):
        sim = _sim(suite)
        frac = 0.25
        sc = Scenario(2, budget=[700.0, 1500.0]).with_faults(
            [ActuationPartial(round=1, fraction=1.0, applied_fraction=frac)]
        )
        res = sim.run(sc, make_controller("dps", suite[0]))
        a0, a1 = (_applied_caps(r) for r in res.records)
        cmd1 = res.records[1].result.allocation.caps
        for k, prev in a0.items():
            want = tuple(
                p + frac * (c - p) for p, c in zip(prev, cmd1[k])
            )
            assert a1[k] == pytest.approx(want, abs=1e-9)

    def test_delay_lands_next_round_displacing_its_command(self, suite):
        sim = _sim(suite)
        sc = Scenario(3, budget=[700.0, 1000.0, 1500.0]).with_faults(
            [ActuationDelay(round=1, fraction=1.0)]
        )
        res = sim.run(sc, make_controller("dps", suite[0]))
        a0, a1, a2 = (_applied_caps(r) for r in res.records)
        cmd1 = res.records[1].result.allocation.caps
        _caps_equal(a1, a0)  # nothing landed in the delayed round
        # the delayed round-1 command displaced round 2's own command
        for k in a2:
            assert a2[k] == pytest.approx(tuple(cmd1[k]), abs=1e-9)


# ---------------------------------------------------------------------------
# PowerGuard watchdog
# ---------------------------------------------------------------------------


BUDGETS = [
    1400.0, 700.0, 1200.0, 500.0, 1400.0, 900.0,
    1300.0, 550.0, 1400.0, 650.0, 1200.0, 1400.0,
]


class TestPowerGuard:
    def test_budget_never_exceeded_by_settled_draw(self, suite):
        sim = _sim(suite)
        sc = Scenario(12, budget=BUDGETS).with_fault_storm(
            seed=5, telemetry_corrupt=0.4, actuation_nack=0.5,
            actuation_partial=0.3, node_fraction=0.5,
        )
        res = sim.run(sc, make_controller("dps", suite[0]))
        saw_excursion = False
        for rec in res.records:
            extra = sum(
                float(np.sum(t.allocated_caps) - np.sum(t.baseline_caps))
                for t in rec.telemetry
            )
            budget = rec.result.budget
            assert extra <= budget + 1e-6, (rec.round, extra, budget)
            if rec.overdraw_w > 0:
                saw_excursion = True
                assert rec.derate_w > 0  # clawed back in the same round
        assert saw_excursion  # a shrinking budget under NACKs must trip it

    def test_domain_caps_hold_under_storm(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
        committed = float(sim.table.caps.sum())
        topo = PowerTopology.uniform_racks(
            24, 3, rack_cap=committed / 3 + 450.0
        )
        sc = (
            Scenario(12, budget=BUDGETS)
            .with_topology(topo)
            .with_fault_storm(
                seed=9, telemetry_corrupt=0.3, actuation_nack=0.5,
                actuation_partial=0.3, actuation_delay=0.3,
                telemetry_drop=0.1, telemetry_stale=0.2, node_fraction=0.4,
            )
        )
        res = sim.run(sc, make_controller("ecoshift_hier", system))
        assert any(rec.nacked for rec in res.records)
        for rec in res.records:
            for d, w in rec.domain_draw.items():
                assert w <= rec.domain_caps[d] + 1e-6, (rec.round, d)

    def test_forced_domain_excursion_settles_same_round(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
        topo = PowerTopology.uniform_racks(24, 3, rack_cap=1e6)
        # per-rack committed baseline draw (uniform node baselines)
        committed0 = float(sim.table.caps.sum()) / 3
        # round 2: rack0's cap collapses to committed + 50 W of headroom
        # while every node NACKs and keeps its round-1 caps -> the stuck
        # draw exceeds the new cap and PowerGuard must claw it back now
        sc = (
            Scenario(4, budget=900.0)
            .with_topology(topo)
            .with_domain_cap(2, "rack0", committed0 + 50.0)
            .with_faults([ActuationNack(round=2, fraction=1.0)])
        )
        res = sim.run(sc, make_controller("ecoshift_hier", system))
        rec = res.records[2]
        assert "rack0" in rec.excursion_domains
        assert rec.overdraw_w > 0
        for r in res.records[2:]:
            for d, w in r.domain_draw.items():
                assert w <= r.domain_caps[d] + 1e-6, (r.round, d)


# ---------------------------------------------------------------------------
# NACK pinning + backoff + headroom redistribution
# ---------------------------------------------------------------------------


class TestPinning:
    def test_nacked_receiver_pinned_at_confirmed_caps(self, suite):
        system, _, _ = suite
        sim = _sim(suite)
        budgets = [1400.0, 700.0, 700.0, 700.0]
        sc = Scenario(4, budget=budgets).with_faults(
            [ActuationNack(round=1, fraction=0.3, seed=2)]
        )
        ctrl = make_controller("ecoshift", system)
        res = sim.run(sc, ctrl)
        nacked = res.records[1].nacked
        assert nacked
        a1 = _applied_caps(res.records[1])
        cmd2 = res.records[2].result.allocation.caps
        # round 2 re-commands the stuck receivers at their confirmed caps
        for nm in nacked:
            assert cmd2[nm] == pytest.approx(a1[nm], abs=1e-9)
        # ... while the freed headroom still goes to work: the commanded
        # allocation spends (close to) the full budget
        assert res.records[2].result.allocation.spent >= 700.0 * 0.95

    def test_ack_clears_pin_after_backoff(self, suite):
        system, _, _ = suite
        sim = _sim(suite)
        budgets = [1400.0, 700.0, 700.0, 700.0, 700.0]
        faulted = Scenario(5, budget=budgets).with_faults(
            [ActuationNack(round=1, fraction=0.3, seed=2)]
        )
        clean = Scenario(5, budget=budgets)
        res_f = sim.run(faulted, make_controller("ecoshift", system))
        res_c = sim.run(clean, make_controller("ecoshift", system))
        # one NACK backs off for one round; after the round-2 ACK the pin
        # clears and round 3 on is identical to the never-faulted run
        for rf, rc in zip(res_f.records[3:], res_c.records[3:]):
            _caps_equal(
                rf.result.allocation.caps, rc.result.allocation.caps
            )

    def test_retry_exhaustion_pins_permanently(self, suite):
        system, _, _ = suite
        ctrl = make_controller("ecoshift", system)
        caps = {"stuck": (150.0, 200.0)}
        for r in range(ctrl.NACK_MAX_RETRIES):
            ctrl.notify_actuation(
                ActuationReport(
                    round=r, acked=(), nacked=("stuck",), applied=caps
                )
            )
        pin = ctrl._pins["stuck"]
        assert pin["fails"] == ctrl.NACK_MAX_RETRIES
        # an ACK long after the horizon still cannot clear it
        ctrl.notify_actuation(
            ActuationReport(round=10_000, acked=("stuck",), nacked=(), applied={})
        )
        assert "stuck" in ctrl._pins

    def test_invalidate_drops_pins(self, suite):
        system, _, _ = suite
        ctrl = make_controller("ecoshift", system)
        ctrl.notify_actuation(
            ActuationReport(
                round=0, acked=(), nacked=("a", "b"),
                applied={"a": (100.0, 100.0), "b": (100.0, 100.0)},
            )
        )
        ctrl.invalidate(["a"])
        assert "a" not in ctrl._pins and "b" in ctrl._pins
        ctrl.invalidate(None)
        assert not ctrl._pins


# ---------------------------------------------------------------------------
# Crash / snapshot / restore
# ---------------------------------------------------------------------------


class TestCrashRestore:
    @pytest.mark.parametrize("policy", ["ecoshift", "ecoshift_hier"])
    def test_restored_run_is_bit_for_bit(self, suite, policy):
        system, apps, surfs = suite
        budgets = BUDGETS[:8]
        topo = (
            PowerTopology.uniform_racks(24, 3, rack_cap=1e6)
            if policy == "ecoshift_hier"
            else None
        )

        def _run(crash):
            sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
            sc = Scenario(8, budget=budgets)
            if topo is not None:
                sc = sc.with_topology(topo)
            if crash:
                sc = sc.with_faults([ControllerCrash(round=4, restore=True)])
            return sim.run(sc, make_controller(policy, system))

        ref, crashed = _run(False), _run(True)
        for a, b in zip(ref.records, crashed.records):
            _caps_equal(a.result.allocation.caps, b.result.allocation.caps)
            assert a.result.improvements == b.result.improvements

    def test_cold_crash_reconverges_immediately_on_clean_channel(self, suite):
        # K = 0 (DESIGN.md §18): warm caches are pure accelerators, so a
        # non-restored crash replays the clean run exactly from the very
        # next solve — only pins / online-learned state need the snapshot
        system, apps, surfs = suite

        def _run(crash):
            sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
            sc = Scenario(6, budget=BUDGETS[:6])
            if crash:
                sc = sc.with_faults([ControllerCrash(round=3, restore=False)])
            return sim.run(sc, make_controller("ecoshift", system))

        ref, crashed = _run(False), _run(True)
        for a, b in zip(ref.records, crashed.records):
            _caps_equal(a.result.allocation.caps, b.result.allocation.caps)

    def test_snapshot_file_roundtrip_bit_for_bit(self, suite, tmp_path):
        system, _, _ = suite
        sim = _sim(suite)
        budgets = [1400.0, 700.0, 700.0, 700.0, 700.0, 700.0]
        ctrl = make_controller("ecoshift", system)
        # warm the controller into a pinned state, then checkpoint it
        for r in range(3):
            sim.run_round(ctrl, budget=budgets[r], round_index=r)
        ctrl.notify_actuation(
            ActuationReport(
                round=2, acked=(), nacked=("pinned",),
                applied={"pinned": (140.0, 180.0)},
            )
        )
        path = tmp_path / "ctrl.snap"
        save_snapshot(path, ctrl.snapshot())
        restored = make_controller("ecoshift", system)
        restored.restore(load_snapshot(path))
        assert restored._pins == ctrl._pins
        assert restored._pin_round == ctrl._pin_round
        for r in range(3, 6):
            a = sim.run_round(ctrl, budget=budgets[r], round_index=r)
            b = sim.run_round(restored, budget=budgets[r], round_index=r)
            _caps_equal(a.allocation.caps, b.allocation.caps)

    def test_snapshot_pack_format_roundtrips_arrays(self, tmp_path):
        snap = {
            "policy": "ecoshift",
            "arr": np.arange(6, dtype=np.float64).reshape(2, 3),
            "tup": (1, 2.5, "x"),
            "intkeys": {(0.5, 1.5): [3.0, 2]},
            "nested": {"a": np.array([1.0, np.inf, -1.0])},
        }
        path = tmp_path / "fmt.snap"
        save_snapshot(path, snap)
        out = load_snapshot(path)
        assert out["policy"] == "ecoshift"
        assert np.array_equal(out["arr"], snap["arr"])
        assert out["arr"].dtype == np.float64
        assert out["tup"] == (1, 2.5, "x")
        assert out["intkeys"] == {(0.5, 1.5): [3.0, 2]}
        assert np.array_equal(out["nested"]["a"], snap["nested"]["a"])

    def test_restore_rejects_policy_mismatch(self, suite):
        system, _, _ = suite
        ctrl = make_controller("ecoshift", system)
        with pytest.raises(ValueError, match="policy"):
            ctrl.restore({"policy": "dps", "pins": {}, "pin_round": -1})


# ---------------------------------------------------------------------------
# Storm end-to-end: everything at once, invariants hold
# ---------------------------------------------------------------------------


class TestFaultStormEndToEnd:
    def test_full_storm_with_crash_keeps_every_invariant(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
        committed = float(sim.table.caps.sum())
        topo = PowerTopology.uniform_racks(
            24, 3, rack_cap=committed / 3 + 450.0
        )
        sc = (
            Scenario(14, budget=(BUDGETS + BUDGETS)[:14])
            .with_topology(topo)
            .with_fault_storm(
                seed=11, telemetry_drop=0.15, telemetry_delay=0.2,
                telemetry_corrupt=0.35, telemetry_stale=0.15,
                actuation_nack=0.4, actuation_partial=0.25,
                actuation_delay=0.25, node_fraction=0.35,
                crash_rounds=(5, 10),
            )
        )
        res = sim.run(sc, make_controller("ecoshift_hier", system))
        assert res.n_rounds == 14
        for rec in res.records:
            for d, w in rec.domain_draw.items():
                assert w <= rec.domain_caps[d] + 1e-6, (rec.round, d)
            for t in rec.telemetry:
                assert np.all(np.isfinite(np.asarray(t.allocated_caps)))


class TestDeepTreeFusedStorm:
    """Storms over the deep-tree + fused configurations: fused == host
    bit-for-bit under faults, every level capped, ≤1-round excursions."""

    @staticmethod
    def _deep_topology(system, apps, surfs, n):
        """4-level uniform_tree with binding caps: committed draw plus
        headroom tightening toward the leaves (root unconstrained)."""
        from repro.core.topology import PowerDomain

        probe = ClusterSim.build(
            system, apps, surfs, n_nodes=n, seed=0,
            initial_caps=(150.0, 150.0),
            topology=PowerTopology.uniform_tree(n, (2, 2), [1e15] * 3),
        )
        _, committed, _ = probe.domain_headroom(0)
        topo0 = probe.topology

        def recap(dom, depth):
            i = topo0.index[dom.name]
            cap = 1e18 if depth == 0 else float(committed[i]) + 500.0 / depth
            return PowerDomain(
                name=dom.name, cap=cap, nodes=dom.nodes,
                children=tuple(recap(c, depth + 1) for c in dom.children),
            )

        return PowerTopology(recap(topo0.domains[0], 0), n_nodes=n)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_fused_matches_host_under_storm(self, suite, seed):
        system, apps, surfs = suite
        n = 48
        topo = self._deep_topology(system, apps, surfs, n)
        budgets = [
            2000.0, 900.0, 1600.0, 700.0,
            2000.0, 1100.0, 1800.0, 800.0,
        ]
        scen = (
            Scenario(len(budgets), budget=budgets)
            .with_topology(topo)
            .with_fault_storm(
                seed=40 + seed, telemetry_drop=0.1, telemetry_corrupt=0.3,
                telemetry_stale=0.1, actuation_nack=0.35,
                actuation_partial=0.25, actuation_delay=0.2,
                node_fraction=0.3, crash_rounds=(3,),
            )
        )
        traces = {}
        for fused in (False, True):
            sim = ClusterSim.build(
                system, apps, surfs, n_nodes=n, seed=0,
                initial_caps=(150.0, 150.0), topology=topo,
            )
            traces[fused] = sim.run(
                scen, make_controller("ecoshift_hier", system, fused=fused)
            )
        host, fus = traces[False], traces[True]
        for a, b in zip(host.records, fus.records):
            assert dict(a.result.allocation.caps) == dict(
                b.result.allocation.caps
            ), f"fused diverged from host at round {a.round}"
        assert any(r.nacked for r in fus.records)
        prev_over = False
        for rec in fus.records:
            for name, draw in rec.domain_draw.items():
                assert draw <= rec.domain_caps[name] + 1e-6, (
                    rec.round, name, draw, rec.domain_caps[name]
                )
            over = rec.overdraw_w > 0.0
            # a pre-derate excursion is clawed back the round it appears,
            # never carried into the next round's settled draw
            if over:
                assert rec.derate_w > 0.0, rec.round
            assert not (over and prev_over), rec.round
            prev_over = over


# ---------------------------------------------------------------------------
# apply_event fail-fast (satellite)
# ---------------------------------------------------------------------------


class TestApplyEventsFailFast:
    def test_unknown_event_names_the_class(self, suite):
        sim = _sim(suite, n_nodes=8)
        with pytest.raises(TypeError, match="object"):
            sim.apply_events([object()])

    def test_fault_event_on_timeline_points_to_with_faults(self, suite):
        sim = _sim(suite, n_nodes=8)
        with pytest.raises(TypeError, match="with_faults"):
            sim.apply_events([TelemetryDrop(round=0)])


# ---------------------------------------------------------------------------
# Bounded warm caches (satellite)
# ---------------------------------------------------------------------------


class TestCacheBounds:
    @pytest.mark.parametrize("policy", ["ecoshift", "ecoshift_hier"])
    def test_tiny_bounds_are_bit_for_bit(self, suite, policy):
        system, apps, surfs = suite
        tiny = ControllerConfig(
            max_group_tables=1, max_agg_curves=1, max_picks=1,
            max_plans=1, max_allocations=1, max_frontiers=1,
        )
        topo = (
            PowerTopology.uniform_racks(24, 3, rack_cap=1e6)
            if policy == "ecoshift_hier"
            else None
        )

        def _run(cfg):
            sim = ClusterSim.build(system, apps, surfs, n_nodes=24, seed=3)
            sc = Scenario(6, budget=BUDGETS[:6])
            if topo is not None:
                sc = sc.with_topology(topo)
            return sim.run(sc, make_controller(policy, system, config=cfg))

        ref, bounded = _run(None), _run(tiny)
        for a, b in zip(ref.records, bounded.records):
            _caps_equal(a.result.allocation.caps, b.result.allocation.caps)

    def test_resize_evicts_to_bound(self):
        from repro.core.mckp import LRUCache

        c = LRUCache(8)
        for i in range(8):
            c[i] = i
        c.resize(2)
        assert len(c) == 2 and c.maxsize == 2
        assert c.get(7) == 7  # hottest entries survive
        with pytest.raises(ValueError):
            c.resize(0)


# ---------------------------------------------------------------------------
# Docs drift guard (satellite)
# ---------------------------------------------------------------------------


class TestDocsDrift:
    def test_fallback_reason_enum_matches_code_and_docs(self):
        from repro.core import mckp

        src = Path(mckp.__file__).read_text()
        emitted = set(
            re.findall(r'stats\["fallback_reason"\] = "(\w+)"', src)
        )
        assert emitted == types.FUSED_FALLBACK_REASONS
        doc = types.FusedRoundStats.__doc__ or ""
        field_doc = Path(types.__file__).read_text()
        design = (REPO / "DESIGN.md").read_text()
        for reason in types.FUSED_FALLBACK_REASONS:
            assert f'"{reason}"' in field_doc, reason
            assert f"`{reason}`" in design, reason
