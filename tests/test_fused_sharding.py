"""Device-sharded fused leaf DPs: parity across device counts.

The fused round ``shard_map``s its batched leaf DP scan over the leaf
axis (``repro.kernels.ops.leaf_shard_mesh``).  Each [L, NB] DP row is
independent, so the split is bitwise-neutral by construction — this
suite certifies it end to end on 4 virtual CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``, the same smoke
CI runs): the sharded fused solve, the forced-single-device fused solve
(``REPRO_FUSED_SHARDS=1``) and the host sparse solve must agree
bit-for-bit on picks, total value, spends and per-domain spends.

XLA fixes the device count at backend init, so the comparison runs in a
subprocess with the flag set before the first jax import.
"""

import os
import pathlib
import subprocess
import sys

_ROOT = pathlib.Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import numpy as np
import jax

assert jax.device_count() == 4, jax.devices()

import sys
sys.path.insert(0, "tests")
from test_hier_alloc import _random_groups
from test_deep_tree import _random_deep_tree
from repro.core import mckp


def solve_fused(root, budget):
    fstate = mckp.FusedState()
    out = mckp.solve_hierarchical_fused(
        root, budget, state=mckp.HierState(), fstate=fstate
    )
    assert out is not None, fstate.stats["fallback_reason"]
    return out


for seed in range(6):
    rng = np.random.default_rng(7000 + seed)
    budget = float(rng.integers(6, 30)) * 25.0
    root, _ = _random_deep_tree(
        rng, budget, unconstrained_internal=bool(seed % 2)
    )
    host = mckp.solve_hierarchical(root, budget)

    assert mckp._fused_shards() == 4  # sharded path engaged
    sharded = solve_fused(root, budget)

    import os
    os.environ["REPRO_FUSED_SHARDS"] = "1"
    mckp._fused_shards.cache_clear()
    assert mckp._fused_shards() == 1
    single = solve_fused(root, budget)
    del os.environ["REPRO_FUSED_SHARDS"]
    mckp._fused_shards.cache_clear()

    for sol in (sharded, single):
        assert sol.picks == host.picks
        assert sol.total_value == host.total_value
        assert sol.spent == host.spent
        assert sol.domain_spent == host.domain_spent

# warm-state structure change under sharding (DESIGN.md §17): a second,
# differently-shaped tree against the *same* FusedState must repack the
# resident banks by device-side compaction — no host fallback — and stay
# bit-for-bit with the host solver
rng = np.random.default_rng(7777)
budget = 500.0
root_a, _ = _random_deep_tree(rng, budget, unconstrained_internal=False)
root_b, _ = _random_deep_tree(rng, budget, unconstrained_internal=True)
fstate = mckp.FusedState()
sa = mckp.solve_hierarchical_fused(
    root_a, budget, state=mckp.HierState(), fstate=fstate
)
assert sa is not None, fstate.stats["fallback_reason"]
sb = mckp.solve_hierarchical_fused(
    root_b, budget, state=mckp.HierState(), fstate=fstate
)
assert sb is not None, fstate.stats["fallback_reason"]
assert fstate.stats["fallbacks"] == 0, fstate.stats
assert fstate.stats["rebuilds"] == 1, fstate.stats  # cold start only
assert fstate.stats["compactions"] >= 1, fstate.stats
hb = mckp.solve_hierarchical(root_b, budget)
assert sb.picks == hb.picks
assert sb.total_value == hb.total_value
assert sb.spent == hb.spent
assert sb.domain_spent == hb.domain_spent

print("SHARDED_PARITY_OK")
"""


def test_sharded_leaf_dps_bitwise_match_single_device():
    env = dict(os.environ)
    if "xla_force_host_platform_device_count" not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=4"
        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(_ROOT / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.pop("REPRO_FUSED_SHARDS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        cwd=_ROOT,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED_PARITY_OK" in out.stdout
