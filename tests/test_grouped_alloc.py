"""Group-collapsed allocation parity suite (DESIGN.md §11).

The load-bearing contracts of the multiplicity-aware MCKP solvers:

 * ``solve_sparse_grouped`` is **bit-for-bit** equal to ``solve_sparse`` on
   the name-sorted ungrouped expansion — picks, total_value and spent —
   on randomized mixed clusters, including interleaved member names and
   byte-identical duplicate tables (the straggler split);
 * the grouped dense/JAX/Pallas paths are bitwise equal to their ungrouped
   counterparts (same convolutions, same order);
 * end-to-end: a grouped controller stepping a scenario with failures and
   stragglers produces exactly the legacy per-instance controller's
   allocations and measured improvements, round for round.
"""

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # image without hypothesis: property tests skip
    from _hypothesis_stub import hypothesis, st

from repro.cluster import ClusterSim, Scenario
from repro.cluster.controller import make_controller
from repro.core import curves, mckp, policies, surfaces, types


@pytest.fixture(scope="module")
def suite():
    system = types.SYSTEM_1
    apps, surfs = surfaces.build_paper_suite(system)
    return system, apps, surfs


# ---------------------------------------------------------------------------
# Randomized mixed clusters: sparse grouped == sparse ungrouped, bit-for-bit
# ---------------------------------------------------------------------------


def _random_groups(rng: np.random.Generator, budget: float):
    """Random behaviour classes with interleaved member names and an
    occasional byte-identical duplicate table (straggler split)."""
    n_groups = int(rng.integers(1, 6))
    sizes = [int(rng.integers(1, 8)) for _ in range(n_groups)]
    slots: list[int] = []
    for g, m in enumerate(sizes):
        slots += [g] * m
    rng.shuffle(slots)
    members: dict[int, list[str]] = {g: [] for g in range(n_groups)}
    for i, g in enumerate(slots):
        members[g].append(f"x{i:03d}")

    groups = []
    for g in range(n_groups):
        k = int(rng.integers(1, 7))
        costs = np.unique(
            rng.integers(1, max(2, int(budget / 25)), size=k)
        ).astype(float) * 25.0
        values = np.sort(rng.uniform(0.01, 0.5, size=len(costs)))
        caps = np.stack([100.0 + costs, np.full_like(costs, 100.0)], axis=-1)
        table = curves.OptionTable(
            name=f"class{g}",
            costs=np.concatenate([[0.0], costs]),
            values=np.concatenate([[0.0], values]),
            caps=np.concatenate([[[100.0, 100.0]], caps], axis=0),
        )
        groups.append(
            mckp.GroupedOptions(table=table, members=tuple(sorted(members[g])))
        )
    if n_groups >= 2 and rng.random() < 0.4:
        t0 = groups[0].table
        dup = curves.OptionTable(
            name="dup",
            costs=t0.costs.copy(),
            values=t0.values.copy(),
            caps=t0.caps.copy(),
        )
        groups[1] = mckp.GroupedOptions(table=dup, members=groups[1].members)
    return groups


def _assert_bitwise_equal(a: mckp.MCKPSolution, b: mckp.MCKPSolution):
    assert a.picks == b.picks
    assert a.total_value == b.total_value
    assert a.spent == b.spent


@pytest.mark.parametrize("seed", range(12))
def test_sparse_grouped_parity_grid_sweep(seed):
    rng = np.random.default_rng(seed)
    for _ in range(15):
        budget = float(rng.integers(3, 40)) * 25.0
        groups = _random_groups(rng, budget)
        sp = mckp.solve_sparse(mckp.expand_groups(groups), budget)
        gr = mckp.solve_sparse_grouped(groups, budget)
        _assert_bitwise_equal(sp, gr)


@hypothesis.given(seed=st.integers(0, 2**31 - 1), budget_u=st.integers(3, 60))
@hypothesis.settings(max_examples=30, deadline=None)
def test_sparse_grouped_parity_property(seed, budget_u):
    rng = np.random.default_rng(seed)
    budget = budget_u * 25.0
    groups = _random_groups(rng, budget)
    sp = mckp.solve_sparse(mckp.expand_groups(groups), budget)
    gr = mckp.solve_sparse_grouped(groups, budget)
    _assert_bitwise_equal(sp, gr)


def test_sparse_grouped_curve_cache_reuse():
    rng = np.random.default_rng(5)
    groups = _random_groups(rng, 500.0)
    cache: dict = {}
    a = mckp.solve_sparse_grouped(groups, 500.0, curve_cache=cache)
    assert cache  # aggregate curves were stored
    b = mckp.solve_sparse_grouped(groups, 500.0, curve_cache=cache)
    _assert_bitwise_equal(a, b)


def test_aggregate_curve_matches_sequential_stages():
    """The binary-split m-fold self-convolution equals brute force over a
    small group."""
    rng = np.random.default_rng(9)
    for _ in range(10):
        budget = float(rng.integers(4, 12)) * 25.0
        groups = _random_groups(rng, budget)[:1]
        g = mckp.GroupedOptions(
            table=groups[0].table, members=groups[0].members[:4] or ("a",)
        )
        bf = mckp.brute_force(mckp.expand_groups([g]), budget)
        gr = mckp.solve_sparse_grouped([g], budget)
        np.testing.assert_allclose(gr.total_value, bf.total_value, atol=1e-9)
        assert gr.spent <= budget + 1e-9


# ---------------------------------------------------------------------------
# Dense / JAX / Pallas grouped paths
# ---------------------------------------------------------------------------


def test_dense_grouped_bitwise_parity():
    rng = np.random.default_rng(3)
    for _ in range(20):
        budget = float(rng.integers(3, 25)) * 25.0
        groups = _random_groups(rng, budget)
        de = mckp.solve_dense(mckp.expand_groups(groups), budget)
        dg = mckp.solve_dense_grouped(groups, budget)
        _assert_bitwise_equal(de, dg)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_jax_grouped_bitwise_parity(backend):
    rng = np.random.default_rng(4)
    for _ in range(3):
        budget = float(rng.integers(3, 10)) * 25.0
        groups = _random_groups(rng, budget)
        ja = mckp.solve_dense_jax(
            mckp.expand_groups(groups), budget, backend=backend
        )
        jg = mckp.solve_dense_jax_grouped(groups, budget, backend=backend)
        _assert_bitwise_equal(ja, jg)


# ---------------------------------------------------------------------------
# Controller / engine level
# ---------------------------------------------------------------------------


class TestControllerParity:
    def test_grouped_controller_equals_pure_policy(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=25, seed=4)
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        rows = sim.table.rows_for_ids([n.node_id for n in recv])
        batch = sim._receiver_batch(rows, None, False)
        ctrl = make_controller("ecoshift", system)
        for budget in (400.0, 1500.0):
            got = ctrl.allocate_grouped(batch, budget)
            want = policies.ecoshift(
                [n.app for n in recv], baselines, budget, system, seen
            )
            assert dict(got.caps) == dict(want.caps)
            assert got.spent == want.spent

    def test_pure_policy_grouped_kwarg(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=30, seed=6)
        _, recv, _ = sim.partition()
        baselines = {n.app.name: n.caps for n in recv}
        seen = {n.app.name: sim._surface(n) for n in recv}
        recv_apps = [n.app for n in recv]
        for solver in ("sparse", "dense"):
            a = policies.ecoshift(
                recv_apps, baselines, 900.0, system, seen, solver=solver
            )
            b = policies.ecoshift(
                recv_apps,
                baselines,
                900.0,
                system,
                seen,
                solver=solver,
                grouped=True,
            )
            assert dict(a.caps) == dict(b.caps)
            assert a.spent == b.spent

    @pytest.mark.parametrize("policy", ["ecoshift", "oracle"])
    def test_scenario_grouped_equals_legacy_per_instance(self, suite, policy):
        """Full multi-round certification: grouped columnar controller ==
        legacy per-instance controller, through failures and stragglers
        (the straggler's byte-identical table exercises class merging)."""
        system, apps, surfs = suite
        scen = (
            Scenario.constant(4, budget=1500.0)
            .with_failure(1, 2, 5)
            .with_straggler(2, 8, 1.8)
        )
        kw = {"exhaustive": False} if policy == "oracle" else {}
        sim_g = ClusterSim.build(system, apps, surfs, n_nodes=40, seed=0)
        trace_g = sim_g.run(scen, make_controller(policy, system, **kw))
        sim_l = ClusterSim.build(system, apps, surfs, n_nodes=40, seed=0)
        ctrl_l = make_controller(policy, system, **kw)
        if policy == "ecoshift":
            ctrl_l.grouped = False
        else:
            ctrl_l.supports_grouped = False
        trace_l = sim_l.run(scen, ctrl_l)
        for rg, rl in zip(trace_g.records, trace_l.records):
            assert dict(rg.result.allocation.caps) == dict(
                rl.result.allocation.caps
            )
            assert rg.result.improvements == rl.result.improvements

    def test_online_controller_grouped_path(self, suite):
        """ecoshift_online allocates through the grouped path with
        predictor-served surfaces (one class per served app)."""
        from repro.cluster.predictor import OnlinePredictor, OnlinePredictorConfig

        system, apps, surfs = suite

        class _StubNCF:
            def __init__(self, system):
                self.system = system
                self.app_index = {}

        served = {
            a.name: surfaces.tabulate(surfs[a.name], system) for a in apps[:6]
        }
        pred = OnlinePredictor(_StubNCF(system), OnlinePredictorConfig())
        pred.seed_surfaces(served)
        sim = ClusterSim.build(system, apps[:6], surfs, n_nodes=18, seed=1)
        ctrl = make_controller("ecoshift_online", system, predictor=pred)
        assert ctrl.supports_grouped
        res = sim.run_round(ctrl, budget=900.0)
        assert np.isfinite(list(res.improvements.values())).all()
        # served surfaces are shared per app: warm cache holds one table
        # per (app class, baseline), not one per node
        assert len(ctrl._group_tables) <= len(served)

    def test_grouped_cache_warm_across_budgets(self, suite):
        system, apps, surfs = suite
        sim = ClusterSim.build(system, apps, surfs, n_nodes=50, seed=2)
        ctrl = make_controller("ecoshift", system)
        sim.run_round(ctrl, budget=500.0)
        n_tables = len(ctrl._group_tables)
        assert n_tables > 0
        sim.run_round(ctrl, budget=2500.0)  # budget-independent tables
        assert len(ctrl._group_tables) == n_tables
